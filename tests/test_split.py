"""Split learning core — the paper's central mechanism.

Key invariants:
  1. split forward == full forward at every paper cut fraction (CNNs)
  2. split backward (client+server grads via the one-program autodiff)
     == joint end-to-end grads — Algorithm 3's distributed backward is
     exactly gradient-correct
  3. FedAvg mean semantics
  4. transformer group-cut partition preserves the function
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedavg import fedavg, fedavg_stack
from repro.core.split import (SplitStep, apply_stages, cut_index_for_fraction,
                              init_stages, partition_stages, split_stack,
                              merge_stack, stack_cut_index)
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
from repro.models.transformer import (build_groups, default_cut_layer,
                                      model_forward, model_init)
from repro.configs import ARCHS

FRACTIONS = (0.15, 0.25, 0.40, 0.75)  # the paper's SL_{a,b} variants


@pytest.fixture(scope="module")
def cnn_setup():
    key = jax.random.PRNGKey(0)
    stages = CNN_BUILDERS["mobilenetv2"](12)
    params = init_stages(key, stages)
    x = jax.random.uniform(jax.random.fold_in(key, 1), (4, 32, 32, 3))
    y = jax.random.randint(jax.random.fold_in(key, 2), (4,), 0, 12)
    return stages, params, x, y


@pytest.mark.parametrize("frac", FRACTIONS)
def test_split_forward_equivalence(cnn_setup, frac):
    stages, params, x, _ = cnn_setup
    full = apply_stages(stages, params, x)
    cs, cp, ss, sp, k = partition_stages(stages, params, frac)
    smashed = apply_stages(cs, cp, x)
    out = apply_stages(ss, sp, smashed)
    assert 1 <= k < len(stages)
    np.testing.assert_allclose(out, full, atol=1e-5)


def test_cut_fraction_monotone(cnn_setup):
    stages, *_ = cnn_setup
    ks = [cut_index_for_fraction(stages, f) for f in FRACTIONS]
    assert ks == sorted(ks)
    assert ks[0] >= 1 and ks[-1] <= len(stages) - 1


@pytest.mark.slow
def test_split_backward_equals_joint(cnn_setup):
    """Invariant 2: Algorithm 3's distributed backward == joint autodiff."""
    stages, params, x, y = cnn_setup
    frac = 0.4
    cs, cp, ss, sp, k = partition_stages(stages, params, frac)

    def joint_loss(all_params):
        out = apply_stages(stages, all_params, x)
        return cross_entropy_loss(out, y)

    g_joint = jax.grad(joint_loss)(params)

    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    _, _, g_c, g_s = step.grads(cp, sp, {"inputs": x, "targets": y})
    for a, b in zip(jax.tree_util.tree_leaves(g_c),
                    jax.tree_util.tree_leaves(g_joint[:k])):
        np.testing.assert_allclose(a, b, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_s),
                    jax.tree_util.tree_leaves(g_joint[k:])):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_ushaped_keeps_labels_clientside(cnn_setup):
    stages, params, x, y = cnn_setup
    cs, cp, ss, sp, k = partition_stages(stages, params, 0.25)
    # server body = all but last stage; client holds the head too
    body, head = ss[:-1], ss[-1]
    bp, hp = sp[:-1], sp[-1]

    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc["front"], xx),
        server_body=lambda ps, sm: apply_stages(body, ps, sm),
        client_head_loss=lambda pc, feats, yy: (
            cross_entropy_loss(head.apply(pc["head"], feats), yy), {}),
        variant="ushaped",
    )
    loss, aux = step.loss_fn({"front": cp, "head": hp}, bp,
                             {"inputs": x, "targets": y})
    assert jnp.isfinite(loss)
    assert "smashed_elems" in aux


def test_fedavg_mean():
    trees = [{"w": jnp.full((3,), float(i))} for i in range(4)]
    avg = fedavg(trees)
    np.testing.assert_allclose(avg["w"], 1.5)
    weighted = fedavg(trees, weights=[1, 0, 0, 0])
    np.testing.assert_allclose(weighted["w"], 0.0)


def test_fedavg_stack_broadcast():
    stacked = {"w": jnp.arange(8.0).reshape(4, 2)}
    out = fedavg_stack(stacked)
    expect = jnp.tile(jnp.array([[3.0, 4.0]]), (4, 1))
    np.testing.assert_allclose(out["w"], expect)


def test_split_stack_roundtrip():
    stacked = {"w": jnp.arange(12.0).reshape(6, 2)}
    c, s = split_stack(stacked, 2)
    assert c["w"].shape == (2, 2) and s["w"].shape == (4, 2)
    m = merge_stack(c, s)
    np.testing.assert_allclose(m["w"], stacked["w"])


def test_stack_cut_index_moe_clamp():
    assert stack_cut_index(28, 0.5, max_client=1) == 1
    assert stack_cut_index(28, 0.15) == 5


@pytest.mark.parametrize("arch", [
    "smollm-135m",
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    "rwkv6-7b", "whisper-tiny"])
def test_transformer_cut_preserves_function(arch):
    """Cutting a transformer into client/server groups must not change the
    function: evaluating the cut model == evaluating the same weights with
    the cut stacks merged back into one group."""
    cfg = ARCHS[arch].reduced()
    key = jax.random.PRNGKey(0)
    cut = default_cut_layer(cfg, 0.5)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.enc_dec:
        batch["frames"] = 0.02 * jax.random.normal(key, (2, cfg.enc_seq_len,
                                                         cfg.d_model))
    p_cut = model_init(cfg, key, cut_layer=cut)
    logits_cut, _ = model_forward(cfg, p_cut, batch, cut_layer=cut)

    # merge adjacent same-kind groups back into the uncut structure
    groups = build_groups(cfg, cut_layer=cut)
    merged, merged_groups = [], []
    for g, gp in zip(groups, p_cut["groups"]):
        if merged_groups and merged_groups[-1].kind == g.kind \
           and merged_groups[-1].moe == g.moe:
            merged[-1] = merge_stack(merged[-1], gp)
            merged_groups[-1] = build_groups(cfg)[len(merged) - 1]
        else:
            merged.append(gp)
            merged_groups.append(g)
    p_plain = dict(p_cut, groups=merged)
    logits_plain, _ = model_forward(cfg, p_plain, batch)
    np.testing.assert_allclose(np.asarray(logits_cut, np.float32),
                               np.asarray(logits_plain, np.float32),
                               atol=1e-4, rtol=1e-4)


def test_cut_tiers_tagged():
    cfg = ARCHS["yi-9b"]
    cut = default_cut_layer(cfg, 0.25)
    groups = build_groups(cfg, cut_layer=cut)
    tiers = [g.tier for g in groups]
    assert "client" in tiers and "server" in tiers
    assert sum(g.count for g in groups if g.tier == "client") == cut


def test_moe_cut_clamped_to_first_moe_layer():
    cfg = ARCHS["deepseek-moe-16b"]
    cut = default_cut_layer(cfg, 0.75)  # would be layer 21 without clamp
    assert cut == 1                      # clamped: experts are server-side
