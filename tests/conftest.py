import os
import sys

# the legacy XLA:CPU runtime parallelizes grad kernels inside scan bodies
# (the scanned multi-client engine's hot path) — must be set before jax
# initializes its backend. See repro.runtime_flags.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.runtime_flags import enable_fast_cpu_runtime  # noqa: E402

enable_fast_cpu_runtime()

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
