"""Fleet subsystem — sharded engine equivalence, hetero bucketing, link
compression, and the campaign acceptance scenario.

Equivalence assertions use ``repro.fleet.engine.FLEET_EQUIV_ATOL``, the
documented loosened tolerance: vmapping/sharding the client axis
reassociates fp32 reductions vs the sequential scan reference (the scanned
engine itself holds a 1e-4 bound vs the host loop — see test_engine.py).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import compile_experiment
from repro.core.energy import HardwareProfile, JETSON_AGX_ORIN
from repro.core.link import LinkConfig
from repro.core.split import (SplitStep, apply_stages, init_stages,
                              make_fl_round, partition_stages)
from repro.fleet import (CampaignConfig, FleetLink, HeteroFleet,
                         FLEET_EQUIV_ATOL, assign_cuts_cnn, bucket_by_cut,
                         campaign_spec, campaign_totals, cnn_split_program,
                         make_fleet_fl_round, make_fleet_sl_round,
                         stack_split_program)
from repro.kernels.quant.ref import roundtrip_error_bound
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
from repro.optim import adamw, apply_updates, init_stacked

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
C, S, B = 4, 2, 4          # clients, local steps, batch
NUM_CLASSES = 4


@pytest.fixture(scope="module")
def tiny_setup():
    stages = CNN_BUILDERS["tinycnn"](NUM_CLASSES)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    bx = jax.random.uniform(jax.random.fold_in(key, 1), (C, S, B, 16, 16, 3))
    by = jax.random.randint(jax.random.fold_in(key, 2), (C, S, B), 0,
                            NUM_CLASSES)
    return stages, params, bx, by


def _max_tree_diff(a, b) -> float:
    return max(float(jnp.abs(la.astype(jnp.float32)
                             - lb.astype(jnp.float32)).max())
               for la, lb in zip(jax.tree_util.tree_leaves(a),
                                 jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# engine: vmapped client axis + sharding
# ---------------------------------------------------------------------------

def test_fleet_fl_vmap_matches_scan(tiny_setup):
    """The vmapped FL client axis (fleet engine / client_axis='vmap') tracks
    the sequential scan engine within the documented loosened tolerance."""
    stages, params, bx, by = tiny_setup
    opt = adamw(1e-3)

    def grad_fn(p, batch):
        xx, yy = batch
        return jax.value_and_grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, xx), yy))(p)

    scan_round = jax.jit(make_fl_round(grad_fn, opt, client_axis="scan"))
    fleet_round = jax.jit(make_fleet_fl_round(grad_fn, opt))
    p_scan, p_fleet = params, params
    for _ in range(2):   # two consecutive rounds so drift compounds
        p_scan, l_scan = scan_round(p_scan, (bx, by))
        p_fleet, l_fleet = fleet_round(p_fleet, (bx, by))
        assert l_fleet.shape == (C, S)
        np.testing.assert_allclose(np.asarray(l_fleet), np.asarray(l_scan),
                                   atol=FLEET_EQUIV_ATOL)
    assert _max_tree_diff(p_fleet, p_scan) < FLEET_EQUIV_ATOL


def test_fl_round_rejects_unknown_client_axis(tiny_setup):
    stages, params, bx, by = tiny_setup
    with pytest.raises(ValueError):
        make_fl_round(lambda p, b: (0.0, p), adamw(1e-3),
                      client_axis="pmap")(params, (bx, by))


def test_fleet_sl_round_matches_parallel_reference(tiny_setup):
    """The compiled parallel-SL round == a host loop with the same semantics
    (batched client fwd/bwd, ONE server update per step on the client-mean
    gradient, FedAvg of prefixes at round end)."""
    stages, params, bx, by = tiny_setup
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    engine = jax.jit(make_fleet_sl_round(step, opt_c, opt_s, local_rounds=S))
    client_stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), cp0)
    out_stack, out_sp, _, _, losses = engine(
        client_stack, sp, init_stacked(opt_c, cp0, C), opt_s.init(sp),
        {"inputs": bx, "targets": by})
    assert losses.shape == (S, C)

    # host reference of the same parallel semantics
    cps = [jax.tree_util.tree_map(jnp.copy, cp0) for _ in range(C)]
    cops = [opt_c.init(cp0) for _ in range(C)]
    spar, sop = sp, opt_s.init(sp)
    ref_losses = np.zeros((S, C))
    for si in range(S):
        grads_c, grads_s, step_losses = [], [], []
        for ci in range(C):
            loss, _, g_c, g_s = step.grads(
                cps[ci], spar, {"inputs": bx[ci, si], "targets": by[ci, si]})
            grads_c.append(g_c)
            grads_s.append(g_s)
            step_losses.append(float(loss))
        for ci in range(C):
            up, cops[ci] = opt_c.update(grads_c[ci], cops[ci], cps[ci])
            cps[ci] = apply_updates(cps[ci], up)
        g_mean = jax.tree_util.tree_map(
            lambda *g: jnp.mean(jnp.stack(g), axis=0), *grads_s)
        up_s, sop = opt_s.update(g_mean, sop, spar)
        spar = apply_updates(spar, up_s)
        ref_losses[si] = step_losses
    from repro.core.fedavg import fedavg_stack
    ref_stack = fedavg_stack(jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *cps))

    np.testing.assert_allclose(np.asarray(losses), ref_losses,
                               atol=FLEET_EQUIV_ATOL)
    assert _max_tree_diff(out_stack, ref_stack) < FLEET_EQUIV_ATOL
    assert _max_tree_diff(out_sp, spar) < FLEET_EQUIV_ATOL


def test_sharded_round_matches_unsharded_host_mesh():
    """8 clients on a forced 4-device host mesh: the sharded fleet FL and
    SL rounds — GSPMD-constrained vmap AND explicit-collective shard_map —
    match the unsharded engine within FLEET_EQUIV_ATOL; the shard_map
    dropout masks (fedavg_pmean_masked, psum'd active counts) match the
    vmap masked-FedAvg result at the same gate; the vmap engine also runs
    the 2D (data=2, fsdp=2) layout with fleet_server_pspecs. Runs in a
    subprocess because forcing 4 host devices must precede jax init."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=4 "
            "--xla_cpu_use_thunk_runtime=false")
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.core.split import (SplitStep, apply_stages, init_stages,
                                      partition_stages)
        from repro.fleet.engine import (FLEET_EQUIV_ATOL, make_fleet_fl_round,
                                        make_fleet_sl_round,
                                        shard_client_stack,
                                        shard_server_state)
        from repro.launch.mesh import make_fleet_mesh
        from repro.launch.steps import fleet_server_pspecs
        from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
        from repro.optim import adamw, init_stacked

        C, S, B = 8, 2, 2
        stages = CNN_BUILDERS["tinycnn"](4)
        key = jax.random.PRNGKey(0)
        params = init_stages(key, stages)
        bx = jax.random.uniform(jax.random.fold_in(key, 1),
                                (C, S, B, 16, 16, 3))
        by = jax.random.randint(jax.random.fold_in(key, 2), (C, S, B), 0, 4)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32)
        mesh = make_fleet_mesh(C)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        assert sizes == {"data": 4, "fsdp": 1, "tp": 1}, sizes

        def tree_diff(a, b):
            return max(float(jnp.abs(x - y).max()) for x, y in zip(
                jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))

        diffs = {}
        opt = adamw(1e-3)
        def grad_fn(p, batch):
            xx, yy = batch
            return jax.value_and_grad(lambda q: cross_entropy_loss(
                apply_stages(stages, q, xx), yy))(p)
        plain = jax.jit(make_fleet_fl_round(grad_fn, opt))(params, (bx, by))
        for name, axis in (("fl_vmap", "vmap"), ("fl_smap", "shard_map")):
            out = jax.jit(make_fleet_fl_round(
                grad_fn, opt, mesh=mesh, client_axis=axis))(
                    params, shard_client_stack((bx, by), mesh))
            diffs[name + "_loss"] = float(jnp.abs(plain[1] - out[1]).max())
            diffs[name + "_par"] = tree_diff(plain[0], out[0])
        # dropout: shard_map masked FedAvg (fedavg_pmean_masked) == vmap
        plain_m = jax.jit(make_fleet_fl_round(
            grad_fn, opt, client_dropout=True))(params, (bx, by), mask)
        smap_m = jax.jit(make_fleet_fl_round(
            grad_fn, opt, mesh=mesh, client_axis="shard_map",
            client_dropout=True))(
                params, shard_client_stack((bx, by), mesh),
                shard_client_stack(mask, mesh))
        diffs["fl_mask_loss"] = float(jnp.abs(plain_m[1] - smap_m[1]).max())
        diffs["fl_mask_par"] = tree_diff(plain_m[0], smap_m[0])

        cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
        opt_c, opt_s = adamw(1e-3), adamw(1e-3)
        step = SplitStep(
            client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
            server_loss=lambda ps, sm, yy: (
                cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}))
        stack = jax.tree_util.tree_map(
            lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), cp0)
        batches = {"inputs": bx, "targets": by}

        def sl_state():
            return (stack, sp, init_stacked(opt_c, cp0, C), opt_s.init(sp))

        def sl_sharded_state(m):
            return (shard_client_stack(stack, m), sp,
                    shard_client_stack(init_stacked(opt_c, cp0, C), m),
                    opt_s.init(sp))

        plain_sl = jax.jit(make_fleet_sl_round(
            step, opt_c, opt_s, local_rounds=S))(*sl_state(), batches)
        for name, axis in (("sl_vmap", "vmap"), ("sl_smap", "shard_map")):
            out = jax.jit(make_fleet_sl_round(
                step, opt_c, opt_s, local_rounds=S, mesh=mesh,
                client_axis=axis))(*sl_sharded_state(mesh),
                                   shard_client_stack(batches, mesh))
            diffs[name + "_loss"] = float(jnp.abs(plain_sl[4] - out[4]).max())
            diffs[name + "_par"] = max(tree_diff(plain_sl[0], out[0]),
                                       tree_diff(plain_sl[1], out[1]))
        # dropout through the in-map collectives: masked clients frozen,
        # psum'd server reduction, fedavg_pmean_stack_masked closing agg
        plain_ms = jax.jit(make_fleet_sl_round(
            step, opt_c, opt_s, local_rounds=S, client_dropout=True))(
                *sl_state(), batches, mask)
        smap_ms = jax.jit(make_fleet_sl_round(
            step, opt_c, opt_s, local_rounds=S, mesh=mesh,
            client_axis="shard_map", client_dropout=True))(
                *sl_sharded_state(mesh), shard_client_stack(batches, mesh),
                shard_client_stack(mask, mesh))
        diffs["sl_mask_loss"] = float(
            jnp.abs(plain_ms[4] - smap_ms[4]).max())
        diffs["sl_mask_par"] = max(tree_diff(plain_ms[0], smap_ms[0]),
                                   tree_diff(plain_ms[1], smap_ms[1]))

        # 2D layout: (data=2, fsdp=2) mesh, server suffix sharded with the
        # build_step tier specs, vmap engine (GSPMD; shard_map x fsdp>1 is
        # gated off XLA:CPU — see fleet.engine)
        mesh2d = make_fleet_mesh(C, fsdp=2)
        sizes2d = dict(zip(mesh2d.axis_names, mesh2d.devices.shape))
        assert sizes2d == {"data": 2, "fsdp": 2, "tp": 1}, sizes2d
        sps = fleet_server_pspecs(sp, mesh2d)
        assert any(any(ax == "fsdp" for ax in s)
                   for s in jax.tree_util.tree_leaves(sps))
        out2d = jax.jit(make_fleet_sl_round(
            step, opt_c, opt_s, local_rounds=S, mesh=mesh2d,
            server_pspecs=sps))(
                shard_client_stack(stack, mesh2d),
                shard_server_state(sp, mesh2d, sps),
                shard_client_stack(init_stacked(opt_c, cp0, C), mesh2d),
                opt_s.init(shard_server_state(sp, mesh2d, sps)),
                shard_client_stack(batches, mesh2d))
        diffs["sl_2d_loss"] = float(jnp.abs(plain_sl[4] - out2d[4]).max())
        diffs["sl_2d_par"] = max(tree_diff(plain_sl[0], out2d[0]),
                                 tree_diff(plain_sl[1], out2d[1]))
        server_specs_out = {str(l.sharding.spec)
                            for l in jax.tree_util.tree_leaves(out2d[1])}

        # the same layout through the SPEC layer: EngineSpec(server_mesh=)
        # auto-builds the ('data','fsdp','tp') mesh and plan.init() places
        # the live server params + Adam moments with shard_server_state
        # (incl. the OptState(step=P(), mu=specs, nu=specs) spec tree)
        from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                               ExperimentSpec, ModelSpec, compile_experiment)
        spec = ExperimentSpec(
            model=ModelSpec(name="tinycnn", num_classes=4),
            data=DataSpec(kind="synthetic", image_size=16,
                          classes_per_client=2),
            clients=ClientSpec(num_clients=C),
            cut_policy=CutPolicy(mode="fraction", fraction=0.4),
            engine=EngineSpec(kind="sl", client_axis="vmap",
                              server_mesh=(2, 1)),
            global_rounds=1, local_steps=S, batch_size=2)
        plan = compile_experiment(spec)
        ms = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
        assert ms == {"data": 2, "fsdp": 2, "tp": 1}, ms
        state = plan.init()
        init_specs = {str(l.sharding.spec) for l in
                      jax.tree_util.tree_leaves(state.engine_state[1])}
        assert any("fsdp" in s for s in init_specs), init_specs
        state, rec = plan.run_round(state)
        assert rec.loss == rec.loss and rec.active_clients == C

        diffs["atol"] = FLEET_EQUIV_ATOL
        diffs["server_specs_out"] = sorted(server_specs_out)
        print(json.dumps(diffs))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    for k, v in rec.items():
        if k.endswith("_loss") or k.endswith("_par"):
            assert v < rec["atol"], (k, rec)
    # the 2D run's server suffix really lives on the fsdp axis
    assert any("fsdp" in s for s in rec["server_specs_out"]), rec


# ---------------------------------------------------------------------------
# hetero: cut assignment + bucketed dispatch
# ---------------------------------------------------------------------------

def test_bucketing_partitions_fleet():
    """bucket_by_cut is a partition: every client exactly once, buckets
    keyed by distinct cuts, deterministic order."""
    cuts = [2, 1, 2, 1, 1, 3, 2, 1]
    buckets = bucket_by_cut(cuts)
    seen = [cid for b in buckets for cid in b.client_ids]
    assert sorted(seen) == list(range(len(cuts)))
    assert len(seen) == len(set(seen)) == len(cuts)
    assert [b.cut_index for b in buckets] == [1, 2, 3]
    for b in buckets:
        assert all(cuts[cid] == b.cut_index for cid in b.client_ids)


def test_assign_cuts_cnn_profiles(tiny_setup):
    """Per-client cut selection: valid range, and identical (hardware, link)
    profiles always agree on the cut."""
    stages, params, bx, _ = tiny_setup
    mcu = HardwareProfile("mcu-class", fp32_tflops=0.02, mem_bw_gbs=2.0,
                          tensor_tflops=0.04, cpu_passmark=400.0, power_w=2.0)
    edges = [JETSON_AGX_ORIN, mcu, JETSON_AGX_ORIN, mcu]
    cuts = assign_cuts_cnn(stages, params, bx[0, 0], edges=edges)
    assert len(cuts) == 4
    assert all(1 <= k <= len(stages) - 1 for k in cuts)
    assert cuts[0] == cuts[2] and cuts[1] == cuts[3]


def test_hetero_fleet_round_covers_every_client(tiny_setup):
    """Bucketed dispatch: a mixed-cut fleet runs one global round and every
    client's losses are filled exactly once (from its own bucket)."""
    stages, params, bx, by = tiny_setup
    cuts = [1, 2, 1, 2]
    fleet = HeteroFleet(
        lambda k: cnn_split_program(stages, params, k,
                                    loss_fn=cross_entropy_loss),
        cuts, adamw(1e-3), adamw(1e-3), local_rounds=S)
    assert fleet.cut_of_client == cuts
    assert [b.cut_index for b in fleet.buckets] == [1, 2]
    losses = fleet.run_round({"inputs": bx, "targets": by})
    assert losses.shape == (S, C)
    assert np.isfinite(losses).all() and (losses > 0).all()
    # second round trains on (donated-through) bucket state
    losses2 = fleet.run_round({"inputs": bx, "targets": by})
    assert np.isfinite(losses2).all()
    assert losses2.mean() < losses.mean()   # same batches -> loss drops


def test_stack_split_program_matches_full_forward():
    """split_stack generalization: client scan + server scan == scanning the
    whole stacked-block model, and the fleet round trains it."""
    L, D, Bz = 6, 8, 4
    key = jax.random.PRNGKey(0)
    stacked = {"w": 0.3 * jax.random.normal(key, (L, D, D)),
               "b": jnp.zeros((L, D))}

    def block_apply(blk, h):
        return jnp.tanh(h @ blk["w"] + blk["b"])

    def loss_fn(h, targets):
        return jnp.mean((h.mean(-1) - targets) ** 2)

    prog = stack_split_program(stacked, 2, block_apply=block_apply,
                               loss_fn=loss_fn)
    x = jax.random.normal(jax.random.fold_in(key, 1), (Bz, D))
    full = x
    for i in range(L):
        full = block_apply(jax.tree_util.tree_map(lambda v: v[i], stacked),
                           full)
    smashed = prog.step.client_fwd(prog.params_c0, x)
    assert smashed.shape == (Bz, D)
    loss, _ = prog.step.server_loss(prog.params_s0, smashed,
                                    jnp.zeros((Bz,)))
    served = prog.step.client_fwd(prog.params_s0, smashed)  # same scan body
    np.testing.assert_allclose(np.asarray(served), np.asarray(full),
                               rtol=1e-5, atol=1e-6)
    assert np.isfinite(float(loss))

    # one fleet round over 4 clients of the stacked model
    opt_c, opt_s = adamw(1e-2), adamw(1e-2)
    engine = jax.jit(make_fleet_sl_round(prog.step, opt_c, opt_s,
                                         local_rounds=S))
    stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), prog.params_c0)
    bx = jax.random.normal(jax.random.fold_in(key, 2), (C, S, Bz, D))
    by = jax.random.normal(jax.random.fold_in(key, 3), (C, S, Bz))
    *_, losses = engine(stack, prog.params_s0,
                        init_stacked(opt_c, prog.params_c0, C),
                        opt_s.init(prog.params_s0),
                        {"inputs": bx, "targets": by})
    assert losses.shape == (S, C) and bool(jnp.isfinite(losses).all())


# ---------------------------------------------------------------------------
# link: int8 boundary
# ---------------------------------------------------------------------------

def test_int8_link_roundtrip_and_straight_through():
    """The compressed boundary respects the quantizer's roundtrip error
    bound and passes gradients straight through."""
    link = FleetLink(config=LinkConfig(compress="int8"))
    boundary = link.boundary()
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 128)) * 3.0
    y = boundary(x)
    bound = roundtrip_error_bound(x.reshape(-1, x.shape[-1]))
    assert np.all(np.abs(np.asarray(x - y)) <= np.asarray(bound) + 1e-7)
    # straight-through: d/dx sum(compress(x)) == 1 everywhere
    g = jax.grad(lambda v: boundary(v).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_int8_link_in_split_step_grads_flow(tiny_setup):
    """Attaching the int8 boundary keeps the split step differentiable:
    client and server grads stay finite/nonzero and near the uncompressed
    ones (straight-through estimator)."""
    stages, params, bx, by = tiny_setup
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    step8 = FleetLink(config=LinkConfig(compress="int8")).attach(step)
    assert step8.link_constraint is not None and step.link_constraint is None
    _, _, g_c, g_s = step.grads(cp0, sp, {"inputs": bx[0, 0],
                                          "targets": by[0, 0]})
    _, _, g_c8, g_s8 = step8.grads(cp0, sp, {"inputs": bx[0, 0],
                                             "targets": by[0, 0]})
    for g in (g_c8, g_s8):
        leaves = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.isfinite(l).all()) for l in leaves)
        assert any(float(jnp.abs(l).max()) > 0 for l in leaves)
    # compression perturbs but does not derail the gradients
    num = sum(float(jnp.sum(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(g_c),
                              jax.tree_util.tree_leaves(g_c8)))
    den = sum(float(jnp.sum(jnp.abs(a)))
              for a in jax.tree_util.tree_leaves(g_c))
    assert num / den < 0.5


def test_int8_wire_bytes_ratio():
    """int8 wire volume = 1 byte/elem + one f32 scale per quantizer row
    (the smashed tensor's last dim), matching what the kernel actually
    emits — a 4/(1 + 4/last_dim) shrink vs f32."""
    sd = jax.ShapeDtypeStruct((16, 8, 8, 32), jnp.float32)
    plain = FleetLink(config=LinkConfig()).step_wire_bytes(sd)
    comp = FleetLink(config=LinkConfig(compress="int8")).step_wire_bytes(sd)
    assert plain == 2 * sd.size * 4          # roundtrip fp32
    np.testing.assert_allclose(plain / comp, 4.0 / (1.0 + 4.0 / 32.0),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# campaign (acceptance scenario)
# ---------------------------------------------------------------------------

def test_campaign_link_sweep_records():
    """>=8 simulated clients produce per-round energy/accuracy/link-bytes
    records for both fp32 and int8 link modes; int8 moves ~4x fewer bytes
    on the same scenario; the UAV budget caps the rounds. The sweep is two
    campaign specs differing only in the link policy (the shape the dropped
    ``run_link_sweep`` shim used to package)."""
    cfg = CampaignConfig(model="tinycnn", num_clients=8, global_rounds=2,
                         local_steps=2, batch_size=4, image_size=16,
                         num_classes=NUM_CLASSES, classes_per_client=2)
    results = {}
    for mode in ("none", "int8"):
        spec = campaign_spec(dataclasses.replace(
            cfg, link=dataclasses.replace(cfg.link, compress=mode)))
        plan = compile_experiment(spec)
        _, records = plan.run()
        results[mode] = (plan, records)
    for mode, (plan, records) in results.items():
        assert plan.rounds_budget >= len(records) > 0
        assert len(plan.cut_of_client) == 8
        for rec in records:
            d = rec.to_dict()
            assert d["link_bytes"] > 0 and d["client_energy_j"] > 0
            assert d["server_energy_j"] > 0 and d["uav_energy_j"] > 0
            assert d["link_energy_j"] > 0
            assert 0.0 <= d["accuracy"] <= 1.0
            assert np.isfinite(d["loss"])

    totals = {mode: campaign_totals(records, plan.tour)
              for mode, (plan, records) in results.items()}
    for mode, (plan, records) in results.items():
        # mission totals include the return-to-base leg no record bills
        assert totals[mode]["uav_energy_j"] == pytest.approx(
            sum(r.uav_energy_j for r in records) + plan.tour.e_return)
        assert totals[mode]["rounds_run"] == len(records)

    ratio = totals["none"]["link_bytes"] / totals["int8"]["link_bytes"]
    # 4/(1 + 4/last_dim): narrow CNN smashed tensors pay more scale overhead
    assert 2.5 < ratio < 4.0, ratio
    # the compressed link also cuts radio transmit energy by the same factor
    e_ratio = (totals["none"]["link_energy_j"]
               / totals["int8"]["link_energy_j"])
    np.testing.assert_allclose(e_ratio, ratio, rtol=1e-6)
    # same seed + fleet -> identical tours; only the link differs
    assert results["none"][0].tour.order == results["int8"][0].tour.order


def test_campaign_adaptive_cuts():
    """Adaptive per-client cuts on a heterogeneous fleet: every client gets
    a valid cut and the campaign still produces records."""
    mcu = HardwareProfile("mcu-class", fp32_tflops=0.02, mem_bw_gbs=2.0,
                          tensor_tflops=0.04, cpu_passmark=400.0, power_w=2.0)
    cfg = CampaignConfig(model="tinycnn", num_clients=8, global_rounds=1,
                         local_steps=2, batch_size=4, image_size=16,
                         num_classes=NUM_CLASSES, classes_per_client=2,
                         adaptive_cuts=True,
                         edge_profiles=(JETSON_AGX_ORIN, mcu))
    plan = compile_experiment(campaign_spec(cfg))
    _, records = plan.run()
    assert len(plan.cut_of_client) == 8
    assert all(k >= 1 for k in plan.cut_of_client)
    assert len(records) == 1 and np.isfinite(records[0].loss)


def test_fleet_mesh_divisible_or_none():
    """make_fleet_mesh picks a ('data','fsdp','tp') layout whose data axis
    divides the fleet, or returns None when only one device is usable
    (device count varies with test order — earlier tests may force extra
    host devices)."""
    from repro.launch.mesh import make_fleet_mesh, single_device_fleet_mesh
    mesh = make_fleet_mesh(8)
    if len(jax.devices()) == 1:
        assert mesh is None
    else:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        assert 8 % sizes["data"] == 0
        assert sizes["fsdp"] == sizes["tp"] == 1    # server axes default off
    assert make_fleet_mesh(8, max_data=1) is None   # capped to one device
    assert make_fleet_mesh(1) is None               # one client, no mesh
    # the server sub-mesh consumes devices before the client axis
    n = len(jax.devices())
    assert make_fleet_mesh(8, fsdp=n + 1) is None   # over budget
    if n > 1:
        mesh2d = make_fleet_mesh(8, fsdp=n)
        sizes = dict(zip(mesh2d.axis_names, mesh2d.devices.shape))
        assert sizes == {"data": 1, "fsdp": n, "tp": 1}
    sd = single_device_fleet_mesh()
    assert dict(zip(sd.axis_names, sd.devices.shape)) == {
        "data": 1, "fsdp": 1, "tp": 1}


def test_server_only_mesh_keeps_server_axes():
    """A bucket whose size does not divide `data` falls back to the mesh
    with data collapsed to 1 — the fsdp/tp server sub-mesh survives
    instead of being silently dropped."""
    from repro.fleet.hetero import _server_only_mesh
    assert _server_only_mesh(None) is None
    if len(jax.devices()) >= 2:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(8, fsdp=len(jax.devices()) // 1, tp=1)
        # build a (data=1, fsdp=n) mesh directly: collapse is identity
        assert _server_only_mesh(mesh) is mesh
        mesh_d = make_fleet_mesh(8)          # data>1, fsdp=tp=1
        sub = _server_only_mesh(mesh_d)
        sizes = dict(zip(sub.axis_names, sub.devices.shape))
        assert sizes["data"] == 1
        assert sizes["fsdp"] == mesh_d.devices.shape[1]
        assert sizes["tp"] == mesh_d.devices.shape[2]


def test_fleet_server_pspecs_divisibility_guard():
    """fleet_server_pspecs mirrors build_step's server tier rule on the
    fleet mesh: matrix last-two dims (fsdp, tp), vectors over tp, every
    dim guarded — a non-dividing dim falls back to replicated."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import abstract_mesh
    from repro.launch.steps import fleet_server_pspecs
    mesh = abstract_mesh((1, 2, 4), ("data", "fsdp", "tp"))
    params = {"w": jnp.zeros((3, 3, 8, 16)),   # conv kernel: cin/fsdp, cout/tp
              "v": jnp.zeros((6, 16)),         # dense: 6%2==0 -> fsdp
              "odd": jnp.zeros((5, 7)),        # nothing divides -> replicated
              "b": jnp.zeros((16,)),           # bias follows cout -> tp
              "s": jnp.zeros(())}              # scalar -> replicated
    specs = fleet_server_pspecs(params, mesh)
    assert specs["w"] == P(None, None, "fsdp", "tp")
    assert specs["v"] == P("fsdp", "tp")
    assert specs["odd"] == P(None, None)
    assert specs["b"] == P("tp")
    assert specs["s"] == P()
