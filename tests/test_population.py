"""Population-scale rounds (``ClientSpec.population``).

The contract under test:

  * validation: malformed ClientSpecs fail loudly (population smaller than
    the cohort, dropout_rate outside [0, 1), num_clients < 1), and the
    engine corners population sampling cannot serve (sl/scan's persistent
    per-slot state, adaptive per-cohort cuts) are rejected at compile time,
  * ``sample_cohort`` is key-deterministic, sorted, in-range, the identity
    in the K == M corner, and availability weights down-weight bad-state
    clients,
  * the degenerate corner (population == num_clients) runs the ENTIRE
    cohort path — sampling, pool gather, profile gather — and reproduces
    the population=None record stream bit-for-bit on every engine,
  * engine state is O(cohort), not O(population): byte-identical pytrees
    at M = 1e4 and M = 1e6,
  * Monte-Carlo sweeps replay the plan's cohort stream (seed 0 == the
    plan's own realization) and report held-out accuracy per seed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec,
                       compile_experiment)
from repro.data.partition import (POPULATION_PARTITION_CAP,
                                  population_partition_count)
from repro.sim import (COHORT_DOWN_WEIGHT, AvailabilityParams, ChannelParams,
                       ScenarioSpec, availability_init, availability_step,
                       run_monte_carlo, sample_cohort)

NUM_CLASSES = 4


def _spec(kind="sl", axis="vmap", pop=None, n=4, scenario=None,
          global_rounds=2):
    return ExperimentSpec(
        model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
        data=DataSpec(kind="synthetic", image_size=16, classes_per_client=2),
        clients=ClientSpec(num_clients=n, population=pop),
        cut_policy=CutPolicy(mode="fraction", fraction=0.4),
        link_policy=LinkPolicy(),
        engine=EngineSpec(kind=kind, client_axis=axis),
        mission=MissionSpec(farm_acres=100.0),
        scenario=scenario,
        global_rounds=global_rounds, local_steps=2, batch_size=4, seed=0)


MARKOV = ScenarioSpec(
    channel=ChannelParams(kind="a2g"),
    availability=AvailabilityParams(kind="markov", p_drop=0.4,
                                    p_recover=0.6),
    seed=1)


def _assert_records_match(recs_a, recs_b, *, expect_pids):
    assert len(recs_a) == len(recs_b) > 0
    for a, b in zip(recs_a, recs_b):
        da, db = a.to_dict(), b.to_dict()
        for field, va in da.items():
            if field == "cohort_pids":
                continue
            if isinstance(va, float) and np.isfinite(va):
                assert db[field] == pytest.approx(va, rel=1e-12), field
            else:
                assert db[field] == va, field
        assert tuple(a.cohort_pids) == ()
        assert tuple(b.cohort_pids) == expect_pids


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_rejects_population_smaller_than_cohort():
    with pytest.raises(ValueError, match="smaller than the"):
        compile_experiment(_spec(pop=2, n=4))


def test_rejects_bad_dropout_rate_and_client_count():
    with pytest.raises(ValueError, match="dropout_rate"):
        compile_experiment(dataclasses.replace(
            _spec(), clients=ClientSpec(num_clients=4, dropout_rate=1.0)))
    with pytest.raises(ValueError, match="dropout_rate"):
        compile_experiment(dataclasses.replace(
            _spec(), clients=ClientSpec(num_clients=4, dropout_rate=-0.1)))
    with pytest.raises(ValueError, match="num_clients"):
        compile_experiment(dataclasses.replace(
            _spec(), clients=ClientSpec(num_clients=0)))


def test_rejects_population_on_sl_scan_and_adaptive_cuts():
    # sl/scan keeps per-slot client params + Adam moments across rounds —
    # a sampled cohort would leak one population client's state into
    # another's slot
    with pytest.raises(ValueError, match="sl/scan"):
        compile_experiment(_spec(axis="scan", pop=100))
    with pytest.raises(ValueError, match="adaptive"):
        compile_experiment(dataclasses.replace(
            _spec(pop=100), cut_policy=CutPolicy(mode="adaptive")))


def test_describe_gains_cohort_tag():
    assert _spec().describe() == \
        "sl/vmap[cut=fraction,link=none,mission=yes]"
    assert _spec(pop=1000, n=8).describe() == \
        "sl/vmap[cut=fraction,link=none,mission=yes,cohort=8/1000]"


# ---------------------------------------------------------------------------
# cohort sampling primitive
# ---------------------------------------------------------------------------

def test_sample_cohort_deterministic_sorted_in_range():
    k = jax.random.PRNGKey(3)
    a = np.asarray(sample_cohort(k, 1000, 8))
    b = np.asarray(sample_cohort(k, 1000, 8))
    c = np.asarray(sample_cohort(jax.random.fold_in(k, 1), 1000, 8))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) > 0)            # sorted, no replacement
    assert a.min() >= 0 and a.max() < 1000
    with pytest.raises(ValueError, match="cohort size"):
        sample_cohort(k, 4, 8)


def test_sample_cohort_identity_when_cohort_equals_population():
    for seed in range(4):
        ids = np.asarray(sample_cohort(jax.random.PRNGKey(seed), 6, 6))
        np.testing.assert_array_equal(ids, np.arange(6))
        # weights cannot change a full draw
        w = jnp.asarray([1.0, 0.05, 1.0, 0.05, 1.0, 0.05])
        ids = np.asarray(sample_cohort(jax.random.PRNGKey(seed), 6, 6,
                                       weights=w))
        np.testing.assert_array_equal(ids, np.arange(6))


def test_sample_cohort_weights_downweight_bad_clients():
    """Half the population at COHORT_DOWN_WEIGHT must be sampled far less
    often than the up half (Gumbel top-k == weighted sampling without
    replacement)."""
    pop, k = 100, 10
    w = jnp.concatenate([jnp.ones(50), jnp.full(50, COHORT_DOWN_WEIGHT)])
    down = 0
    trials = 200
    for s in range(trials):
        ids = np.asarray(sample_cohort(jax.random.PRNGKey(s), pop, k,
                                       weights=w))
        down += int((ids >= 50).sum())
    frac_down = down / (trials * k)
    assert frac_down < 0.2                   # unweighted would be ~0.5


# ---------------------------------------------------------------------------
# degenerate corner: population == num_clients is bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,axis", [("fl", "scan"), ("fl", "vmap"),
                                       ("sl", "scan"), ("sl", "vmap")])
def test_degenerate_population_reproduces_records(kind, axis):
    """population == num_clients runs the full cohort path (sampling, pool
    gather, profile gather) yet must reproduce today's record stream
    exactly — the materialized fleet is a pinned special case."""
    _, recs0 = compile_experiment(_spec(kind, axis)).run()
    _, recs1 = compile_experiment(_spec(kind, axis, pop=4)).run()
    _assert_records_match(recs0, recs1, expect_pids=(0, 1, 2, 3))


@pytest.mark.parametrize("kind", ["fl", "sl"])
def test_degenerate_population_reproduces_records_under_scenario(kind):
    """Same corner with a stochastic scenario attached: the availability
    trace runs over the (equal-sized) population and the channel re-bill
    must not move either."""
    _, recs0 = compile_experiment(_spec(kind, "vmap", scenario=MARKOV)).run()
    _, recs1 = compile_experiment(
        _spec(kind, "vmap", pop=4, scenario=MARKOV)).run()
    _assert_records_match(recs0, recs1, expect_pids=(0, 1, 2, 3))


# ---------------------------------------------------------------------------
# O(cohort) state
# ---------------------------------------------------------------------------

def _state_bytes(tree):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


@pytest.mark.parametrize("kind", ["fl", "sl"])
def test_engine_state_independent_of_population(kind):
    """The acceptance bar: a million-client population compiles and runs
    with engine state whose byte size does not depend on M (FL: stateless
    cohort rounds; SL: the EPSL shared client tier)."""
    sizes = {}
    for pop in (10_000, 1_000_000):
        plan = compile_experiment(_spec(kind, "vmap", pop=pop, n=8))
        state = plan.init()
        sizes[pop] = _state_bytes(state.engine_state)
        state, rec = plan.run_round(state, with_eval=False)
        assert len(rec.cohort_pids) == 8
        assert max(rec.cohort_pids) < pop
        # data pool stays O(dataset), capped
        assert len(plan.parts) == population_partition_count(
            pop, len(plan.y_train))
        assert len(plan.parts) <= POPULATION_PARTITION_CAP
    assert sizes[10_000] == sizes[1_000_000]


def test_shared_tier_runs_through_shard_map():
    """The shared client tier lowers through the explicit-collective
    shard_map engine too (client params replicated, gradients psum'd)."""
    plan = compile_experiment(_spec("sl", "shard_map", pop=1000, n=4))
    _, recs = plan.run()
    assert np.isfinite(recs[-1].loss)
    assert len(recs[-1].cohort_pids) == 4


# ---------------------------------------------------------------------------
# availability-weighted sampling (plan level)
# ---------------------------------------------------------------------------

def test_cohort_sampling_follows_availability_trace():
    """Under a bursty markov trace, sampled cohorts must be enriched in
    up-state clients relative to the population's up fraction."""
    scn = ScenarioSpec(
        availability=AvailabilityParams(kind="markov", p_drop=0.6,
                                        p_recover=0.2), seed=3)
    pop, k, rounds = 40, 8, 12
    plan = compile_experiment(
        _spec("fl", "vmap", pop=pop, n=k, scenario=scn))
    state = plan.init()
    frac_up_pop, frac_up_cohort = [], []
    env = jax.random.PRNGKey(scn.seed)
    up = np.asarray(availability_init(pop))
    for r in range(rounds):
        # replicate the plan's trace: weights use the state ENTERING the
        # round, the mask draw (fold 1) advances it
        up_entering = up.copy()
        _, up_j = availability_step(
            jax.random.fold_in(jax.random.fold_in(env, r), 1),
            jnp.asarray(up), scn.availability)
        up = np.asarray(up_j)
        state, rec = plan.run_round(state, with_eval=False)
        if up_entering.sum() == pop:
            continue                          # round 0: everyone up
        frac_up_pop.append(up_entering.mean())
        frac_up_cohort.append(
            up_entering[list(rec.cohort_pids)].mean())
    assert len(frac_up_cohort) > 0
    assert np.mean(frac_up_cohort) > np.mean(frac_up_pop) + 0.1


# ---------------------------------------------------------------------------
# Monte-Carlo: cohort replay + held-out accuracy
# ---------------------------------------------------------------------------

def test_monte_carlo_replays_plan_cohorts_and_reports_accuracy():
    """Sweep seed 0 must replay the plan's own realization — cohort ids
    bit-identical, bills within float tolerance — and every seed carries
    one finite held-out accuracy on its final round."""
    plan = compile_experiment(
        _spec("sl", "vmap", pop=50, n=4, scenario=MARKOV,
              global_rounds=3))
    _, recs = plan.run()
    res = run_monte_carlo(plan, 3, mode="vmap")
    mc = res.records_for_seed(0)
    for r in range(3):
        assert mc[r].cohort_pids == recs[r].cohort_pids
        assert mc[r].loss == pytest.approx(recs[r].loss, rel=2e-5)
        assert mc[r].client_energy_j == pytest.approx(
            recs[r].client_energy_j, rel=1e-5)
        assert mc[r].active_clients == recs[r].active_clients
    # eval satellite: accuracy spread is real, not NaN
    acc = res.stacks["final_accuracy"]
    assert acc.shape == (3,) and np.all(np.isfinite(acc))
    assert np.isfinite(mc[-1].accuracy)
    assert np.isnan(mc[0].accuracy)           # intermediate rounds stay NaN
    stats = res.summary()["final_accuracy"]
    assert stats is not None and np.isfinite(stats["mean"])


def test_monte_carlo_population_vmap_matches_loop():
    plan = compile_experiment(
        _spec("sl", "vmap", pop=50, n=4, scenario=MARKOV,
              global_rounds=3))
    rv = run_monte_carlo(plan, 3, mode="vmap")
    rl = run_monte_carlo(plan, 3, mode="loop")
    np.testing.assert_array_equal(rv.stacks["cohort"], rl.stacks["cohort"])
    for k in ("loss", "client_energy_j", "link_energy_j", "active_clients",
              "final_accuracy"):
        np.testing.assert_allclose(rv.stacks[k], rl.stacks[k],
                                   rtol=1e-5, atol=1e-6)


def test_monte_carlo_without_population_reports_accuracy():
    """The eval pass is population-independent: plain plans gain the
    across-seed accuracy spread too, with no cohort stack."""
    plan = compile_experiment(_spec("sl", "vmap"))
    res = run_monte_carlo(plan, 2, mode="vmap")
    assert "cohort" not in res.stacks
    assert np.all(np.isfinite(res.stacks["final_accuracy"]))
    assert res.records_for_seed(0)[0].cohort_pids == ()
