"""Adaptive split-point selection (paper future-work feature)."""
import jax
import pytest

from repro.configs import ARCHS
from repro.core.adaptive_cut import (profile_cuts_cnn,
                                     profile_cuts_transformer, select_cut)
from repro.core.link import LinkConfig
from repro.core.split import init_stages
from repro.models.cnn import CNN_BUILDERS


def test_cnn_cut_profile_monotone_flops():
    stages = CNN_BUILDERS["mobilenetv2"](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    x = jax.random.uniform(key, (4, 32, 32, 3))
    prof = profile_cuts_cnn(stages, params, x)
    assert len(prof) == len(stages) - 1
    flops = [c.client_flops for c in prof]
    assert all(b >= a for a, b in zip(flops, flops[1:]))  # deeper = more


def test_cnn_min_energy_cut_is_shallow():
    """With MobileNetV2's cheap early layers, the energy-optimal cut is
    client-light — the paper's SL_15,85 finding, now *derived*."""
    stages = CNN_BUILDERS["mobilenetv2"](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    x = jax.random.uniform(key, (4, 32, 32, 3))
    prof = profile_cuts_cnn(stages, params, x)
    best = select_cut(prof)
    assert best.client_fraction <= 0.5


def test_link_deadline_constraint():
    stages = CNN_BUILDERS["resnet18"](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    x = jax.random.uniform(key, (4, 32, 32, 3))
    slow = LinkConfig(rate_bps=1e6)        # 1 Mb/s: link dominates
    prof = profile_cuts_cnn(stages, params, x, link=slow)
    tight = select_cut(prof, max_link_s=min(c.t_link_s for c in prof) * 1.01)
    free = select_cut(prof)
    # the deadline forces the smallest-smashed-tensor cut
    assert tight.smashed_bytes <= free.smashed_bytes


def test_int8_link_shifts_optimum_clientward_or_equal():
    """Compressing the link lowers link cost, so the optimum can only move
    toward shallower (cheaper-client) cuts or stay."""
    stages = CNN_BUILDERS["googlenet"](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    x = jax.random.uniform(key, (4, 32, 32, 3))
    plain = select_cut(profile_cuts_cnn(stages, params, x,
                                        link=LinkConfig(rate_bps=20e6)))
    comp = select_cut(profile_cuts_cnn(
        stages, params, x, link=LinkConfig(rate_bps=20e6, compress="int8")))
    assert comp.energy_j <= plain.energy_j + 1e-9


def test_transformer_profile():
    prof = profile_cuts_transformer(ARCHS["smollm-135m"], batch=4, seq=128)
    assert len(prof) == ARCHS["smollm-135m"].n_layers - 1
    best = select_cut(prof)
    # transformer layers are homogeneous: smashed bytes constant, so the
    # minimum-energy cut is the shallowest — exactly the paper's "first
    # few layers" prescription
    assert best.cut_index == 1
