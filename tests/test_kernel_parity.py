"""Engine-level golden tests: kernel-enabled plans == kernel-off plans.

The PR-9 acceptance gate: one spec compiled with the Pallas kernels on
(``ModelSpec.attn_impl="pallas"`` / ``EngineSpec.link_kernel="fused"``,
interpret mode on this CPU container) must produce an equivalent
``RoundRecord`` stream to the same spec with kernels off, within
``FLEET_EQUIV_ATOL``, on every engine variant — the same style of matrix
``tests/test_fleet.py`` / ``tests/test_api.py`` gate engine axes with.
"""
import dataclasses

import pytest

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, ModelSpec,
                       compile_experiment)
from repro.configs.base import ArchConfig
from repro.fleet import FLEET_EQUIV_ATOL

TINY_ARCH = ArchConfig(name="tinylm", family="attn", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                       dtype="float32")

LM_BASE = ExperimentSpec(
    model=ModelSpec(family="transformer", name="tinylm", arch=TINY_ARCH),
    data=DataSpec(kind="tokens", partition="iid", seq_len=16,
                  n_train=32, n_test=16),
    clients=ClientSpec(num_clients=2),
    cut_policy=CutPolicy(mode="fraction", fraction=0.5),
    engine=EngineSpec(kind="sl", client_axis="vmap"),
    global_rounds=2, local_steps=1, batch_size=4, seed=0)

CNN_BASE = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=4),
    data=DataSpec(kind="synthetic", image_size=12, classes_per_client=2,
                  n_train=32, n_test=16),
    clients=ClientSpec(num_clients=2),
    cut_policy=CutPolicy(mode="fraction", fraction=0.4),
    link_policy=LinkPolicy(compress="int8"),
    engine=EngineSpec(kind="sl", client_axis="vmap"),
    global_rounds=2, local_steps=1, batch_size=4, seed=0)


def _assert_equiv_records(rec_off, rec_on):
    assert len(rec_off) == len(rec_on) > 0
    for a, b in zip(rec_off, rec_on):
        assert abs(a.loss - b.loss) <= FLEET_EQUIV_ATOL
        assert abs(a.accuracy - b.accuracy) <= FLEET_EQUIV_ATOL
        # the wire volume is shape-derived: kernels must not change it
        assert a.link_bytes == b.link_bytes
        assert a.active_clients == b.active_clients
        # the energy bill derives from XLA cost analysis of the ACTUAL
        # program, and a different kernel impl legitimately counts slightly
        # different FLOPs — hold it to a few percent, not bit equality
        assert a.client_energy_j == pytest.approx(b.client_energy_j,
                                                  rel=0.05)
        assert a.server_energy_j == pytest.approx(b.server_energy_j,
                                                  rel=0.05)


@pytest.mark.parametrize("axis", ["scan", "vmap", "shard_map"])
@pytest.mark.parametrize("attn_impl", ["pallas", "ref"])
def test_lm_attn_kernel_matches_xla(axis, attn_impl):
    """Split-LM rounds with the flash kernel (or the O(S²) oracle) in the
    server-suffix AND client-prefix blocks track the chunked-XLA plans."""
    off = dataclasses.replace(LM_BASE, engine=EngineSpec("sl", axis))
    on = dataclasses.replace(
        off, model=dataclasses.replace(LM_BASE.model, attn_impl=attn_impl))
    _, rec_off = compile_experiment(off).run()
    _, rec_on = compile_experiment(on).run()
    _assert_equiv_records(rec_off, rec_on)


@pytest.mark.parametrize("axis", ["scan", "vmap", "shard_map"])
def test_int8_link_fused_matches_xla_sl(axis):
    """The fused one-kernel int8 boundary inside the SL split step tracks
    the two-op jnp reference boundary round-for-round."""
    off = dataclasses.replace(CNN_BASE, engine=EngineSpec("sl", axis))
    on = dataclasses.replace(
        CNN_BASE, engine=EngineSpec("sl", axis, link_kernel="fused"))
    _, rec_off = compile_experiment(off).run()
    _, rec_on = compile_experiment(on).run()
    _assert_equiv_records(rec_off, rec_on)


@pytest.mark.parametrize("axis", ["scan", "vmap", "shard_map"])
def test_int8_link_kernel_flag_is_inert_for_fl(axis):
    """FL rounds have no link boundary: flipping the link kernel must not
    change a single record (completeness row of the kernels-on/off
    matrix)."""
    off = dataclasses.replace(CNN_BASE, engine=EngineSpec("fl", axis))
    on = dataclasses.replace(
        CNN_BASE, engine=EngineSpec("fl", axis, link_kernel="fused"))
    _, rec_off = compile_experiment(off).run()
    _, rec_on = compile_experiment(on).run()
    for a, b in zip(rec_off, rec_on):
        assert a.loss == b.loss and a.accuracy == b.accuracy


def test_lm_attn_and_fused_link_compose():
    """Both kernels on at once — flash attention in the blocks and the
    fused int8 boundary at the cut — still match the all-XLA plan."""
    off = dataclasses.replace(LM_BASE,
                              link_policy=LinkPolicy(compress="int8"))
    on = dataclasses.replace(
        off,
        model=dataclasses.replace(LM_BASE.model, attn_impl="pallas"),
        engine=EngineSpec("sl", "vmap", link_kernel="fused"))
    _, rec_off = compile_experiment(off).run()
    _, rec_on = compile_experiment(on).run()
    _assert_equiv_records(rec_off, rec_on)


def test_kernel_spec_validation():
    with pytest.raises(ValueError, match="attn_impl"):
        compile_experiment(dataclasses.replace(
            CNN_BASE, model=dataclasses.replace(CNN_BASE.model,
                                                attn_impl="pallas")))
    with pytest.raises(ValueError, match="link_kernel"):
        compile_experiment(dataclasses.replace(
            LM_BASE, engine=EngineSpec("sl", "vmap", link_kernel="tf32")))
    with pytest.raises(ValueError, match="int8"):
        # fused boundary without a compressed link is a spec error
        compile_experiment(dataclasses.replace(
            CNN_BASE, link_policy=LinkPolicy(compress="none"),
            engine=EngineSpec("sl", "vmap", link_kernel="fused")))
