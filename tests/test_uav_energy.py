"""UAV energy model — Eqs. (1)-(2) with Table-I constants."""
import math

import pytest

from repro.core.uav_energy import DEFAULT_UAV, UAVParams, tour_energy


def test_hover_power_components():
    u = DEFAULT_UAV
    # P0 = delta/8 * rho * r * a * Omega^3 * R^3
    p0 = 0.011 / 8 * 1.225 * 0.08 * 0.7 * 320 ** 3 * 0.45 ** 3
    assert abs(u.P0 - p0) < 1e-6
    # Pi = (1+k) W^1.5 / sqrt(2 rho a)
    pi = 1.15 * 63.4 ** 1.5 / math.sqrt(2 * 1.225 * 0.7)
    assert abs(u.Pi - pi) < 1e-6
    assert abs(u.xi_h - (p0 + pi)) < 1e-6


def test_propulsion_power_at_speed():
    u = DEFAULT_UAV
    # Eq. (1) at V=10 has all three terms positive & finite
    xm = u.xi_m(10.0)
    assert xm > 0 and math.isfinite(xm)
    # blade-profile term grows with V^2, parasite with V^3: high speed costs
    assert u.xi_m(30.0) > u.xi_m(10.0)


def test_hover_more_expensive_than_slow_flight():
    """Classic rotary-wing curve: induced power drops with forward speed, so
    moderate V is cheaper than hovering."""
    u = DEFAULT_UAV
    assert u.xi_m(10.0) < u.xi_h


def test_reception_range():
    u = UAVParams(altitude=30.0)
    assert abs(u.reception_range(50.0) - math.sqrt(50**2 - 30**2)) < 1e-9
    assert u.reception_range(10.0) == 0.0  # CR < h


def test_tour_energy_budget_decomposition():
    e = tour_energy(1000.0, 4)
    assert abs(e["E_total"] - (e["E_move"] + e["E_hover"] + e["E_comm"])) < 1e-6
    assert e["T_move"] == pytest.approx(100.0)  # 1000m at 10 m/s
