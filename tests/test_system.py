"""End-to-end behaviour tests for the eEnergy-Split system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.deployment import deploy_edge_devices, uniform_grid_sensors
from repro.core.trajectory import plan_tour
from repro.data.synthetic import synthetic_tokens
from repro.models.transformer import default_cut_layer, lm_loss, model_init
from repro.optim import adamw, apply_updates, clip_by_global_norm


@pytest.mark.slow
def test_llm_split_training_loss_decreases():
    """Reduced smollm trained with the split cut for 40 steps must cut loss
    substantially below its initial value (learnable copy-structure data)."""
    cfg = ARCHS["smollm-135m"].reduced()
    cut = default_cut_layer(cfg, 0.15)
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key, cut_layer=cut)
    opt = adamw(3e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, cut_layer=cut),
            has_aux=True)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    losses = []
    for i in range(40):
        toks = synthetic_tokens(jax.random.fold_in(key, i), 8, 64, cfg.vocab)
        params, opt_state, loss = step(params, opt_state,
                                       {"tokens": toks, "labels": toks})
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_full_mission_pipeline():
    """Deployment -> tour -> rounds budget -> the numbers are coherent."""
    pts = uniform_grid_sensors(100, 25)
    dep = deploy_edge_devices(pts, 200.0)
    plan = plan_tour(dep.edge_coords, np.zeros(2))
    assert plan.rounds >= 1
    # energy bookkeeping: first + (rounds-1)*per + return <= beta
    total = plan.e_first + (plan.rounds - 1) * plan.e_per_round + plan.e_return
    assert total <= 1.9e6 + 1e-6


def test_checkpoint_roundtrip_model(tmp_path):
    import os
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    cfg = ARCHS["smollm-135m"].reduced()
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key)
    path = os.path.join(tmp_path, "m.msgpack")
    save_checkpoint(path, params, meta={"arch": cfg.name})
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    back = restore_checkpoint(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
