"""Beyond-paper perf levers must preserve correctness (function-equivalence
or bounded quantization noise)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.moe import moe_apply, moe_init, moe_ref
from repro.models.transformer import (decode_state_init, model_decode_step,
                                      model_forward, model_init)


def test_grouped_moe_matches_global_and_oracle():
    key = jax.random.PRNGKey(0)
    B, S, D, E, F, K = 2, 32, 16, 8, 32, 2
    p = moe_init(key, D, E, F, K)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D)) * 0.5
    yr, _ = moe_ref(p, x, top_k=K)
    for g in (1, 2, 4, 8):
        y, _ = moe_apply(p, x, top_k=K, capacity_factor=float(E), n_groups=g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                                   err_msg=f"groups={g}")


def test_grouped_moe_tight_capacity_finite():
    key = jax.random.PRNGKey(1)
    p = moe_init(key, 16, 4, 32, 2)
    x = jax.random.normal(key, (2, 32, 16))
    y, aux = moe_apply(p, x, top_k=2, capacity_factor=1.0, n_groups=4)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))


@pytest.mark.slow
def test_int8_kv_cache_decode_close_to_f32():
    cfg = ARCHS["yi-9b"].reduced()
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    ref, _ = model_forward(cfg, params, {"tokens": tokens})
    state = decode_state_init(cfg, 2, 12, kv_dtype="int8")
    outs = []
    for t in range(12):
        lg, state = model_decode_step(cfg, params, state, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.abs(dec - ref).max()) / float(jnp.abs(ref).max())
    assert rel < 0.05, rel        # quantization noise only
    # int8 state is actually half the bytes of the f32 cache
    st8 = decode_state_init(cfg, 2, 12, kv_dtype="int8")
    stf = decode_state_init(cfg, 2, 12)
    b8 = sum(x.size * x.dtype.itemsize
             for x in jax.tree_util.tree_leaves(st8))
    bf = sum(x.size * x.dtype.itemsize
             for x in jax.tree_util.tree_leaves(stf))
    assert b8 < 0.5 * bf


@pytest.mark.slow
def test_int8_kv_jamba_hybrid():
    cfg = dataclasses.replace(ARCHS["jamba-1.5-large-398b"].reduced(),
                              capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    ref, _ = model_forward(cfg, params, {"tokens": tokens})
    state = decode_state_init(cfg, 2, 8, kv_dtype="int8")
    outs = []
    for t in range(8):
        lg, state = model_decode_step(cfg, params, state, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.abs(dec - ref).max()) / float(jnp.abs(ref).max())
    assert rel < 0.05, rel


def test_seq_parallel_tiers_identity_on_cpu():
    """Without an active mesh policy the act-spec variants are no-ops, so
    outputs must be bit-identical."""
    cfg = ARCHS["smollm-135m"].reduced()
    key = jax.random.PRNGKey(0)
    from repro.models.transformer import default_cut_layer
    cut = default_cut_layer(cfg, 0.25)
    params = model_init(cfg, key, cut_layer=cut)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    a, _ = model_forward(cfg, params, {"tokens": tokens}, cut_layer=cut)
    b, _ = model_forward(cfg, params, {"tokens": tokens}, cut_layer=cut,
                         seq_parallel_tiers=("client", "server"))
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
