"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps via hypothesis per the deliverable: for each kernel,
assert_allclose against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.attn.flash import flash_attention
from repro.kernels.attn.ref import flash_attention_ref
from repro.kernels.attn.ops import attention
from repro.kernels.quant.int8 import dequantize_int8, quantize_int8
from repro.kernels.quant.ref import (dequantize_int8_ref, quantize_int8_ref,
                                     roundtrip_error_bound)
from repro.kernels.quant.ops import link_compress, quant_dequant
from repro.kernels.rwkv.ref import rwkv6_scan_ref
from repro.kernels.rwkv.scan import rwkv6_scan


# ---------------------------------------------------------------------------
# int8 quant
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.sampled_from([1, 3, 16, 100, 256]),
       st.sampled_from([128, 384, 512]),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 10**6))
def test_quant_kernel_matches_ref(m, d, dtype, seed):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (m, d)) * 5.0
         ).astype(dtype)
    q, s = quantize_int8(x, interpret=True)
    qr, sr = quantize_int8_ref(x)
    # codes may differ by 1 exactly at .5 rounding boundaries (f32 mul/div
    # association differs between the kernel and the oracle)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= 1
    assert (dq != 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = dequantize_int8(q, s, interpret=True)
    yr = dequantize_int8_ref(qr, sr)
    # dequantized outputs may differ by one code step where codes differed
    bound = np.asarray(s) + 1e-6
    assert (np.abs(np.asarray(y) - np.asarray(yr)) <= bound).all()
    # and dequantizing the SAME codes must match exactly
    y2 = dequantize_int8(qr, sr, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(yr), atol=1e-6)


def test_quant_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 3.0
    y = quant_dequant(x)
    bound = roundtrip_error_bound(x)
    assert bool((jnp.abs(y - x) <= bound + 1e-6).all())


def test_link_compress_straight_through():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    g = jax.grad(lambda t: (link_compress(t) * 2.0).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 2.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(1, 1, 128, 64), (2, 2, 256, 32), (1, 4, 64, 128)]),
       st.booleans(),
       st.sampled_from([None, 32, 100]),
       st.integers(0, 10**6))
def test_flash_matches_ref(shape, causal, window, seed):
    b, h, s, d = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], shape)
    v = jax.random.normal(ks[2], shape)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_bf16():
    shape = (1, 2, 128, 64)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, shape).astype(jnp.bfloat16) for kk in ks)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_attention_wrapper_gqa():
    """ops.attention in model layout with GQA repeat."""
    B, S, H, KH, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    out_pallas = attention(q, k, v, use_pallas=True, interpret=True)
    out_ref = attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_ref),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1, 1, 32, 8), (2, 2, 64, 16), (1, 3, 128, 32)]),
       st.integers(0, 10**6))
def test_rwkv_scan_matches_ref(shape, seed):
    b, h, t, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], shape) * 0.5
    k = jax.random.normal(ks[1], shape) * 0.5
    v = jax.random.normal(ks[2], shape) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], shape))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    y = rwkv6_scan(r, k, v, w, u, block_t=16, interpret=True)
    yr = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_scan_decay_contracts_state():
    """w in (0,1) means old contributions decay: y at late t should not blow
    up (stability property of the Finch recurrence)."""
    b, h, t, hd = 1, 1, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, h, t, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, hd)) * 0.5
    w = jnp.full((b, h, t, hd), 0.5)
    u = jnp.zeros((h, hd))
    y = rwkv6_scan_ref(r, k, v, w, u)
    assert float(jnp.abs(y[:, :, -32:]).max()) < 100.0
