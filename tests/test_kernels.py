"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Differential harness for the kernel layer: hypothesis sweeps over
shape/dtype/causal/sliding-window/GQA plus deterministic parametrized
sweeps (the container image has no hypothesis — those tests skip locally
and run in CI's ``.[dev]`` install; the parametrized rows keep coverage
either way). Gradient parity goes through ``jax.grad`` on BOTH sides:
``flash_attention`` differentiates via its closed-form custom_vjp — a
genuinely distinct computation path from jax's autodiff of the oracle.
Non-block-aligned and degenerate shapes (seq < block, prime M) pin the
pad-to-block handling that replaced the old shrink-toward-1 fallback.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.attn.flash import flash_attention
from repro.kernels.attn.ref import flash_attention_ref
from repro.kernels.attn.ops import attention
from repro.kernels.dispatch import (ATTN_IMPLS, LINK_KERNELS,
                                    resolve_attn_impl, resolve_link_kernel)
from repro.kernels.quant.int8 import (_row_blocks, dequantize_int8,
                                      quant_dequant_int8, quantize_int8)
from repro.kernels.quant.ref import (dequantize_int8_ref, quantize_int8_ref,
                                     roundtrip_error_bound)
from repro.kernels.quant.ops import (link_compress, make_link_compress,
                                     quant_dequant, quant_dequant_residual)
from repro.kernels.rwkv.ref import rwkv6_scan_ref
from repro.kernels.rwkv.scan import rwkv6_scan


# ---------------------------------------------------------------------------
# int8 quant
# ---------------------------------------------------------------------------

def _assert_quant_matches_ref(x):
    q, s = quantize_int8(x, interpret=True)
    qr, sr = quantize_int8_ref(x)
    # codes may differ by 1 exactly at .5 rounding boundaries (f32 mul/div
    # association differs between the kernel and the oracle)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= 1
    assert (dq != 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    y = dequantize_int8(q, s, interpret=True)
    yr = dequantize_int8_ref(qr, sr)
    # dequantized outputs may differ by one code step where codes differed
    bound = np.asarray(s) + 1e-6
    assert (np.abs(np.asarray(y) - np.asarray(yr)) <= bound).all()
    # and dequantizing the SAME codes must match exactly
    y2 = dequantize_int8(qr, sr, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(yr), atol=1e-6)
    # the fused single-kernel roundtrip must stay within one code step too
    # (compare in f32: an out_dtype=bf16 cast would add its own rounding)
    yf = quant_dequant_int8(x, out_dtype=jnp.float32, interpret=True)
    assert (np.abs(np.asarray(yf) - np.asarray(yr)) <= bound).all()


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([1, 3, 16, 100, 256]),
       st.sampled_from([128, 384, 512]),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 10**6))
def test_quant_kernel_matches_ref(m, d, dtype, seed):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (m, d)) * 5.0
         ).astype(dtype)
    _assert_quant_matches_ref(x)


@pytest.mark.parametrize("m,d,dtype", [
    (1, 128, "float32"), (3, 384, "bfloat16"), (100, 512, "float32"),
    (256, 128, "bfloat16"), (509, 128, "float32"),   # 509: prime M > block
    (127, 256, "float32"),                           # prime M < block
])
def test_quant_kernel_matches_ref_param(m, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(m * d), (m, d)) * 5.0
         ).astype(dtype)
    _assert_quant_matches_ref(x)


def test_quant_prime_rows_pad_not_shrink():
    """Regression for the old ``while m % bm: bm //= 2`` fallback: awkward
    M must pad to the block multiple, not degrade the block toward 1."""
    assert _row_blocks(509, 256) == (256, 512)
    assert _row_blocks(127, 256) == (127, 127)   # M < block: one tile
    assert _row_blocks(512, 256) == (256, 512)   # aligned: no padding
    x = jax.random.normal(jax.random.PRNGKey(0), (509, 128)) * 3.0
    _assert_quant_matches_ref(x)


def test_quant_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 3.0
    y = quant_dequant(x)
    bound = roundtrip_error_bound(x)
    assert bool((jnp.abs(y - x) <= bound + 1e-6).all())


@pytest.mark.parametrize("m", [8, 100, 509])
def test_fused_quant_dequant_matches_two_op(m):
    """The fused pallas path of quant_dequant must equal its own two-op
    reference (same f32 math, no HBM int8 round-trip)."""
    x = jax.random.normal(jax.random.PRNGKey(m), (m, 128)) * 4.0
    y_fused = quant_dequant(x, use_pallas=True)
    y_ref = quant_dequant(x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               atol=1e-6)


@pytest.mark.parametrize("m", [8, 100, 509])
def test_fused_residual_epilogue(m):
    """dequant(quant(x)) + residual fused in one kernel == the unfused
    composition, pallas and jnp paths both."""
    kx, kr = jax.random.split(jax.random.PRNGKey(m))
    x = jax.random.normal(kx, (m, 128)) * 4.0
    r = jax.random.normal(kr, (m, 128))
    want = quant_dequant(x) + r
    for use_pallas in (True, False):
        got = quant_dequant_residual(x, r, use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_link_compress_straight_through(use_pallas):
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    lc = (make_link_compress(use_pallas=True, interpret=True) if use_pallas
          else link_compress)
    g = jax.grad(lambda t: (lc(t) * 2.0).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 2.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _assert_flash_matches_ref(shape, causal, window, seed, *, block_q=64,
                              block_k=64, grad=False, kv_shape=None,
                              atol=2e-5, gatol=2e-4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], kv_shape or shape)
    v = jax.random.normal(ks[2], kv_shape or shape)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=atol)
    if grad:
        def loss(fn):
            def f(q, k, v):
                o = fn(q, k, v)
                return (o * jnp.cos(o)).sum()   # non-trivial cotangent
            return f
        g = jax.grad(loss(lambda q, k, v: flash_attention(
            q, k, v, causal=causal, window=window, block_q=block_q,
            block_k=block_k, interpret=True)), argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss(lambda q, k, v: flash_attention_ref(
            q, k, v, causal=causal, window=window)), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=gatol)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(1, 1, 128, 64), (2, 2, 256, 32), (1, 4, 64, 128)]),
       st.booleans(),
       st.sampled_from([None, 32, 100]),
       st.integers(0, 10**6))
def test_flash_matches_ref(shape, causal, window, seed):
    _assert_flash_matches_ref(shape, causal, window, seed)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1, 1, 64, 32), (2, 2, 96, 32)]),
       st.booleans(),
       st.sampled_from([None, 16]),
       st.integers(0, 10**6))
def test_flash_grad_matches_ref(shape, causal, window, seed):
    _assert_flash_matches_ref(shape, causal, window, seed, block_q=32,
                              block_k=32, grad=True)


@pytest.mark.parametrize("shape,causal,window", [
    ((1, 1, 128, 64), True, None),
    ((2, 2, 256, 32), True, 32),
    ((1, 4, 64, 128), False, None),
    ((2, 2, 96, 32), False, 16),
])
def test_flash_matches_ref_param(shape, causal, window):
    _assert_flash_matches_ref(shape, causal, window, seed=0, grad=True)


@pytest.mark.parametrize("s,block,causal,window", [
    (100, 64, True, None),    # non-block-aligned: pad 100 -> 128
    (131, 64, True, 32),      # prime S > block, sliding window
    (257, 64, False, None),   # prime S, bidirectional (padded kv masked)
    (7, 64, True, None),      # degenerate: seq < block (single tile)
    (1, 64, True, None),      # single position
])
def test_flash_non_aligned_shapes(s, block, causal, window):
    """Padding path: Q rows pad + slice, padded KV positions masked with
    kv_len — never the old shrink-toward-bq=1 fallback."""
    _assert_flash_matches_ref((2, 2, s, 32), causal, window, seed=3,
                              block_q=block, block_k=block, grad=True)


def test_flash_bf16():
    shape = (1, 2, 128, 64)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (jax.random.normal(kk, shape).astype(jnp.bfloat16) for kk in ks)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


@pytest.mark.parametrize("h,kh", [(4, 2), (4, 1), (2, 2)])
def test_attention_wrapper_gqa(h, kh):
    """ops.attention in model layout with GQA repeat, fwd + grad."""
    B, S, D = 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, h, D))
    k = jax.random.normal(ks[1], (B, S, kh, D))
    v = jax.random.normal(ks[2], (B, S, kh, D))
    out_pallas = attention(q, k, v, use_pallas=True, interpret=True)
    out_ref = attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_pallas), np.asarray(out_ref),
                               atol=2e-5)
    gp = jax.grad(lambda q: attention(q, k, v, use_pallas=True,
                                      interpret=True).sum())(q)
    gr = jax.grad(lambda q: attention(q, k, v, use_pallas=False).sum())(q)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=2e-4)


def test_flash_vmaps():
    """The fleet engines vmap the split step over clients; the pallas call
    must batch (pallas has a vmap rule)."""
    shape = (3, 2, 2, 64, 32)   # (clients, B, H, S, D)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, shape) for kk in ks)
    f = lambda q, k, v: flash_attention(q, k, v, block_q=32, block_k=32,
                                        interpret=True)
    out = jax.vmap(f)(q, k, v)
    ref = jax.vmap(lambda q, k, v: flash_attention_ref(q, k, v))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# dispatch resolution
# ---------------------------------------------------------------------------

def test_dispatch_resolution_cpu():
    assert resolve_attn_impl("xla") == "xla"
    assert resolve_attn_impl("pallas") == "pallas"
    assert resolve_attn_impl("ref") == "ref"
    # "auto" resolves to a concrete impl, never itself
    assert resolve_attn_impl("auto") in ("xla", "pallas")
    assert resolve_link_kernel("xla")[0] is False
    assert resolve_link_kernel("fused")[0] is True
    assert isinstance(resolve_link_kernel("auto")[0], bool)
    with pytest.raises(ValueError):
        resolve_attn_impl("cuda")
    with pytest.raises(ValueError):
        resolve_link_kernel("fp8")
    assert "fused" in LINK_KERNELS


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1, 1, 32, 8), (2, 2, 64, 16), (1, 3, 128, 32)]),
       st.integers(0, 10**6))
def test_rwkv_scan_matches_ref(shape, seed):
    b, h, t, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], shape) * 0.5
    k = jax.random.normal(ks[1], shape) * 0.5
    v = jax.random.normal(ks[2], shape) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], shape))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    y = rwkv6_scan(r, k, v, w, u, block_t=16, interpret=True)
    yr = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_scan_decay_contracts_state():
    """w in (0,1) means old contributions decay: y at late t should not blow
    up (stability property of the Finch recurrence)."""
    b, h, t, hd = 1, 1, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, h, t, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, hd)) * 0.5
    w = jnp.full((b, h, t, hd), 0.5)
    u = jnp.zeros((h, hd))
    y = rwkv6_scan_ref(r, k, v, w, u)
    assert float(jnp.abs(y[:, :, -32:]).max()) < 100.0
