"""Device-resident multi-client engine — regression vs the sequential
reference.

The scanned engine (``make_fl_round`` / ``make_multi_client_round``) must be
numerically equivalent to the plain per-client Python loops it replaced, and
the trainers' energy accounting must come from *symmetric* FLOP counting
(XLA-counted fwd+bwd on both tiers for both pipelines).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import compile_experiment
from repro.core.fedavg import fedavg, fedavg_stack
from repro.core.paper_train import (PaperTrainConfig, count_fl_step_flops,
                                    count_sl_step_flops, paper_spec)
from repro.core.split import (SplitStep, apply_stages, init_stages,
                              make_fl_round, make_multi_client_round,
                              partition_stages)
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
from repro.optim import adamw, apply_updates, init_stacked

C, S, B = 3, 2, 4          # clients, local steps, batch
NUM_CLASSES = 4


@pytest.fixture(scope="module")
def tiny_setup():
    stages = CNN_BUILDERS["tinycnn"](NUM_CLASSES)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    bx = jax.random.uniform(jax.random.fold_in(key, 1), (C, S, B, 16, 16, 3))
    by = jax.random.randint(jax.random.fold_in(key, 2), (C, S, B), 0,
                            NUM_CLASSES)
    return stages, params, bx, by


def _assert_trees_close(a, b, atol=1e-4):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32), atol=atol)


def test_fl_round_matches_sequential(tiny_setup):
    """One scanned FL global round == the per-client Python-loop reference."""
    stages, params, bx, by = tiny_setup
    opt = adamw(1e-3)

    def grad_fn(p, batch):
        xx, yy = batch
        return jax.value_and_grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, xx), yy))(p)

    new_params, losses = jax.jit(make_fl_round(grad_fn, opt))(params, (bx, by))
    assert losses.shape == (C, S)

    # sequential reference: the seed's host loop
    step = jax.jit(lambda p, o, xx, yy: _fl_step(grad_fn, opt, p, o, xx, yy))
    client_models, ref_losses = [], []
    for ci in range(C):
        p = jax.tree_util.tree_map(jnp.copy, params)
        o = opt.init(p)
        for si in range(S):
            p, o, loss = step(p, o, bx[ci, si], by[ci, si])
            ref_losses.append(float(loss))
        client_models.append(p)
    ref_params = fedavg(client_models)

    np.testing.assert_allclose(np.asarray(losses).ravel(),
                               np.asarray(ref_losses), atol=1e-4)
    _assert_trees_close(new_params, ref_params)


def _fl_step(grad_fn, opt, p, o, xx, yy):
    loss, g = grad_fn(p, (xx, yy))
    up, o = opt.update(g, o, p)
    return apply_updates(p, up), o, loss


def test_sl_round_matches_sequential(tiny_setup):
    """One scanned Alg. 3 global round == the seed's step-major host loop
    (sequential server updates per client batch, FedAvg of prefixes)."""
    stages, params, bx, by = tiny_setup
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    engine = jax.jit(make_multi_client_round(step, opt_c, opt_s,
                                             local_rounds=S))
    client_stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), cp0)
    oc_stack = init_stacked(opt_c, cp0, C)
    out_stack, out_sp, _, _, losses = engine(
        client_stack, sp, oc_stack, opt_s.init(sp),
        {"inputs": bx, "targets": by})
    assert losses.shape == (S, C)

    # sequential reference: the seed's host loop (step-major client visits)
    @jax.jit
    def split_step(cp, cop, spar, sop, xx, yy):
        loss, _, gc, gs = step.grads(cp, spar, {"inputs": xx, "targets": yy})
        upc, cop = opt_c.update(gc, cop, cp)
        ups, sop = opt_s.update(gs, sop, spar)
        return apply_updates(cp, upc), cop, apply_updates(spar, ups), sop, loss

    cps = [jax.tree_util.tree_map(jnp.copy, cp0) for _ in range(C)]
    cops = [opt_c.init(cp0) for _ in range(C)]
    spar, sop = sp, opt_s.init(sp)
    ref_losses = np.zeros((S, C))
    for si in range(S):
        for ci in range(C):
            cps[ci], cops[ci], spar, sop, loss = split_step(
                cps[ci], cops[ci], spar, sop, bx[ci, si], by[ci, si])
            ref_losses[si, ci] = float(loss)
    ref_stack = fedavg_stack(jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *cps))

    np.testing.assert_allclose(np.asarray(losses), ref_losses, atol=1e-4)
    _assert_trees_close(out_stack, ref_stack)
    _assert_trees_close(out_sp, spar)


def test_symmetric_flop_accounting(tiny_setup):
    """SL's client+server per-step FLOPs are counted with the same
    methodology as FL's full step: their sum must be close to the full
    fwd+bwd count, and each tier strictly positive (never a silent 0)."""
    stages, params, bx, by = tiny_setup
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
    full = count_fl_step_flops(stages, params, bx[0, 0], by[0, 0])
    client_fl, server_fl, smashed = count_sl_step_flops(
        cs, cp0, ss, sp, bx[0, 0], by[0, 0])
    assert full > 0 and client_fl > 0 and server_fl > 0
    assert smashed.shape[0] == B
    # split-step total ~ full-model total (cut gradient work double-counts
    # only the cut boundary, a small slice of the whole)
    assert 0.5 * full < client_fl + server_fl < 1.5 * full


def test_paper_spec_energy_ratio_and_records():
    """End-to-end via the spec layer (``paper_spec`` — the mapping the
    dropped ``train_fl``/``train_sl`` shims used): both pipelines run on
    the tiny backbone and a shallow split spends less client energy than
    FL under the symmetric accounting (the paper's headline direction)."""
    rng = np.random.RandomState(0)
    n = 96
    x = rng.uniform(0, 1, size=(n, 16, 16, 3)).astype(np.float32)
    y = rng.randint(0, 12, size=(n,))
    cfg = PaperTrainConfig(model="tinycnn", num_clients=3, global_rounds=2,
                           local_steps=2, batch_size=4, image_size=16,
                           client_fraction=0.4)
    data = (x, y, x[:24], y[:24])
    plan_fl = compile_experiment(paper_spec(cfg, "fl"), data=data)
    plan_sl = compile_experiment(paper_spec(cfg, "sl"), data=data)
    _, rec_fl = plan_fl.run()
    _, rec_sl = plan_sl.run()
    assert len(rec_fl) == len(rec_sl) == cfg.global_rounds

    # symmetric accounting: the SL client runs a strict subset of the FL
    # client's per-step work, so its energy must be strictly smaller
    k = plan_sl.cut_of_client[0]
    client_flops, server_flops, _smashed = plan_sl.flops[k]
    assert client_flops > 0 and server_flops > 0
    assert client_flops < plan_fl.flops["full"]
    assert (sum(r.client_energy_j for r in rec_sl)
            < sum(r.client_energy_j for r in rec_fl))
    assert sum(r.link_bytes for r in rec_sl) > 0
    assert sum(r.link_bytes for r in rec_fl) == 0
