"""Algorithm 2 (UAV tour planning) — exactness + energy accounting."""
import itertools
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.trajectory import (greedy_tour_plan, held_karp,
                                   nearest_neighbor_tour, plan_tour,
                                   solve_tsp, two_opt)
from repro.core.uav_energy import DEFAULT_UAV, UAVParams


def brute_force_tsp(points):
    m = len(points)
    d = np.linalg.norm(points[:, None] - points[None], axis=-1)
    best = None
    for perm in itertools.permutations(range(1, m)):
        order = (0,) + perm
        length = sum(d[order[i], order[(i + 1) % m]] for i in range(m))
        if best is None or length < best:
            best = length
    return best


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 7), st.integers(0, 10**6))
def test_held_karp_is_exact(m, seed):
    rng = np.random.RandomState(seed)
    pts = rng.uniform(0, 1000, size=(m, 2))
    _, hk = held_karp(pts)
    bf = brute_force_tsp(pts)
    assert abs(hk - bf) < 1e-6 * max(bf, 1.0)


def test_exact_beats_greedy():
    rng = np.random.RandomState(0)
    worse = 0
    for seed in range(20):
        pts = np.random.RandomState(seed).uniform(0, 1000, size=(8, 2))
        _, hk = held_karp(pts)
        _, nn = nearest_neighbor_tour(pts)
        assert hk <= nn + 1e-9
        worse += nn > hk + 1e-6
    assert worse > 0  # greedy is strictly worse somewhere


def test_tour_visits_all_once():
    pts = np.random.RandomState(1).uniform(0, 500, size=(9, 2))
    order, _ = solve_tsp(pts)
    assert sorted(order) == list(range(9))


def test_plan_tour_rounds_budget():
    """gamma maximal subject to Eq. (5)-(6) with the delayed-return check."""
    pts = np.random.RandomState(2).uniform(0, 600, size=(5, 2))
    base = np.zeros(2)
    plan = plan_tour(pts, base)
    assert plan.rounds >= 1
    # consumed energy within budget
    assert plan.total_energy <= DEFAULT_UAV.beta + 1e-6
    # one more round would bust the budget
    overspend = plan.total_energy + plan.e_per_round
    assert overspend > DEFAULT_UAV.beta


def test_zero_rounds_when_budget_too_small():
    pts = np.random.RandomState(3).uniform(0, 5000, size=(6, 2))
    tiny = UAVParams(beta=1e3)
    plan = plan_tour(pts, np.zeros(2), params=tiny)
    assert plan.rounds == 0


def test_exact_plan_beats_greedy_plan():
    pts = np.random.RandomState(4).uniform(0, 2000, size=(9, 2))
    base = np.zeros(2)
    exact = plan_tour(pts, base)
    greedy = greedy_tour_plan(pts, base)
    assert exact.tour_length <= greedy.tour_length + 1e-9
    assert exact.rounds >= greedy.rounds


def test_two_opt_no_worse():
    pts = np.random.RandomState(5).uniform(0, 1000, size=(20, 2))
    order, nn_len = nearest_neighbor_tour(pts)
    _, opt_len = two_opt(pts, order)
    assert opt_len <= nn_len + 1e-9


def test_held_karp_exact_at_eight():
    """Deterministic pin of the exact solver at the paper's fleet scale
    (complements the hypothesis property, which may be skipped)."""
    for seed in range(4):
        pts = np.random.RandomState(seed).uniform(0, 1000, size=(8, 2))
        _, hk = held_karp(pts)
        bf = brute_force_tsp(pts)
        assert abs(hk - bf) < 1e-6 * max(bf, 1.0)


def test_fallback_beyond_exact_limit():
    """solve_tsp's M>16 NN+2opt fallback: a valid cycle whose reported
    length is true, never longer than ANY single-start greedy tour."""
    for m, seed in ((17, 0), (18, 3), (20, 7), (24, 11), (40, 2)):
        pts = np.random.RandomState(seed).uniform(0, 1000, size=(m, 2))
        order, length = solve_tsp(pts)
        assert sorted(order) == list(range(m))
        d = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
        true = sum(d[order[i], order[(i + 1) % m]] for i in range(m))
        assert abs(true - length) < 1e-9
        for start in range(m):
            _, nn_len = nearest_neighbor_tour(pts, start=start)
            assert length <= nn_len + 1e-9


def test_two_opt_never_longer_than_greedy_fleetwide():
    """2-opt over the greedy seed is monotone at every scale the mission
    planner can hit (small farms through M>16 fallback territory)."""
    improved_somewhere = False
    for m in (6, 10, 17, 25, 33):
        for seed in range(5):
            pts = np.random.RandomState(1000 + 31 * m + seed).uniform(
                0, 800, size=(m, 2))
            order, nn_len = nearest_neighbor_tour(pts)
            o2, l2 = two_opt(pts, order)
            assert sorted(o2) == list(range(m))
            assert l2 <= nn_len + 1e-9
            improved_somewhere |= l2 < nn_len - 1e-6
    assert improved_somewhere


def test_plan_tour_uses_fallback_past_exact_limit():
    pts = np.random.RandomState(9).uniform(0, 2000, size=(18, 2))
    plan = plan_tour(pts, np.zeros(2))
    assert sorted(plan.order) == list(range(18))
    assert plan.rounds >= 1
    assert plan.total_energy <= DEFAULT_UAV.beta + 1e-6
    # the multi-start seeded fallback is at least as good as the greedy plan
    greedy = greedy_tour_plan(pts, np.zeros(2))
    assert plan.tour_length <= greedy.tour_length + 1e-9
