"""Launch layer: sharding specs, step builders, and a miniature dry-run.

The miniature dry-run runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
initializes (the main test process keeps its 1 real device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES
from repro.launch.mesh import abstract_mesh as _abstract_mesh
from repro.launch.steps import (batch_sds, effective_window, shape_supported,
                                tier_fn_for)
from repro.models.transformer import default_cut_layer, model_init
from repro.parallel.sharding import param_pspecs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_pspecs_rules():
    cfg = ARCHS["yi-9b"]
    params = jax.eval_shape(lambda k: model_init(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = param_pspecs(params, mesh)
    # embed: vocab over model, d_model over data
    assert specs["embed"]["table"] == P("model", "data")
    g0 = specs["groups"][0]
    # stacked layer axis replicated; col-parallel q
    assert g0["attn"]["wq"]["w"] == P(None, "data", "model")
    assert g0["attn"]["wo"]["w"] == P(None, "model", "data")
    assert g0["ffn"]["gate"]["w"] == P(None, "data", "model")
    assert g0["ffn"]["down"]["w"] == P(None, "model", "data")
    assert g0["ln1"]["scale"] == P()


def test_param_pspecs_client_tier_no_tp():
    cfg = ARCHS["yi-9b"]
    cut = default_cut_layer(cfg, 0.25)
    params = jax.eval_shape(lambda k: model_init(cfg, k, cut_layer=cut),
                            jax.random.PRNGKey(0))
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = param_pspecs(params, mesh, tier_fn=tier_fn_for(cfg, cut))
    client = specs["groups"][0]
    server = specs["groups"][1]
    # client tier: NO 'model' axis anywhere (edge devices can't do TP)
    for leaf in jax.tree_util.tree_leaves(
            client, is_leaf=lambda s: isinstance(s, P)):
        assert "model" not in [a for a in leaf if a]
    assert server["attn"]["wq"]["w"] == P(None, "data", "model")


def test_divisibility_guard():
    cfg = ARCHS["whisper-tiny"]  # d_model=384: 384/16=24 ok; heads 6 not
    params = jax.eval_shape(lambda k: model_init(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = param_pspecs(params, mesh)
    # vocab padded to 51872 => divisible; embed sharded
    assert specs["embed"]["table"] == P("model", "data")


def test_moe_expert_parallel_specs():
    cfg = ARCHS["deepseek-moe-16b"]
    params = jax.eval_shape(lambda k: model_init(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = param_pspecs(params, mesh)
    moe_group = specs["groups"][1]
    assert moe_group["moe"]["w_gate"] == P(None, "model", "data", None)
    assert moe_group["moe"]["w_down"] == P(None, "model", "data", None)


def test_effective_window_variants():
    assert effective_window(ARCHS["yi-9b"], INPUT_SHAPES["train_4k"]) is None
    assert effective_window(ARCHS["yi-9b"], INPUT_SHAPES["long_500k"]) == 8192
    assert effective_window(ARCHS["h2o-danube-1.8b"],
                            INPUT_SHAPES["train_4k"]) == 4096


def test_shape_support_matrix():
    ok, _ = shape_supported(ARCHS["whisper-tiny"], INPUT_SHAPES["long_500k"])
    assert not ok
    for arch in ARCHS.values():
        for shape in INPUT_SHAPES.values():
            if arch.name == "whisper-tiny" and shape.name == "long_500k":
                continue
            ok, why = shape_supported(arch, shape)
            assert ok, (arch.name, shape.name, why)


def test_batch_sds_shapes():
    d = batch_sds(ARCHS["pixtral-12b"], INPUT_SHAPES["train_4k"],
                  with_labels=True)
    n_text = 4096 - ARCHS["pixtral-12b"].frontend_tokens
    assert d["tokens"].shape == (256, n_text)
    assert d["patch_embeds"].shape == (256, 1024, 5120)


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """8-device miniature of the production dry-run (2x4 mesh analogue):
    lower+compile a train step for the reduced smollm on a (2,4) mesh."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro.configs import ARCHS
        from repro.launch.steps import build_step
        import dataclasses
        cfg = dataclasses.replace(
            ARCHS["smollm-135m"].reduced(), vocab=512, d_model=256, d_ff=512)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        import repro.configs.base as base
        shape = base.InputShape("mini", 64, 8, "train")
        import repro.launch.steps as steps
        built = steps.build_train_step(cfg, shape, mesh)
        with mesh:
            comp = jax.jit(built.fn, in_shardings=built.in_shardings,
                           out_shardings=built.out_shardings
                           ).lower(*built.args_sds).compile()
        from repro.core.flops import compiled_cost
        print(json.dumps({"flops": float(compiled_cost(comp).get("flops", -1))}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
