"""Substrate: optimizers, schedules, checkpointing, data, energy, link."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.energy import (CO2_G_PER_J, EnergyTracker, JETSON_AGX_ORIN,
                               RTX_A5000, TPU_V5E, roofline_time, scale_time)
from repro.core.link import LinkConfig, smashed_bytes
from repro.data.partition import partition_dirichlet, partition_non_iid
from repro.data.synthetic import SyntheticPestImages, synthetic_tokens
from repro.data.pipeline import BatchIterator
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         cosine_schedule, sgd, warmup_cosine)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_first_step_is_lr_sized():
    """After one step, |update| ~ lr regardless of grad scale (Adam)."""
    opt = adamw(1e-2, weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 123.0)}
    st_ = opt.init(p)
    up, _ = opt.update(g, st_, p)
    np.testing.assert_allclose(np.asarray(jnp.abs(up["w"])), 1e-2, rtol=1e-3)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    p = {"w": jnp.asarray(5.0)}
    st_ = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: (q["w"] - 2.0) ** 2)(p)
        up, st_ = opt.update(g, st_, p)
        p = apply_updates(p, up)
    assert abs(float(p["w"]) - 2.0) < 0.05


def test_sgd_momentum_converges():
    opt = sgd(0.05, momentum=0.9)
    p = {"w": jnp.asarray(-3.0)}
    st_ = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: (q["w"] - 1.0) ** 2)(p)
        up, st_ = opt.update(g, st_, p)
        p = apply_updates(p, up)
    assert abs(float(p["w"]) - 1.0) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                         jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(700.0), rel=1e-5)


def test_schedules():
    sc = cosine_schedule(1.0, 100)
    assert float(sc(0)) == pytest.approx(1.0)
    assert float(sc(100)) == pytest.approx(0.0, abs=1e-6)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(5)) == pytest.approx(0.5)
    assert float(wc(10)) == pytest.approx(1.0, rel=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, tree, meta={"step": 7})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    back = restore_checkpoint(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_non_iid_partition_paper_setting():
    """Paper: 4 clients x 3 classes each."""
    labels = np.repeat(np.arange(12), 50)
    parts = partition_non_iid(labels, 4, 3, num_classes=12)
    assert len(parts) == 4
    covered = set()
    for idx in parts:
        cls = set(labels[idx])
        assert len(cls) == 3
        covered |= cls
    assert covered == set(range(12))


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 8), st.floats(0.1, 5.0), st.integers(0, 10**6))
def test_dirichlet_partition_property(nc, alpha, seed):
    labels = np.random.RandomState(seed).randint(0, 10, size=500)
    parts = partition_dirichlet(labels, nc, alpha=alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(set(allidx.tolist())) == len(labels)  # a partition


def test_synthetic_images_learnable_structure():
    gen = SyntheticPestImages(image_size=32)
    x, y = gen.dataset(128)
    assert x.shape == (128, 32, 32, 3)
    assert int(y.max()) < 12
    # class-conditional means differ (signal exists)
    m0 = x[y == int(y[0])].mean()
    m_all = x.mean()
    assert x.std() > 0.05


def test_batch_iterator_drops_and_shuffles():
    xs = np.arange(103)
    it = BatchIterator((xs,), 10, seed=0)
    batches = list(it)
    assert len(batches) == 10
    seen = np.concatenate([b[0] for b in batches])
    assert len(set(seen.tolist())) == 100


def test_synthetic_tokens_copy_structure():
    toks = synthetic_tokens(jax.random.PRNGKey(0), 4, 256, 1000)
    assert toks.shape == (4, 256)
    rolled = jnp.roll(toks, 16, axis=1)
    frac = float((toks[:, 16:] == rolled[:, 16:]).mean())
    assert frac > 0.4  # periodic copy structure present


# ---------------------------------------------------------------------------
# energy model (paper Eq. 9) + link (Eq. 8)
# ---------------------------------------------------------------------------

def test_eq9_scaling_identity():
    assert scale_time(1.0, RTX_A5000, RTX_A5000) == pytest.approx(1.0)


def test_eq9_scaling_a5000_to_jetson():
    """Scaling to the weaker device must inflate time substantially —
    the paper's Table III rests on this."""
    t = scale_time(1.0, RTX_A5000, JETSON_AGX_ORIN)
    # (27.8/2.7)^1 * (768/51.2)^0.5 * (216/21.6)^0.8 * (35000/2500)^0.3
    expected = (27.8 / 2.7) * (768 / 51.2) ** 0.5 * 10 ** 0.8 * 14 ** 0.3
    assert t == pytest.approx(expected, rel=1e-6)
    assert t > 100


def test_roofline_time_regimes():
    hw = TPU_V5E
    # compute-bound: many flops, few bytes
    t_c = roofline_time(1e15, 1e6, hw)
    assert t_c == pytest.approx(1e15 / (hw.tensor_tflops * 1e12))
    # memory-bound
    t_m = roofline_time(1e6, 1e12, hw)
    assert t_m == pytest.approx(1e12 / (hw.mem_bw_gbs * 1e9))


def test_energy_tracker_accumulates():
    tr = EnergyTracker(JETSON_AGX_ORIN)
    tr.track("client/fwd", flops=1e12, bytes_moved=1e9)
    tr.track("client/bwd", flops=2e12, bytes_moved=2e9)
    tr.track("server/fwd", flops=1e13, bytes_moved=1e9)
    tot = tr.total()
    assert tot.time_s > 0
    assert tot.energy_j == pytest.approx(tot.time_s * JETSON_AGX_ORIN.power_w)
    assert tot.co2_g == pytest.approx(tot.energy_j * CO2_G_PER_J)
    c = tr.by_prefix("client/")
    assert c.time_s < tot.time_s


def test_link_eq8_and_compression():
    lk = LinkConfig(rate_bps=100e6)
    nbytes = smashed_bytes(4, 128, 128, dtype_bytes=4)
    t = lk.transfer_time_s(nbytes)
    assert t == pytest.approx(8 * nbytes / 100e6)
    lk8 = LinkConfig(rate_bps=100e6, compress="int8")
    assert lk8.transfer_time_s(nbytes) < t / 3.5  # ~4x compression
