"""Dry-run utilities: HLO collective parser + shape-byte accounting."""
from repro.launch.dryrun import _shape_bytes, collective_bytes

HLO_SAMPLE = """
HloModule jit_step
%all-gather.202 = f32[1536,576]{0,1} all-gather(%convert), channel_id=14
  %all-reduce.204 = f32[16,4096,576]{2,1,0} all-reduce(%fusion), channel_id=22
%fusion.9 = f32[16,4096,576]{2,1,0} fusion(%all-reduce.204, %copy.647)
%collective-permute.136 = bf16[1536,36]{0,1} collective-permute(%bitcast)
%all-gather-start.5 = (f32[8,2]{1,0}, f32[16,2]{1,0}) all-gather-start(%p0)
%all-gather-done.5 = f32[16,2]{1,0} all-gather-done(%all-gather-start.5)
%reduce-scatter.1 = bf16[64,64]{1,0} reduce-scatter(%x), dimensions={0}
%all-to-all.3 = s8[128]{0} all-to-all(%y)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[1536,576]") == 1536 * 576 * 4
    assert _shape_bytes("bf16[2,3]{1,0}") == 12
    assert _shape_bytes("(f32[8,2]{1,0}, f32[16,2]{1,0})") == (16 + 32) * 4
    assert _shape_bytes("pred[]") == 1          # scalar
    assert _shape_bytes("token[]") == 0         # unknown type ignored


def test_collective_parser_counts_and_bytes():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"]["count"] == 2      # plain + -start (not -done)
    assert out["all-gather"]["bytes"] == 1536 * 576 * 4 + (16 + 32) * 4
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 16 * 4096 * 576 * 4
    assert out["collective-permute"]["count"] == 1
    assert out["reduce-scatter"]["count"] == 1
    assert out["all-to-all"]["count"] == 1
    assert out["all-to-all"]["bytes"] == 128
    # the fusion line referencing %all-reduce.204 must NOT be counted
    assert out["total_bytes"] == sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))


def test_roofline_param_count_sanity():
    import os, sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from roofline import param_count
    from repro.configs import ARCHS
    n, na = param_count(ARCHS["smollm-135m"])
    assert 100e6 < n < 200e6          # "135M"
    n, na = param_count(ARCHS["yi-9b"])
    assert 7e9 < n < 11e9
    n, na = param_count(ARCHS["arctic-480b"])
    assert 350e9 < n < 600e9
    assert na < n / 10                # top-2 of 128 experts: sparse
    n, na = param_count(ARCHS["jamba-1.5-large-398b"])
    assert 250e9 < n < 500e9
    assert na < n / 2
