"""Degrade gracefully when ``hypothesis`` is not installed.

The container image does not ship hypothesis; property-based tests must
*skip* instead of killing collection of their whole module (the plain
unit tests in the same files still run). Modules do::

    from _hypothesis_compat import given, settings, st

With hypothesis installed (the CI dev extra: ``pip install -e .[dev]``),
these are the real objects. Without it, ``given`` replaces the test with
a zero-argument stub carrying the same skip that ``pytest.importorskip``
would produce, and ``st``'s strategy constructors return inert
placeholders that are only ever passed to that stub.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="could not import 'hypothesis'")
            def stub():  # zero-arg: strategy params must not look like fixtures
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate

    class _InertStrategies:
        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            strategy.__name__ = name
            return strategy

    st = _InertStrategies()
