"""The in-graph metrics bus (``repro.obs.metrics``) — PR acceptance gates.

The contract under test:

  * metrics-off plans are untouched: a plan compiled without a
    ``MetricsConfig`` lowers to the BYTE-identical round program (canonical
    jaxpr comparison through the same audit handles ``repro_lint --jaxpr``
    traces), and metrics-on runs reproduce every non-metrics
    ``RoundRecord`` field bitwise across fl/sl x scan/vmap/shard_map, the
    EPSL shared cohort tier, the degenerate population corner, and hetero
    buckets;
  * taps ride the round's own scan outputs — enabling the default tap set
    costs < 3% wall on a measured 20-round run (interleaved A/B, same
    estimator as ``test_obs_overhead_under_2pct``);
  * the NaN guard localizes an injected nonfinite batch to its exact
    (round, step, client slot) on every engine variant, recording under
    ``health/*`` or raising :class:`NonfiniteError` per policy;
  * Monte-Carlo sweeps stack taps per seed: seed 0 of a ``seed=0`` sweep
    replays ``plan.run()``'s metric stream (health/mask keys exactly;
    float taps within the same rtol=2e-5 the loss replay pin uses), and
    ``summary()`` reports across-seed tap spread;
  * the JSONL sink carries the round summaries as ``metrics`` events,
    rendered by ``tools/obs_report.py`` (tap sparklines, health table,
    ``--health-gate``, ``--compare``), and ``benchmarks/report.py
    --compact`` prunes the perf log the CI artifact uploads.
"""
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, ModelSpec,
                       compile_experiment)
from repro.obs import NULL_OBS, ObsConfig
from repro.obs.metrics import (MetricsConfig, NonfiniteError, TAPS,
                               engine_tap_names, first_nonfinite_coord,
                               summarize_round_metrics)
from repro.obs.timeline import fenced

NUM_CLASSES = 4

BASE = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
    data=DataSpec(kind="synthetic", image_size=12, classes_per_client=2,
                  n_train=32, n_test=16),
    clients=ClientSpec(num_clients=3),
    cut_policy=CutPolicy(mode="fraction", fraction=0.4),
    engine=EngineSpec(kind="sl", client_axis="vmap"),
    global_rounds=2, local_steps=3, batch_size=4, seed=0)

ENGINES = [("fl", "scan"), ("fl", "vmap"), ("fl", "shard_map"),
           ("sl", "scan"), ("sl", "vmap"), ("sl", "shard_map")]

# every RoundRecord field that must stay bitwise identical metrics-on vs
# metrics-off (i.e. everything except `metrics` itself)
NON_METRICS_FIELDS = ("round", "loss", "accuracy", "link_bytes",
                      "link_time_s", "link_energy_j", "client_energy_j",
                      "server_energy_j", "uav_energy_j", "client_time_s",
                      "server_time_s", "active_clients", "engine",
                      "cohort_pids")


def _metrics_obs(**kw):
    return ObsConfig(enabled=False, metrics=MetricsConfig(**kw))


def _engine_spec(kind, axis, **kw):
    return dataclasses.replace(
        BASE, engine=EngineSpec(kind=kind, client_axis=axis), **kw)


def _poison(batches, client, step):
    """The round's own batch stack with NaN planted at one
    (client slot, local step) — both engine batch formats."""
    if isinstance(batches, dict):                      # SL
        bx = np.asarray(batches["inputs"]).copy()
        bx[client, step] = np.nan
        return {"inputs": jnp.asarray(bx), "targets": batches["targets"]}
    bx, by = batches                                   # FL
    bx = np.asarray(bx).copy()
    bx[client, step] = np.nan
    return jnp.asarray(bx), by


# ---------------------------------------------------------------------------
# config + pure helpers
# ---------------------------------------------------------------------------

def test_metrics_config_validation():
    assert MetricsConfig().taps == TAPS
    with pytest.raises(ValueError, match="unknown metrics taps"):
        MetricsConfig(taps=("grad_norms", "nope"))
    with pytest.raises(ValueError, match="on_nonfinite"):
        MetricsConfig(on_nonfinite="explode")


def test_engine_tap_names_resolution():
    cfg = MetricsConfig()
    sl = engine_tap_names(cfg, kind="sl", has_link=True)
    assert "quant_error" in sl and "grad_norm_server" in sl
    sl_fp32 = engine_tap_names(cfg, kind="sl", has_link=False)
    assert "quant_error" not in sl_fp32
    fl = engine_tap_names(cfg, kind="fl", has_link=False)
    # FL has no server tier and no link boundary
    assert fl == ("grad_norm_client", "update_norm_client", "nonfinite")
    assert engine_tap_names(None, kind="sl", has_link=True) == ()
    # host-only taps lower nothing in-graph
    host_only = MetricsConfig(taps=("loss_spread", "mask"), nan_guard=False)
    assert engine_tap_names(host_only, kind="sl", has_link=True) == ()


def test_first_nonfinite_coord_layouts():
    # SL layout (steps, clients) passes through; FL (clients, steps) is
    # transposed to time-major before the argwhere
    sl = np.zeros((3, 2), np.float32)
    sl[2, 1] = 1.0
    assert first_nonfinite_coord(sl, "sl") == (2, 1, 1)
    fl = np.zeros((2, 3), np.float32)                  # (clients, steps)
    fl[1, 2] = 1.0
    assert first_nonfinite_coord(fl, "fl") == (2, 1, 1)
    assert first_nonfinite_coord(np.zeros((3, 2)), "sl") is None


def test_summarize_round_metrics_is_pure_numpy():
    cfg = MetricsConfig()
    taps = {"grad_norm_client": np.array([[1.0, 3.0], [2.0, 4.0]]),
            "nonfinite": np.zeros((2, 2), np.float32)}
    losses = np.array([[1.0, 2.0], [1.5, 2.5]])
    out = summarize_round_metrics(cfg, taps, losses=losses, kind="sl",
                                  n=2, active=2)
    assert out["grad_norm_client/mean"] == pytest.approx(2.5)
    assert out["grad_norm_client/max"] == 4.0
    assert out["loss/spread"] == pytest.approx(0.5)
    assert out["mask/active"] == 2 and out["mask/fraction"] == 1.0
    assert out["health/nonfinite"] == 0
    assert out["health/first_step"] == -1
    # identical inputs -> identical floats (the MC replay relies on this)
    again = summarize_round_metrics(cfg, taps, losses=losses, kind="sl",
                                    n=2, active=2)
    assert out == again


# ---------------------------------------------------------------------------
# metrics-off stays byte-identical; metrics-on perturbs nothing it reports on
# ---------------------------------------------------------------------------

def _round_jaxpr(plan) -> str:
    """Canonical jaxpr of the plan's jitted round via the same audit handle
    ``repro_lint --jaxpr`` traces."""
    from repro.analyze.jaxpr_audit import _canon_jaxpr, _example_round_args
    args, audit = _example_round_args(plan)
    return _canon_jaxpr(jax.make_jaxpr(audit["jit_fn"])(*args))


@pytest.mark.parametrize("kind,axis", [("fl", "vmap"), ("sl", "scan"),
                                       ("sl", "vmap")])
def test_metrics_off_program_bit_identical(kind, axis):
    spec = _engine_spec(kind, axis)
    base = _round_jaxpr(compile_experiment(spec))
    # an ObsConfig WITHOUT metrics compiles the same program as obs=None
    off = _round_jaxpr(compile_experiment(spec, obs=ObsConfig(enabled=False)))
    assert off == base
    # ... and the tap-carrying twin is a genuinely different program
    on = _round_jaxpr(compile_experiment(spec, obs=_metrics_obs()))
    assert on != base


def _assert_streams_match(spec, rounds=2):
    _, recs_off = compile_experiment(spec).run(rounds)
    _, recs_on = compile_experiment(spec, obs=_metrics_obs()).run(rounds)
    assert len(recs_off) == len(recs_on) == rounds
    for a, b in zip(recs_off, recs_on):
        assert a.metrics == {} and b.metrics
        assert b.metrics["health/nonfinite"] == 0
        for f in NON_METRICS_FIELDS:
            assert getattr(a, f) == getattr(b, f), f
    return recs_on


@pytest.mark.parametrize("kind,axis", ENGINES)
def test_record_parity_engine_matrix(kind, axis):
    recs = _assert_streams_match(_engine_spec(kind, axis))
    m = recs[0].metrics
    assert "grad_norm_client/mean" in m and "update_norm_client/max" in m
    if kind == "sl":
        assert "grad_norm_server/mean" in m and "smashed_std/mean" in m
    else:
        assert "grad_norm_server/mean" not in m and "smashed_std/mean" not in m
    assert "quant_error/mean" not in m                 # fp32 link


def test_record_parity_shared_cohort_tier():
    # population > num_clients lowers the EPSL shared client tier; its
    # update_norm_client channel is the per-step shared-update scalar
    spec = dataclasses.replace(
        BASE, clients=ClientSpec(num_clients=3, population=9))
    recs = _assert_streams_match(spec)
    assert len(recs[0].cohort_pids) == 3
    assert "update_norm_client/mean" in recs[0].metrics


def test_record_parity_degenerate_population():
    # population == num_clients reproduces the materialized fleet
    spec = dataclasses.replace(
        BASE, clients=ClientSpec(num_clients=3, population=3))
    _assert_streams_match(spec)


def test_record_parity_hetero_buckets():
    spec = dataclasses.replace(BASE, cut_policy=CutPolicy(mode="adaptive"))
    recs = _assert_streams_match(spec)
    assert "grad_norm_client/mean" in recs[0].metrics


def test_quant_error_tap_requires_int8_link():
    spec = dataclasses.replace(BASE, link_policy=LinkPolicy(compress="int8"))
    plan = compile_experiment(spec, obs=_metrics_obs())
    st = plan.init()
    _, rec = plan.run_round(st, with_eval=False)
    assert "quant_error/mean" in rec.metrics
    assert rec.metrics["quant_error/mean"] > 0         # int8 is lossy
    # record is JSON round-trippable with the metrics dict aboard
    d = json.loads(json.dumps(rec.to_dict()))
    assert d["metrics"]["quant_error/mean"] == rec.metrics["quant_error/mean"]


# ---------------------------------------------------------------------------
# the NaN guard localizes exactly, on every engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,axis", ENGINES)
def test_nan_localized_exactly(kind, axis):
    plan = compile_experiment(_engine_spec(kind, axis), obs=_metrics_obs())
    state = plan.init()
    state, rec0 = plan.run_round(state, with_eval=False)
    assert rec0.metrics["health/nonfinite"] == 0
    bad = _poison(plan.round_batches(state), client=2, step=1)
    state, rec1 = plan.run_round(state, bad, with_eval=False)
    m = rec1.metrics
    assert m["health/nonfinite"] >= 1
    assert m["health/first_step"] == 1
    assert m["health/first_client"] == 2


def test_nan_raise_policy_carries_coordinate():
    plan = compile_experiment(
        BASE, obs=_metrics_obs(on_nonfinite="raise"))
    state = plan.init()
    state, _ = plan.run_round(state, with_eval=False)  # round 0 clean
    bad = _poison(plan.round_batches(state), client=1, step=2)
    with pytest.raises(NonfiniteError) as ei:
        plan.run_round(state, bad, with_eval=False)
    assert ei.value.round_index == 1
    assert ei.value.step == 2 and ei.value.client == 1
    assert ei.value.count >= 1 and "round=1" in str(ei.value)


# ---------------------------------------------------------------------------
# overhead: taps ride the scan carry, no extra syncs
# ---------------------------------------------------------------------------

def test_metrics_overhead_under_3pct():
    """Default tap set on a measured 20-round run stays under 3%: taps
    ride the round's existing device->host pull, and the NaN guard reuses
    the tapped norms instead of a second elementwise pass.

    Estimator: 20 interleaved off/on rounds each; the ratio of per-round
    MINIMA (scheduler interference only ever ADDS time, so the min
    converges to the true floor while round-level interleaving keeps both
    arms exposed to the same machine state — tighter than the trial-level
    A/B in ``test_obs_overhead_under_2pct``). A failing measurement is
    re-taken up to twice: the quantity pinned is the program's floor
    cost, not one noisy sample. The workload is sized so training compute
    dominates: tap cost is O(params) per slot-step, independent of
    batch/image, so tiny rounds would measure small-op dispatch, not the
    bus."""
    spec = dataclasses.replace(
        BASE, data=DataSpec(kind="synthetic", image_size=32,
                            classes_per_client=2, n_train=256, n_test=32),
        clients=ClientSpec(num_clients=4),
        global_rounds=20, local_steps=2, batch_size=64)
    plan_off = compile_experiment(spec)
    plan_on = compile_experiment(spec, obs=_metrics_obs())
    assert plan_off.obs is NULL_OBS and plan_off.metrics_config is None
    batches = plan_off.round_batches(plan_off.init())

    def one_round(plan, st):
        _, wall = fenced(
            lambda: plan.run_round(st, batches, with_eval=False))
        return wall

    st_off, st_on = plan_off.init(), plan_on.init()
    for _ in range(2):                                 # compile + warm
        one_round(plan_off, st_off)
        one_round(plan_on, st_on)

    def measure():
        pairs = [(one_round(plan_off, st_off), one_round(plan_on, st_on))
                 for _ in range(20)]
        return (min(b for _, b in pairs) / min(a for a, _ in pairs))

    ratio = measure()
    for _ in range(2):                                 # noisy-sample retries
        if ratio < 1.03:
            break
        ratio = min(ratio, measure())
    assert ratio < 1.03, f"metrics-bus overhead {ratio:.4f}x"


# ---------------------------------------------------------------------------
# Monte-Carlo: per-seed tap stacks, seed-0 replay, across-seed spread
# ---------------------------------------------------------------------------

def _stoch_metrics_plan(rounds=3):
    from repro.api import MissionSpec
    from repro.sim import AvailabilityParams, ChannelParams, ScenarioSpec
    scn = ScenarioSpec(
        channel=ChannelParams(kind="a2g"),
        availability=AvailabilityParams(kind="markov", p_drop=0.4,
                                        p_recover=0.6),
        num_uavs=2, serve_mode="relay", seed=1)
    return compile_experiment(
        dataclasses.replace(BASE, global_rounds=rounds,
                            mission=MissionSpec(farm_acres=100.0),
                            scenario=scn),
        obs=_metrics_obs())


def test_monte_carlo_seed_zero_replays_plan_metrics():
    plan = _stoch_metrics_plan()
    _, recs = plan.run(with_eval=False)
    mc = __import__("repro.sim", fromlist=["run_monte_carlo"]) \
        .run_monte_carlo(plan, 2, rounds=3, seed=0)
    mrecs = mc.records_for_seed(0)
    for a, b in zip(recs, mrecs):
        assert set(a.metrics) == set(b.metrics)
        for k in a.metrics:
            if k.startswith(("health/", "mask/")):
                assert a.metrics[k] == b.metrics[k], k
            else:
                # same tolerance the loss replay pin uses (vmap may
                # reassociate float reductions); in practice bit-exact
                np.testing.assert_allclose(a.metrics[k], b.metrics[k],
                                           rtol=2e-5, atol=1e-7, err_msg=k)


def test_monte_carlo_metrics_stacks_and_summary():
    plan = _stoch_metrics_plan()
    mc = plan and __import__("repro.sim", fromlist=["run_monte_carlo"]) \
        .run_monte_carlo(plan, 3, rounds=2)
    tap_keys = [k for k in mc.stacks if k.startswith("metrics/")]
    assert "metrics/grad_norm_client" in tap_keys
    for k in tap_keys:
        assert mc.stacks[k].shape[:2] == (3, 2)        # (seeds, rounds, ...)
    assert mc.stacks["loss_stack"].shape[:2] == (3, 2)
    s = mc.summary()["metrics"]
    assert s is not None and "grad_norm_client" in s
    assert s["grad_norm_client"]["min"] <= s["grad_norm_client"]["mean"] \
        <= s["grad_norm_client"]["max"]
    # loop mode carries the same tap stacks
    lc = __import__("repro.sim", fromlist=["run_monte_carlo"]) \
        .run_monte_carlo(plan, 2, rounds=2, mode="loop")
    for k in tap_keys:
        assert k in lc.stacks


def test_monte_carlo_without_metrics_unchanged():
    plan = compile_experiment(dataclasses.replace(BASE, global_rounds=2))
    from repro.sim import run_monte_carlo
    mc = run_monte_carlo(plan, 2, rounds=2)
    assert not any(k.startswith("metrics/") for k in mc.stacks)
    assert "loss_stack" not in mc.stacks
    assert mc.records_for_seed(0)[0].metrics == {}
    assert mc.summary()["metrics"] is None


# ---------------------------------------------------------------------------
# sink + report tooling
# ---------------------------------------------------------------------------

def test_metrics_events_stream_and_health_gate(tmp_path):
    obs_cfg = ObsConfig(run_root=str(tmp_path), run_id="mx",
                        metrics=MetricsConfig())
    plan = compile_experiment(dataclasses.replace(BASE, global_rounds=2),
                              obs=obs_cfg)
    plan.run(with_eval=False)
    plan.obs.close()
    import obs_report
    _, events = obs_report.load_run(plan.obs.run_dir)
    mev = obs_report.metrics_rounds(events)
    assert [e["round"] for e in mev] == [0, 1]
    assert all("grad_norm_client/mean" in e for e in mev)
    assert obs_report.health_nonfinite_total(events) == 0
    lines = obs_report.metrics_section(events)
    assert any("metrics taps" in ln for ln in lines)
    assert any("0 nonfinite" in ln for ln in lines)
    rendered = obs_report.render(plan.obs.run_dir, *obs_report.load_run(
        plan.obs.run_dir))
    assert any("grad_norm_client/mean" in ln for ln in rendered)


def test_obs_report_compare_two_runs(tmp_path):
    import obs_report
    for rid in ("a", "b"):
        obs_cfg = ObsConfig(run_root=str(tmp_path), run_id=rid)
        plan = compile_experiment(dataclasses.replace(BASE, global_rounds=1),
                                  obs=obs_cfg)
        plan.run(with_eval=False)
        plan.obs.close()
    lines = obs_report.compare_runs(os.path.join(str(tmp_path), "a"),
                                    os.path.join(str(tmp_path), "b"))
    assert lines[0].startswith("compare")
    body = "\n".join(lines)
    assert "run/round/execute" in body and "d_wall" in body
    assert "root wall" in lines[-1]


def test_perf_log_compaction():
    from benchmarks.report import compact_perf_log, perf_trend
    rows = [{"commit": c, "bench": "engine_perf", "model": "m", "case": "c",
             "variant": v, "steps_per_s": 100.0 + i}
            for i, c in enumerate(["c1", "c2", "c3", "c4"])
            for v in ("sl_fleet", "fl_vmap")]
    rows.append({"bench": "other", "note": "passthrough"})
    pruned = compact_perf_log(rows, 2)
    kept = {r["commit"] for r in pruned if "commit" in r}
    assert kept == {"c3", "c4"}
    assert any(r.get("bench") == "other" for r in pruned)   # untouched
    # the trend gate sees the same last-two comparison before and after
    before = perf_trend(rows)[0]
    after = perf_trend(pruned)[0]
    assert before == after
    with pytest.raises(ValueError):
        compact_perf_log(rows, 0)
