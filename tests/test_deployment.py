"""Algorithm 1 (edge deployment) — unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.deployment import (build_csr_adjacency, coverage_ok,
                                   deploy_edge_devices, deploy_gasbac,
                                   deploy_kmeans, field_side_meters,
                                   random_sensors, uniform_grid_sensors)


def test_field_side():
    # 100 acres ~ 636m square
    assert abs(field_side_meters(100) - 636.2) < 1.0


def test_csr_adjacency_symmetric():
    pts = uniform_grid_sensors(100, 25)
    csr = build_csr_adjacency(pts, 200.0)
    for i in range(len(pts)):
        for j in csr.neighbors(i):
            assert i in csr.neighbors(int(j))
    # self-coverage
    for i in range(len(pts)):
        assert i in csr.neighbors(i)


def test_paper_configuration_coverage():
    """The paper's Fig-2a config: 25 sensors / 100 acres / CR=200m."""
    pts = uniform_grid_sensors(100, 25)
    dep = deploy_edge_devices(pts, 200.0)
    assert coverage_ok(dep)
    # minimal-ish deployment: far fewer edge devices than sensors
    assert len(dep.edge_indices) < 25 / 2


def test_greedy_beats_or_ties_baselines_device_count():
    for acres, n in ((100, 25), (140, 36), (200, 49)):
        pts = uniform_grid_sensors(acres, n)
        ours = deploy_edge_devices(pts, 200.0)
        km = deploy_kmeans(pts, 200.0)
        assert len(ours.edge_indices) <= len(km.edge_indices) + 1


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 30), st.floats(100.0, 400.0), st.integers(0, 10**6))
def test_coverage_property(n, cr, seed):
    """Every sensor ends up within CR of its edge device, always."""
    pts = random_sensors(60, n, seed=seed)
    dep = deploy_edge_devices(pts, cr)
    assert coverage_ok(dep)
    # edge devices are sensors
    assert set(dep.edge_indices).issubset(set(range(n)))
    # every sensor assigned
    assert (dep.assignment >= 0).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(9, 25), st.integers(0, 10**6))
def test_load_balance_reasonable(n, seed):
    pts = random_sensors(80, n, seed=seed)
    dep = deploy_edge_devices(pts, 250.0)
    loads = dep.loads
    assert loads.sum() == n
    # balanced assignment: no edge device starves while others overflow by
    # more than the CR-feasibility forces
    assert loads.max() <= n


def test_kmeans_and_gasbac_run():
    pts = random_sensors(100, 25, seed=3)
    km = deploy_kmeans(pts, 250.0)
    gb = deploy_gasbac(pts, 250.0)
    assert len(km.edge_indices) >= 1
    assert len(gb.edge_indices) >= 1
    assert coverage_ok(km)
