"""The unified ``repro.api`` experiment layer.

Acceptance: ONE ``ExperimentSpec`` reproduces the FL baseline, sequential
SL, fleet-vmap SL, fleet-shard_map SL (explicit collectives), hetero-cut SL
and a compressed-link campaign round by changing only spec fields; the
legacy config surfaces map onto specs through ``paper_spec`` /
``campaign_spec`` (the ``train_fl``/``train_sl``/``run_campaign`` shims
they once fed are dropped). Policy follow-ups landed in the redesign —
P3SL-style client dropout and the mission-derived link deadline — are
covered here too, as is the transformer-ArchConfig path through
``fleet.hetero.stack_split_program`` and the perf trend gate.
"""
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec,
                       RoundRecord, compile_experiment, mission_max_link_s)
from repro.core.adaptive_cut import profile_cuts_cnn, select_cut
from repro.core.energy import HardwareProfile, JETSON_AGX_ORIN
from repro.core.paper_train import PaperTrainConfig, paper_spec
from repro.core.split import (SplitStep, apply_stages, init_stages,
                              partition_stages)
from repro.fleet import (CampaignConfig, FLEET_EQUIV_ATOL, campaign_spec,
                         make_fleet_sl_round)
from repro.fleet.hetero import arch_split_program, transformer_block_apply
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
from repro.optim import adamw, init_stacked

NUM_CLASSES = 4

BASE = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
    data=DataSpec(kind="synthetic", image_size=16, classes_per_client=2),
    clients=ClientSpec(num_clients=4),
    cut_policy=CutPolicy(mode="fraction", fraction=0.4),
    engine=EngineSpec(kind="sl", client_axis="scan"),
    global_rounds=2, local_steps=2, batch_size=4)

MCU = HardwareProfile("mcu-class", fp32_tflops=0.02, mem_bw_gbs=2.0,
                      tensor_tflops=0.04, cpu_passmark=400.0, power_w=2.0)

# The acceptance matrix: every paper scenario is a FIELD EDIT on one spec.
VARIANTS = {
    "fl_baseline": dataclasses.replace(
        BASE, engine=EngineSpec(kind="fl", client_axis="scan")),
    "sl_sequential": BASE,
    "sl_fleet_vmap": dataclasses.replace(
        BASE, engine=EngineSpec(kind="sl", client_axis="vmap")),
    "sl_fleet_shard_map": dataclasses.replace(
        BASE, engine=EngineSpec(kind="sl", client_axis="shard_map")),
    "fl_shard_map": dataclasses.replace(
        BASE, engine=EngineSpec(kind="fl", client_axis="shard_map")),
    "sl_hetero_cut": dataclasses.replace(
        BASE, engine=EngineSpec(kind="sl", client_axis="vmap"),
        cut_policy=CutPolicy(mode="adaptive"),
        clients=ClientSpec(num_clients=4,
                           edge_profiles=(JETSON_AGX_ORIN, MCU))),
    "campaign_int8": dataclasses.replace(
        BASE, engine=EngineSpec(kind="sl", client_axis="vmap"),
        link_policy=LinkPolicy(compress="int8"),
        mission=MissionSpec(farm_acres=100.0)),
}


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_one_spec_reproduces_every_round_shape(name):
    """compile_experiment lowers each field-edited spec to a running plan
    with the uniform RoundRecord stream."""
    spec = VARIANTS[name]
    plan = compile_experiment(spec)
    state, records = plan.run()
    assert len(records) == plan.num_rounds > 0
    assert state.last_metrics is not None
    for rec in records:
        assert isinstance(rec, RoundRecord)
        d = rec.to_dict()
        assert np.isfinite(d["loss"])
        assert 0.0 <= d["accuracy"] <= 1.0
        assert d["client_energy_j"] > 0
        assert d["active_clients"] == spec.clients.num_clients
        assert d["engine"] == plan.engine_label
        if spec.engine.kind == "sl":
            assert d["link_bytes"] > 0 and d["server_energy_j"] > 0
        else:
            assert d["link_bytes"] == 0.0
        assert (d["uav_energy_j"] > 0) == (spec.mission is not None)
    if name == "sl_hetero_cut":
        assert len(set(plan.cut_of_client)) >= 1
        assert len(plan.cut_of_client) == 4
    if name == "campaign_int8":
        assert plan.tour is not None and plan.rounds_budget >= len(records)


def test_hetero_plan_states_are_independent():
    """plan.init() returns fresh state on every call, hetero path included:
    a second run must not wipe or alias the first run's trained state."""
    plan = compile_experiment(VARIANTS["sl_hetero_cut"])
    s1, _ = plan.run_round(plan.init())
    m1 = plan.evaluate(s1)
    s2 = plan.init()                    # must not reset s1's state
    m1_again = plan.evaluate(s1)
    assert m1 == m1_again
    m_fresh = plan.evaluate(s2)
    # fresh state is the untrained init, distinct object from s1's
    assert s2.engine_state is not s1.engine_state
    assert m_fresh.keys() == m1.keys()


def test_second_round_trains(tmp_path):
    """The record stream reflects actual optimization: training loss drops
    over rounds on every engine (same synthetic data, fresh plan)."""
    for name in ("fl_baseline", "sl_fleet_vmap"):
        spec = dataclasses.replace(VARIANTS[name], global_rounds=3)
        _, records = compile_experiment(spec).run()
        assert records[-1].loss < records[0].loss


# ---------------------------------------------------------------------------
# legacy config surfaces map onto specs (the dropped shims' contract)
# ---------------------------------------------------------------------------

def _shim_data(seed=0, n=96):
    rng = np.random.RandomState(seed)
    x = rng.uniform(0, 1, size=(n, 16, 16, 3)).astype(np.float32)
    y = rng.randint(0, NUM_CLASSES, size=(n,))
    return x, y, x[:24], y[:24]


@pytest.mark.parametrize("kind", ["fl", "sl"])
def test_paper_spec_maps_config_and_runs(kind):
    """paper_spec pins the historical PaperTrainConfig surface onto the
    sequential engines — field-for-field — and the spec runs end to end
    (what the dropped train_fl/train_sl shims used to wrap)."""
    cfg = PaperTrainConfig(model="tinycnn", num_clients=3, global_rounds=2,
                           local_steps=2, batch_size=4, image_size=16,
                           client_fraction=0.4, num_classes=NUM_CLASSES,
                           compress_link=True)
    spec = paper_spec(cfg, kind)
    assert spec.engine == EngineSpec(kind=kind, client_axis="scan")
    assert spec.data.kind == "arrays" and spec.data.shrink_batches
    assert spec.cut_policy.fraction == cfg.client_fraction
    assert spec.link_policy.compress == "int8"
    assert (spec.clients.num_clients, spec.global_rounds, spec.local_steps,
            spec.batch_size) == (cfg.num_clients, cfg.global_rounds,
                                 cfg.local_steps, cfg.batch_size)
    plan = compile_experiment(spec, data=_shim_data())
    _, records = plan.run()
    assert len(records) == cfg.global_rounds
    assert all(np.isfinite(r.loss) for r in records)
    if kind == "sl":
        assert all(r.link_bytes > 0 for r in records)


def test_campaign_spec_maps_config_and_runs():
    """campaign_spec pins the historical CampaignConfig surface onto the
    fleet SL engine + mission (what the dropped run_campaign shim used to
    wrap); the compiled plan exposes the tour/budget/cut surfaces the old
    CampaignResult carried."""
    cfg = CampaignConfig(model="tinycnn", num_clients=4, global_rounds=2,
                         local_steps=2, batch_size=4, image_size=16,
                         num_classes=NUM_CLASSES, classes_per_client=2)
    spec = campaign_spec(cfg)
    assert spec.engine == EngineSpec(kind="sl", client_axis="vmap")
    assert spec.mission is not None
    assert spec.mission.farm_acres == cfg.farm_acres
    assert spec.cut_policy.mode == "fraction"
    plan = compile_experiment(spec)
    _, records = plan.run()
    assert plan.tour is not None and plan.rounds_budget >= len(records) > 0
    assert len(plan.cut_of_client) == cfg.num_clients
    for rec in records:
        assert rec.uav_energy_j > 0 and rec.link_bytes > 0
    # the fp32-vs-int8 sweep is two specs differing only in the link policy
    spec8 = dataclasses.replace(
        spec, link_policy=dataclasses.replace(spec.link_policy,
                                              compress="int8"))
    plan8 = compile_experiment(spec8)
    _, records8 = plan8.run()
    assert plan8.tour.order == plan.tour.order      # same seed, same tour
    assert (sum(r.link_bytes for r in records8)
            < sum(r.link_bytes for r in records))


# ---------------------------------------------------------------------------
# policy follow-ups: client dropout + mission-derived link deadline
# ---------------------------------------------------------------------------

def test_client_dropout_masks_stragglers():
    """P3SL-style dropout: some rounds run with fewer active clients; the
    round's energy/link bill covers only the active subset."""
    spec = dataclasses.replace(
        VARIANTS["sl_fleet_vmap"], global_rounds=4,
        clients=ClientSpec(num_clients=4, dropout_rate=0.6), seed=3)
    plan = compile_experiment(spec)
    _, records = plan.run()
    actives = [r.active_clients for r in records]
    assert all(1 <= a <= 4 for a in actives)
    assert min(actives) < 4          # dropout actually fired at rate 0.6
    full = compile_experiment(dataclasses.replace(
        spec, clients=ClientSpec(num_clients=4)))
    _, full_records = full.run()
    for r, fr in zip(records, full_records):
        if r.active_clients < 4:
            assert r.client_energy_j < fr.client_energy_j
            assert r.link_bytes < fr.link_bytes
        assert np.isfinite(r.loss)


def test_dropout_engine_full_mask_matches_plain():
    """The mask-aware fleet SL round with an all-ones mask == the plain
    round (the dropout seam costs nothing when unused)."""
    C, S, B = 4, 2, 4
    stages = CNN_BUILDERS["tinycnn"](NUM_CLASSES)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    bx = jax.random.uniform(jax.random.fold_in(key, 1), (C, S, B, 16, 16, 3))
    by = jax.random.randint(jax.random.fold_in(key, 2), (C, S, B), 0,
                            NUM_CLASSES)
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.4)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), cp0)
    state = (stack, sp, init_stacked(opt_c, cp0, C), opt_s.init(sp))
    batches = {"inputs": bx, "targets": by}
    plain = make_fleet_sl_round(step, opt_c, opt_s, local_rounds=S)(
        *state, batches)
    masked = make_fleet_sl_round(step, opt_c, opt_s, local_rounds=S,
                                 client_dropout=True)(
        *state, batches, jnp.ones(C))
    for a, b in zip(jax.tree_util.tree_leaves(plain),
                    jax.tree_util.tree_leaves(masked)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=FLEET_EQUIV_ATOL)

    # a zero mask is a no-op round: params pass through untouched
    frozen = make_fleet_sl_round(step, opt_c, opt_s, local_rounds=S,
                                 client_dropout=True)(
        *jax.tree_util.tree_map(jnp.copy, state), batches, jnp.zeros(C))
    for a, b in zip(jax.tree_util.tree_leaves(frozen[:4]),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-7)


def test_mission_derives_link_deadline():
    """With adaptive cuts + a mission, the UAV hover window bounds the
    per-step link time exactly as an explicit max_link_s would."""
    mission = MissionSpec(hover_s_per_stop=0.002, comm_s_per_stop=0.002)
    derived = mission_max_link_s(mission.hover_s_per_stop,
                                 mission.comm_s_per_stop, BASE.local_steps)
    assert derived == pytest.approx(0.004 / BASE.local_steps)
    starved = LinkPolicy(rate_bps=1e6)    # 1 Mb/s: link time dominates
    with_mission = dataclasses.replace(
        VARIANTS["sl_hetero_cut"], link_policy=starved, mission=mission)
    explicit = dataclasses.replace(
        VARIANTS["sl_hetero_cut"], link_policy=starved,
        cut_policy=CutPolicy(mode="adaptive", max_link_s=derived))
    plan_m = compile_experiment(with_mission)
    plan_e = compile_experiment(explicit)
    assert plan_m.cut_of_client == plan_e.cut_of_client

    # the binding deadline forces the min-link-time cut (select_cut's
    # documented fallback) for the Jetson-profile clients
    stages = plan_m.stages
    choices = profile_cuts_cnn(stages, plan_m.params0,
                               jnp.asarray(plan_m.x_train[:BASE.batch_size]),
                               edge=JETSON_AGX_ORIN, link=starved.config())
    expected = select_cut(choices, max_link_s=derived).cut_index
    assert plan_m.cut_of_client[0] == expected


# ---------------------------------------------------------------------------
# real transformer ArchConfig through the stacked-block split (ROADMAP PR-2)
# ---------------------------------------------------------------------------

def _tiny_arch():
    from repro.configs.base import ArchConfig
    return ArchConfig(name="tiny-attn", family="dense", n_layers=4,
                      d_model=16, n_heads=2, n_kv_heads=2, d_ff=32,
                      vocab=64, dtype="float32")


def test_arch_split_program_matches_full_group_apply():
    """arch_split_program drives models.transformer.group_apply through
    stack_split_program: client scan + server scan == one scan over the
    whole stack, and the fleet round trains the split."""
    from repro.models.transformer import GroupSpec, group_apply
    cfg = _tiny_arch()
    key = jax.random.PRNGKey(0)

    def loss_fn(h, targets):
        return jnp.mean((h.mean(-1) - targets) ** 2)

    prog = arch_split_program(cfg, key, 2, loss_fn=loss_fn)
    B, S = 2, 8
    x = 0.5 * jax.random.normal(jax.random.fold_in(key, 1),
                                (B, S, cfg.d_model), jnp.float32)
    smashed = prog.step.client_fwd(prog.params_c0, x)
    assert smashed.shape == (B, S, cfg.d_model)
    served = prog.step.client_fwd(prog.params_s0, smashed)

    # reference: group_apply over the full 4-layer stack in one scan
    from repro.core.split import merge_stack
    full_stack = merge_stack(prog.params_c0, prog.params_s0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ref, _ = group_apply(cfg, GroupSpec("attn", cfg.n_layers, 0), full_stack,
                         x, jnp.zeros((), jnp.float32), positions=positions,
                         window=cfg.swa_window)
    np.testing.assert_allclose(np.asarray(served), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # the split trains under the fleet engine
    C, St = 2, 2
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    engine = jax.jit(make_fleet_sl_round(prog.step, opt_c, opt_s,
                                         local_rounds=St))
    stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (C,) + v.shape), prog.params_c0)
    bx = 0.5 * jax.random.normal(jax.random.fold_in(key, 2),
                                 (C, St, B, S, cfg.d_model), jnp.float32)
    by = jax.random.normal(jax.random.fold_in(key, 3), (C, St, B, S))
    *_, losses = engine(stack, prog.params_s0,
                        init_stacked(opt_c, prog.params_c0, C),
                        opt_s.init(prog.params_s0),
                        {"inputs": bx, "targets": by})
    assert losses.shape == (St, C) and bool(jnp.isfinite(losses).all())


def test_transformer_block_apply_rejects_moe():
    import dataclasses as dc
    cfg = dc.replace(_tiny_arch(), n_experts=4, top_k=2)
    with pytest.raises(ValueError):
        transformer_block_apply(cfg)


# ---------------------------------------------------------------------------
# spec validation + perf trend gate
# ---------------------------------------------------------------------------

def test_spec_validation_errors():
    with pytest.raises(ValueError):   # adaptive cuts need the fleet engine
        compile_experiment(dataclasses.replace(
            BASE, cut_policy=CutPolicy(mode="adaptive")))
    with pytest.raises(ValueError):   # dropout is a fleet policy
        compile_experiment(dataclasses.replace(
            BASE, clients=ClientSpec(num_clients=4, dropout_rate=0.5)))
    with pytest.raises(ValueError):   # arrays spec needs arrays
        compile_experiment(dataclasses.replace(
            BASE, data=DataSpec(kind="arrays")))
    with pytest.raises(ValueError):
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="sl", client_axis="pmap")))
    with pytest.raises(ValueError):   # server_mesh needs a fleet SL engine
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="fl", client_axis="vmap",
                                    server_mesh=(2, 1))))
    with pytest.raises(ValueError):   # ... not the sequential engine
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="sl", client_axis="scan",
                                    server_mesh=(2, 1))))
    with pytest.raises(ValueError):   # sizes >= 1
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="sl", client_axis="vmap",
                                    server_mesh=(0, 1))))
    # an explicit mesh must match the spec's requested server sub-mesh —
    # never a silent fall-back to a replicated server suffix
    from repro.launch.mesh import single_device_fleet_mesh
    with pytest.raises(ValueError, match="server_mesh"):
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="sl", client_axis="vmap",
                                    server_mesh=(2, 1))),
            mesh=single_device_fleet_mesh())


# ---------------------------------------------------------------------------
# shard_map engine through the spec layer
# ---------------------------------------------------------------------------

def test_shard_map_spec_matches_vmap():
    """One spec-field edit flips an experiment onto the explicit-collective
    path: the shard_map plans track the vmap plans round-for-round within
    FLEET_EQUIV_ATOL (same seed -> same batch/dropout streams). Runs on
    whatever devices exist (single-device fleet mesh here; the forced
    multi-device equivalence lives in test_fleet.py)."""
    for base in (VARIANTS["sl_fleet_vmap"], VARIANTS["fl_baseline"]):
        eng = base.engine
        vmap_spec = dataclasses.replace(
            base, engine=dataclasses.replace(eng, client_axis="vmap"))
        sm_spec = dataclasses.replace(
            base, engine=dataclasses.replace(eng, client_axis="shard_map"))
        _, rec_v = compile_experiment(vmap_spec).run()
        _, rec_s = compile_experiment(sm_spec).run()
        assert [r.engine for r in rec_s] == [
            f"{eng.kind}/shard_map"] * len(rec_s)
        for a, b in zip(rec_v, rec_s):
            assert abs(a.loss - b.loss) <= FLEET_EQUIV_ATOL
            assert abs(a.accuracy - b.accuracy) <= FLEET_EQUIV_ATOL
            assert a.link_bytes == b.link_bytes


def test_shard_map_dropout_matches_vmap():
    """Dropout masks inside the shard_map round (fedavg_pmean_masked +
    psum'd active counts) reproduce the vmap masked-FedAvg records: same
    seed -> identical mask stream -> identical active-client counts and
    losses within the tolerance gate."""
    base = dataclasses.replace(
        VARIANTS["sl_fleet_vmap"], global_rounds=4,
        clients=ClientSpec(num_clients=4, dropout_rate=0.6), seed=3)
    sm = dataclasses.replace(
        base, engine=dataclasses.replace(base.engine,
                                         client_axis="shard_map"))
    _, rec_v = compile_experiment(base).run()
    _, rec_s = compile_experiment(sm).run()
    assert min(r.active_clients for r in rec_v) < 4   # dropout fired
    for a, b in zip(rec_v, rec_s):
        assert a.active_clients == b.active_clients
        assert abs(a.loss - b.loss) <= FLEET_EQUIV_ATOL
        assert a.client_energy_j == b.client_energy_j


def test_perf_trend_gate(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.report import check_perf, perf_trend

    def row(commit, variant, sps):
        return {"commit": commit, "bench": "engine_perf", "model": "tinycnn",
                "case": "c8s2b8", "variant": variant, "steps_per_s": sps}

    rows = [row("aaa", "sl_fleet", 100.0), row("aaa", "fl_vmap", 200.0),
            row("bbb", "sl_fleet", 95.0), row("bbb", "fl_vmap", 170.0)]
    comps, regs = perf_trend(rows, threshold=0.10)
    assert len(comps) == 2
    assert len(regs) == 1 and "fl_vmap" in regs[0]   # -15% flagged, -5% not
    assert perf_trend(rows[:2]) == ([], [])          # one commit: vacuous

    path = tmp_path / "engine_perf.json"
    path.write_text(json.dumps(rows))
    assert check_perf(str(path), threshold=0.10) == 1
    assert check_perf(str(path), threshold=0.20) == 0
    assert check_perf(str(tmp_path / "missing.json")) == 0

    # relative mode: a 2x-slower machine is NOT a regression once each
    # variant is normalized by its commit's sl_host_loop baseline — but a
    # genuinely slower engine still is
    rel = [row("aaa", "sl_host_loop", 100.0), row("aaa", "sl_fleet", 300.0),
           row("bbb", "sl_host_loop", 50.0), row("bbb", "sl_fleet", 150.0)]
    comps, regs = perf_trend(rel, threshold=0.10, relative=True)
    fleet = [c for c in comps if c["variant"] == "sl_fleet"][0]
    assert fleet["unit"] == "x host_loop" and regs == []   # 3.0x both sides
    _, regs_abs = perf_trend(rel, threshold=0.10)
    assert len(regs_abs) == 2                        # absolute mode flags both
    rel[-1] = row("bbb", "sl_fleet", 100.0)          # fleet fell to 2x: real
    _, regs = perf_trend(rel, threshold=0.10, relative=True)
    assert len(regs) == 1 and "sl_fleet" in regs[0]
