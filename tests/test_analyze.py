"""``repro.analyze`` — the static-analysis subsystem itself.

Contract under test:

  * every jaxpr-audit check fires on a synthetic bad program (undonated
    donate, callback-in-scan, f64 leak, off-mesh collective axis,
    trace-unstable closure, over-budget closure const) and stays silent
    on a clean one,
  * the ``repro.keys`` registry rejects duplicate slot names/values and
    the registered layout matches the historical magic numbers
    bit-for-bit (the replay tests pin the streams themselves),
  * every AST rule fires on a minimal bad source snippet with the exact
    rule id + line, stays silent on the idiomatic counterpart, and the
    ``repro: ignore[<rule>] -- reason`` escape hatch suppresses exactly
    when a reason is present,
  * the compiled engine-variant matrix audits clean — zero findings over
    fl/sl x scan/vmap/shard_map, dropout, population cohorts, and the
    Monte-Carlo vmap rollout (full sweep is slow-marked; a cross-section
    runs in the fast suite),
  * the repo's own source tree lints clean (the CI lint gate, as a test).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import keys
from repro.analyze import (audit_keys, audit_mc, audit_plan,
                           check_callbacks, check_collective_axes,
                           check_const_budget, check_donation, check_f64,
                           check_trace_stability, compiled_variants,
                           lint_paths, lint_source)
from repro.api import compile_experiment

# ---------------------------------------------------------------------------
# keys registry
# ---------------------------------------------------------------------------

def test_registered_slots_match_historical_magic_numbers():
    # load-bearing values: replay tests pin the resulting streams, so the
    # registry must encode exactly the pre-registry literals
    assert (keys.ENV_MASK.value, keys.ENV_RATES.value,
            keys.ENV_COHORT.value) == (1, 2, 3)
    assert (keys.DATA_TRAIN.value, keys.DATA_TEST.value) == (0, 1)
    assert keys.INIT_FFN_ALT.value == 1
    assert keys.INIT_MOE_SHARED.value == 7


def test_fold_equals_raw_fold_in():
    k = jax.random.PRNGKey(0)
    np.testing.assert_array_equal(
        keys.fold(k, keys.ENV_COHORT), jax.random.fold_in(k, 3))
    np.testing.assert_array_equal(
        keys.round_env_key(k, 5), jax.random.fold_in(k, 5))


def test_register_rejects_name_and_value_collisions():
    with pytest.raises(ValueError, match="already registered with value"):
        keys.register("env", "mask", 9)       # name collision, new value
    with pytest.raises(ValueError, match="already taken"):
        keys.register("env", "mask2", 1)      # value collision, new name
    # exact re-registration is idempotent (module reloads)
    assert keys.register("env", "mask", 1) is keys.ENV_MASK
    # same value in a DIFFERENT domain is fine (data/train=0 vs env uses)
    assert keys.DATA_TRAIN.value == 0


def test_audit_keys_clean():
    assert audit_keys().ok


# ---------------------------------------------------------------------------
# jaxpr audit: one synthetic bad program per check
# ---------------------------------------------------------------------------

def test_donation_detects_unconsumed_donated_buffer():
    # 'a' is donated but never aliased into an output -> silently copied
    bad = jax.jit(lambda a, b: b * 2.0, donate_argnums=(0,))
    x = jnp.ones((8, 8))
    findings = check_donation(bad, (x, x), (0,), "bad")
    assert [f.rule for f in findings] == ["jaxpr-donation"]
    assert "0/1" in findings[0].message

    good = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
    assert check_donation(good, (x, x), (0,), "good") == []


def test_callback_detected_through_scan():
    def bad(x):
        def body(c, _):
            jax.debug.print("c={c}", c=c)
            return c + 1.0, c
        out, _ = jax.lax.scan(body, x, jnp.arange(3.0))
        return out

    closed = jax.make_jaxpr(bad)(1.0)
    findings = check_callbacks(closed, "bad")
    assert findings and all(f.rule == "jaxpr-callback" for f in findings)

    closed = jax.make_jaxpr(
        lambda x: jax.lax.scan(lambda c, _: (c + 1.0, c), x,
                               jnp.arange(3.0))[0])(1.0)
    assert check_callbacks(closed, "good") == []


def test_f64_promotion_detected():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x * np.float64(2.0))(np.float64(1.0))
    findings = check_f64(closed, "bad")
    assert findings and findings[0].rule == "jaxpr-f64"

    closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.float32(1.0))
    assert check_f64(closed, "good") == []


def test_collective_axis_checked_against_mesh():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import single_device_fleet_mesh

    mesh = single_device_fleet_mesh()

    def fn(x):
        return shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P())(x)

    closed = jax.make_jaxpr(fn)(jnp.ones(4))
    # the psum really names the 'data' axis
    assert check_collective_axes(closed, mesh, "good") == []
    # ... which does not exist on an unbound (None) mesh
    findings = check_collective_axes(closed, None, "bad")
    assert findings and findings[0].rule == "jaxpr-collective-axis"
    assert "'data'" in findings[0].message


def test_trace_instability_detected():
    calls = [0]

    def bad(x):
        calls[0] += 1
        return x + float(calls[0])   # fresh literal every trace

    findings = check_trace_stability(bad, (jnp.ones(2),), "bad")
    assert [f.rule for f in findings] == ["jaxpr-trace-stability"]

    assert check_trace_stability(lambda x: x + 1.0, (jnp.ones(2),),
                                 "good") == []


def test_const_budget_flags_baked_in_arrays():
    big = jnp.zeros((1024, 512), jnp.float32)          # 2 MiB closure const
    closed = jax.make_jaxpr(lambda x: x + big.sum())(jnp.float32(0.0))
    findings = check_const_budget(closed, "bad")
    assert findings and findings[0].rule == "jaxpr-const-budget"

    assert check_const_budget(closed, "ok",
                              const_budget_bytes=4 << 20) == []


# ---------------------------------------------------------------------------
# AST lint: one bad snippet per rule (exact rule + line)
# ---------------------------------------------------------------------------

def _rules_at(findings):
    return [(f.rule, int(f.where.rsplit(":", 1)[1])) for f in findings]


def test_ast_traced_branch():
    bad = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x:\n"
        "        return 1\n"
        "    return 0\n")
    assert _rules_at(lint_source(bad)) == [("traced-branch", 4)]
    # `is None` tests are static and exempt; un-jitted branching is fine
    ok = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x is None:\n"
        "        return 0\n"
        "    return x\n"
        "def g(y):\n"
        "    if y:\n"
        "        return 1\n")
    assert lint_source(ok) == []


def test_ast_traced_branch_through_wrapper_call():
    bad = (
        "import jax\n"
        "def body(c, x):\n"
        "    while c:\n"
        "        c = c - 1\n"
        "    return c, x\n"
        "out = jax.lax.scan(body, 0, None)\n")
    assert _rules_at(lint_source(bad)) == [("traced-branch", 3)]


def test_ast_raw_timer_and_suppression():
    bad = "import time\nt0 = time.perf_counter()\n"
    assert _rules_at(lint_source(bad)) == [("raw-timer", 2)]
    with_reason = ("import time\n"
                   "t0 = time.time()  "
                   "# repro: ignore[raw-timer] -- progress stamp only\n")
    assert lint_source(with_reason) == []
    no_reason = ("import time\n"
                 "t0 = time.time()  # repro: ignore[raw-timer]\n")
    # a reason-less ignore is flagged AND does not suppress
    assert sorted(f.rule for f in lint_source(no_reason)) == [
        "bad-suppression", "raw-timer"]
    unknown = ("import time\n"
               "t0 = time.time()  # repro: ignore[not-a-rule] -- because\n")
    found = lint_source(unknown)
    # the bogus ignore is flagged AND does not suppress the raw timer
    assert sorted(f.rule for f in found) == ["bad-suppression", "raw-timer"]


def test_ast_key_reuse():
    bad = (
        "import jax\n"
        "def f():\n"
        "    k = jax.random.PRNGKey(0)\n"
        "    a = jax.random.normal(k, (2,))\n"
        "    b = jax.random.uniform(k, (2,))\n"
        "    return a, b\n")
    assert _rules_at(lint_source(bad)) == [("key-reuse", 5)]
    ok = (
        "import jax\n"
        "def f():\n"
        "    k = jax.random.PRNGKey(0)\n"
        "    k1, k2 = jax.random.split(k)\n"
        "    return jax.random.normal(k1, (2,)), "
        "jax.random.uniform(k2, (2,))\n")
    assert lint_source(ok) == []


def test_ast_magic_fold():
    bad = "import jax\nk2 = jax.random.fold_in(k, 3)\n"
    assert _rules_at(lint_source(bad)) == [("magic-fold", 2)]
    # non-literal folds (round/step indices) are the blessed pattern
    ok = ("import jax\nfrom repro import keys\n"
          "k2 = jax.random.fold_in(k, r)\n"
          "k3 = keys.fold(k, keys.ENV_MASK)\n")
    assert lint_source(ok) == []


def test_ast_unhoisted_const():
    bad = (
        "import jax.numpy as jnp\n"
        "def f(n):\n"
        "    out = []\n"
        "    for i in range(n):\n"
        "        out.append(jnp.ones((4, 4)) * i)\n"
        "    return out\n")
    assert _rules_at(lint_source(bad)) == [("unhoisted-const", 5)]
    # a def inside the loop is traced, not executed per iteration
    ok = (
        "import jax.numpy as jnp\n"
        "def f(n):\n"
        "    fns = []\n"
        "    for i in range(n):\n"
        "        def g(x):\n"
        "            return x + jnp.ones((4, 4))\n"
        "        fns.append(g)\n"
        "    return fns\n")
    assert lint_source(ok) == []


def test_ast_bare_except():
    bad = "try:\n    x = 1\nexcept:\n    pass\n"
    assert _rules_at(lint_source(bad)) == [("bare-except", 3)]
    assert lint_source("try:\n    x = 1\nexcept ValueError:\n    pass\n") == []


def test_ast_label_link():
    bad = (
        "from repro.core.split import SplitStep\n"
        "step = SplitStep(\n"
        "    client_fwd=lambda pc, xx, yy: fwd(pc, xx, yy),\n"
        "    server_loss=loss_fn)\n")
    found = lint_source(bad)
    assert [f.rule for f in found] == ["label-link"]
    assert "'yy'" in found[0].message
    ok = (
        "from repro.core.split import SplitStep\n"
        "step = SplitStep(\n"
        "    client_fwd=lambda pc, xx: fwd(pc, xx),\n"
        "    server_loss=lambda ps, sm, yy: loss(ps, sm, yy))\n")
    assert lint_source(ok) == []


# ---------------------------------------------------------------------------
# the repo audits clean (the CI gate, as tests)
# ---------------------------------------------------------------------------

def test_repo_source_tree_lints_clean():
    import repro
    from pathlib import Path
    src = Path(next(iter(repro.__path__))).resolve()
    report = lint_paths([src], repo_root=src.parent.parent)
    assert report.ok, "\n".join(str(f) for f in report.findings)
    assert len(report.checked) > 50


def test_variant_cross_section_audits_clean():
    # one representative per engine family; the full matrix is slow-marked
    for name, plan, _ in compiled_variants(mc=False,
                                           match="sl/shard_map"):
        report = audit_plan(plan)
        assert report.ok, (name, [str(f) for f in report.findings])


def test_audit_rejects_hetero_plans():
    import dataclasses
    from repro.api import ClientSpec, CutPolicy
    from repro.core.energy import HardwareProfile, JETSON_AGX_ORIN
    from repro.analyze.variants import _tiny_spec
    mcu = HardwareProfile("mcu-class", fp32_tflops=0.02, mem_bw_gbs=2.0,
                          tensor_tflops=0.04, cpu_passmark=400.0,
                          power_w=2.0)
    spec = dataclasses.replace(
        _tiny_spec("sl", "vmap"),
        clients=ClientSpec(num_clients=4,
                           edge_profiles=(JETSON_AGX_ORIN, mcu)),
        cut_policy=CutPolicy(mode="adaptive"))
    plan = compile_experiment(spec)
    if len(set(plan.cut_of_client)) == 1:
        pytest.skip("adaptive cuts collapsed to one bucket on this host")
    with pytest.raises(ValueError, match="no single"):
        audit_plan(plan)


@pytest.mark.slow
def test_full_variant_matrix_audits_clean():
    for name, plan, with_mc in compiled_variants(mc=True):
        report = audit_plan(plan)
        if with_mc:
            report.extend(audit_mc(plan))
        assert report.ok, (name, [str(f) for f in report.findings])


def test_mc_rollout_audits_clean_and_matches_execution():
    from repro.sim import run_monte_carlo
    from repro.analyze.variants import mc_specs
    name, spec = next(iter(mc_specs()))
    plan = compile_experiment(spec)
    report = audit_mc(plan)
    assert report.ok, [str(f) for f in report.findings]
    # the audited builder is the executed builder: the sweep still runs
    res = run_monte_carlo(plan, 2, rounds=2)
    assert res.stacks["loss"].shape == (2, 2)
