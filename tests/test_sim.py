"""``repro.sim`` — stochastic mission & channel scenarios.

The contract under test:

  * the air-to-ground rate model is physically sane (monotone in distance,
    deterministic when shadowing/fading are off),
  * availability traces are valid masks (>=1 active; markov burstiness),
  * the mission rollout's degenerate corner IS ``plan_tour`` (single UAV,
    hover), and multi-UAV dispatch partitions the fleet,
  * the degenerate scenario reproduces today's ``campaign_spec`` records —
    the paper numbers are a pinned special case of the subsystem,
  * Monte-Carlo rollouts are bitwise-reproducible under a fixed seed, and
    the vectorized (vmap) rollout matches the per-seed Python loop.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec,
                       compile_experiment)
from repro.core.trajectory import plan_tour
from repro.core.uav_energy import DEFAULT_UAV
from repro.fleet import CampaignConfig, campaign_spec
from repro.sim import (AvailabilityParams, ChannelParams, ScenarioSpec,
                       availability_init, availability_step,
                       degenerate_scenario, deterministic_rate_bps,
                       rollout_mission, run_monte_carlo, sample_rates_bps)

NUM_CLASSES = 4

BASE = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
    data=DataSpec(kind="synthetic", image_size=16, classes_per_client=2),
    clients=ClientSpec(num_clients=4),
    cut_policy=CutPolicy(mode="fraction", fraction=0.4),
    engine=EngineSpec(kind="sl", client_axis="vmap"),
    mission=MissionSpec(farm_acres=100.0),
    global_rounds=2, local_steps=2, batch_size=4)

STOCH = ScenarioSpec(
    channel=ChannelParams(kind="a2g"),
    availability=AvailabilityParams(kind="markov", p_drop=0.4,
                                    p_recover=0.6),
    num_uavs=2, serve_mode="relay", seed=1)


# ---------------------------------------------------------------------------
# channel
# ---------------------------------------------------------------------------

def test_channel_rate_monotone_in_distance():
    p = ChannelParams(kind="a2g", shadowing_sigma_db=0.0, fading="none")
    d = jnp.asarray([10.0, 30.0, 100.0, 300.0, 1000.0])
    r = np.asarray(deterministic_rate_bps(p, d, 1e8))
    assert np.all(np.diff(r) < 0)            # strictly decreasing
    assert np.all(r >= p.min_rate_bps)
    # the deterministic corner bypasses the RNG: sample == deterministic,
    # any key
    s = np.asarray(sample_rates_bps(jax.random.PRNGKey(7), p, d, 1e8))
    np.testing.assert_array_equal(s, r)


def test_channel_constant_kind_is_the_nominal_rate():
    p = ChannelParams(kind="constant")
    d = jnp.asarray([1.0, 500.0])
    r = np.asarray(sample_rates_bps(jax.random.PRNGKey(0), p, d, 42e6))
    np.testing.assert_array_equal(r, np.full(2, 42e6, np.float32))


def test_channel_stochastic_draws_vary_but_reproduce():
    p = ChannelParams(kind="a2g", shadowing_sigma_db=4.0, fading="rayleigh")
    d = jnp.full((8,), 100.0)
    k = jax.random.PRNGKey(3)
    a = np.asarray(sample_rates_bps(k, p, d, 1e8))
    b = np.asarray(sample_rates_bps(k, p, d, 1e8))
    c = np.asarray(sample_rates_bps(jax.random.fold_in(k, 1), p, d, 1e8))
    np.testing.assert_array_equal(a, b)      # same key -> bitwise same
    assert np.std(a) > 0                     # fading across clients
    assert not np.array_equal(a, c)          # fresh key -> fresh draw


# ---------------------------------------------------------------------------
# availability
# ---------------------------------------------------------------------------

def test_availability_masks_valid_and_bursty():
    n, rounds = 8, 40
    p = AvailabilityParams(kind="markov", p_drop=0.3, p_recover=0.3)
    key = jax.random.PRNGKey(0)
    up = availability_init(n)
    trace = []
    for r in range(rounds):
        mask, up = availability_step(jax.random.fold_in(key, r), up, p)
        assert float(mask.sum()) >= 1.0      # never a dead fleet
        trace.append(np.asarray(mask))
    trace = np.stack(trace)
    assert 0.0 < trace.mean() < 1.0          # both states visited
    # burstiness: a down client stays down with prob 1 - p_recover = 0.7,
    # far above its ~0.45 stationary up-probability's complement persistence
    down = trace[:-1] == 0
    stays_down = ((trace[1:] == 0) & down).sum() / max(down.sum(), 1)
    assert stays_down > 0.5


def test_availability_full_is_identity():
    p = AvailabilityParams(kind="full")
    up = availability_init(3)
    mask, up2 = availability_step(jax.random.PRNGKey(0), up, p)
    np.testing.assert_array_equal(np.asarray(mask), np.ones(3))
    np.testing.assert_array_equal(np.asarray(up2), np.ones(3))


# ---------------------------------------------------------------------------
# mission rollout
# ---------------------------------------------------------------------------

def test_single_uav_hover_rollout_is_plan_tour():
    rng = np.random.RandomState(0)
    coords = rng.uniform(0, 400, size=(6, 2))
    base = np.zeros(2)
    tl = rollout_mission(coords, base, hover_s_per_stop=30.0,
                         comm_s_per_stop=10.0)
    ref = plan_tour(coords, base, hover_s_per_stop=30.0, comm_s_per_stop=10.0)
    r = tl.routes[0].tour
    assert r.order == ref.order
    assert r.e_first == ref.e_first and r.e_per_round == ref.e_per_round
    assert tl.rounds == ref.rounds and tl.e_return_j == ref.e_return
    assert tl.uav_energy_j(0) == ref.e_first
    assert tl.uav_energy_j(1) == ref.e_per_round
    # hover serves overhead: every slant distance is the flight altitude
    np.testing.assert_allclose(tl.serve_dist_m, DEFAULT_UAV.altitude)
    # battery decreases monotonically and never goes negative
    assert np.all(np.diff(tl.battery_j[0]) < 0)
    assert tl.battery_j[0, -1] >= tl.e_return_j - 1e-6  # return leg reserved
    # serve windows are ordered along the tour and fit the round
    starts = tl.hover_start_s[np.asarray(ref.order)]
    assert np.all(np.diff(starts) > 0)
    assert starts[-1] + 40.0 <= tl.round_duration_s + 1e-6


def test_multi_uav_partitions_fleet_and_budgets():
    rng = np.random.RandomState(1)
    coords = rng.uniform(0, 600, size=(9, 2))
    tl = rollout_mission(coords, np.zeros(2), num_uavs=3)
    ids = sorted(i for r in tl.routes for i in r.client_ids)
    assert ids == list(range(9))             # every client exactly once
    assert len(tl.routes) == 3
    single = rollout_mission(coords, np.zeros(2))
    # splitting the tour shortens each UAV's cycle -> more budgeted rounds
    assert tl.rounds >= single.rounds
    assert tl.round_duration_s <= single.round_duration_s
    # fleet bill is the sum of per-UAV tour energies
    assert tl.e_per_round_j == pytest.approx(
        sum(r.tour.e_per_round for r in tl.routes))


def test_relay_mode_varies_serve_distance():
    rng = np.random.RandomState(2)
    coords = rng.uniform(0, 500, size=(6, 2))
    tl = rollout_mission(coords, np.zeros(2), serve_mode="relay")
    # distances vary across clients and exceed the overhead-hover slant
    assert np.std(tl.serve_dist_m) > 0
    assert np.all(tl.serve_dist_m >= DEFAULT_UAV.altitude - 1e-9)
    # the parked relay spends no per-round movement energy
    assert tl.routes[0].tour.tour_length == 0.0


# ---------------------------------------------------------------------------
# the degenerate-scenario equivalence gate
# ---------------------------------------------------------------------------

def test_degenerate_scenario_reproduces_campaign_spec_records():
    """Constant channel + full availability + one hovering UAV, run through
    the ENTIRE sim path, must reproduce the idealized campaign_spec records
    — the paper numbers are a special case of the subsystem."""
    cfg = CampaignConfig(model="tinycnn", num_clients=4, global_rounds=2,
                         local_steps=2, batch_size=4,
                         num_classes=NUM_CLASSES, classes_per_client=2,
                         image_size=16)
    plan_ref = compile_experiment(campaign_spec(cfg))
    _, recs_ref = plan_ref.run()
    plan_sim = compile_experiment(campaign_spec(
        dataclasses.replace(cfg, scenario=degenerate_scenario())))
    _, recs_sim = plan_sim.run()
    assert plan_sim.timeline is not None     # the sim path actually ran
    assert plan_sim.tour.order == plan_ref.tour.order
    assert len(recs_sim) == len(recs_ref) > 0
    for a, b in zip(recs_ref, recs_sim):
        da, db = a.to_dict(), b.to_dict()
        for field, va in da.items():
            if isinstance(va, float) and np.isfinite(va):
                assert db[field] == pytest.approx(va, rel=1e-12), field
            else:
                assert db[field] == va, field


def test_stochastic_scenario_changes_bill_not_bytes():
    """An a2g channel re-bills link time/energy per round; wire bytes and
    compute energy are rate-independent and must not move."""
    plan0 = compile_experiment(BASE)
    _, recs0 = plan0.run()
    scn = ScenarioSpec(channel=ChannelParams(kind="a2g"), seed=3)
    plan1 = compile_experiment(dataclasses.replace(BASE, scenario=scn))
    _, recs1 = plan1.run()
    times0 = [r.link_time_s for r in recs0]
    times1 = [r.link_time_s for r in recs1]
    assert times0 != times1                  # the channel moved the bill
    for a, b in zip(recs0, recs1):
        assert a.link_bytes == b.link_bytes
        assert a.client_energy_j == b.client_energy_j
        assert b.link_time_s > 0


def test_availability_trace_drives_dropout_masks():
    spec = dataclasses.replace(
        BASE, global_rounds=4,
        scenario=ScenarioSpec(availability=AvailabilityParams(
            kind="markov", p_drop=0.6, p_recover=0.4), seed=5))
    plan = compile_experiment(spec)
    _, recs = plan.run()
    actives = [r.active_clients for r in recs]
    assert min(actives) < 4 and min(actives) >= 1
    full = compile_experiment(BASE)
    _, frecs = full.run()
    for r, fr in zip(recs, frecs):
        if r.active_clients < 4:
            assert r.client_energy_j < fr.client_energy_j


def test_scenario_validation_errors():
    with pytest.raises(ValueError):          # a2g channel needs a mission
        compile_experiment(dataclasses.replace(
            BASE, mission=None,
            scenario=ScenarioSpec(channel=ChannelParams(kind="a2g"))))
    with pytest.raises(ValueError):          # multi-UAV needs a mission
        compile_experiment(dataclasses.replace(
            BASE, mission=None, scenario=ScenarioSpec(num_uavs=2)))
    with pytest.raises(ValueError):          # availability needs a fleet
        compile_experiment(dataclasses.replace(
            BASE, engine=EngineSpec(kind="sl", client_axis="scan"),
            scenario=ScenarioSpec(availability=AvailabilityParams(
                kind="bernoulli", p_drop=0.5))))
    with pytest.raises(ValueError):          # one straggler process only
        compile_experiment(dataclasses.replace(
            BASE, clients=ClientSpec(num_clients=4, dropout_rate=0.5),
            scenario=ScenarioSpec(availability=AvailabilityParams(
                kind="bernoulli", p_drop=0.5))))
    with pytest.raises(ValueError):          # more UAVs than clients
        compile_experiment(dataclasses.replace(
            BASE, scenario=ScenarioSpec(num_uavs=9)))
    with pytest.raises(ValueError):
        ChannelParams(kind="fso").validate()
    with pytest.raises(ValueError):
        AvailabilityParams(kind="weather").validate()


# ---------------------------------------------------------------------------
# Monte-Carlo rollouts
# ---------------------------------------------------------------------------

def _stoch_plan(rounds=2):
    return compile_experiment(dataclasses.replace(
        BASE, global_rounds=rounds, scenario=STOCH))


def test_monte_carlo_bitwise_reproducible():
    plan = _stoch_plan()
    a = run_monte_carlo(plan, 3, rounds=2, seed=11)
    b = run_monte_carlo(plan, 3, rounds=2, seed=11)
    for k in a.stacks:
        np.testing.assert_array_equal(a.stacks[k], b.stacks[k], err_msg=k)
    c = run_monte_carlo(plan, 3, rounds=2, seed=12)
    assert any(not np.array_equal(a.stacks[k], c.stacks[k])
               for k in a.stacks)            # a different sweep seed differs


def test_monte_carlo_vmap_matches_python_loop():
    plan = _stoch_plan()
    v = run_monte_carlo(plan, 4, rounds=2, mode="vmap", seed=0)
    l = run_monte_carlo(plan, 4, rounds=2, mode="loop", seed=0)
    assert v.stacks["loss"].shape == (4, 2)
    for k in v.stacks:
        np.testing.assert_allclose(v.stacks[k], l.stacks[k], rtol=1e-5,
                                   atol=1e-6, err_msg=k)
    # seeds genuinely differ (channel/availability draws are per seed)
    assert np.std(v.stacks["link_time_s"].sum(axis=1)) > 0


def test_monte_carlo_seed_zero_replays_the_plan():
    """Sweep seed i IS scenario realization scn.seed + base + i: seed 0 of
    a base-0 sweep draws the exact mask/rate streams plan.run() draws, so
    one MC outlier can be replayed through the plan for inspection."""
    plan = _stoch_plan(rounds=3)
    _, recs = plan.run(with_eval=False)
    mc = run_monte_carlo(plan, 2, rounds=3, seed=0)
    for r, rec in enumerate(recs):
        assert int(mc.stacks["active_clients"][0, r]) == rec.active_clients
        np.testing.assert_allclose(mc.stacks["loss"][0, r], rec.loss,
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(mc.stacks["link_time_s"][0, r],
                                   rec.link_time_s, rtol=1e-5)
        np.testing.assert_allclose(mc.stacks["client_energy_j"][0, r],
                                   rec.client_energy_j, rtol=1e-5)
    # ... and a replaced scenario seed shifts which realization seed 0 is
    plan2 = compile_experiment(dataclasses.replace(
        BASE, global_rounds=3,
        scenario=dataclasses.replace(STOCH, seed=STOCH.seed + 1)))
    mc2 = run_monte_carlo(plan2, 1, rounds=3, seed=0)
    np.testing.assert_allclose(mc.stacks["link_time_s"][1],
                               mc2.stacks["link_time_s"][0], rtol=1e-6)


def test_monte_carlo_records_and_summary():
    plan = _stoch_plan()
    mc = run_monte_carlo(plan, 3, rounds=2)
    recs = mc.records_for_seed(1)
    assert len(recs) == 2
    assert recs[0].engine == plan.engine_label
    assert recs[0].uav_energy_j == pytest.approx(plan.timeline.e_first_j)
    assert np.isnan(recs[0].accuracy)        # no held-out eval inside vmap
    s = mc.summary()
    assert s["num_seeds"] == 3
    assert s["final_loss"]["min"] <= s["final_loss"]["mean"] \
        <= s["final_loss"]["max"]
    assert s["total_energy_j"]["mean"] > 0


def test_monte_carlo_rejects_hetero_plans():
    from repro.core.energy import HardwareProfile, JETSON_AGX_ORIN
    mcu = HardwareProfile("mcu", fp32_tflops=0.02, mem_bw_gbs=2.0,
                          tensor_tflops=0.04, cpu_passmark=400.0, power_w=2.0)
    plan = compile_experiment(dataclasses.replace(
        BASE, cut_policy=CutPolicy(mode="adaptive"),
        clients=ClientSpec(num_clients=4,
                           edge_profiles=(JETSON_AGX_ORIN, mcu))))
    if len(set(plan.cut_of_client)) > 1:     # hetero buckets actually formed
        with pytest.raises(ValueError, match="hetero"):
            run_monte_carlo(plan, 2, rounds=1)
    else:                                    # degenerate profiles: still runs
        run_monte_carlo(plan, 1, rounds=1)


# ---------------------------------------------------------------------------
# spec-reachable satellites: dirichlet partition + transformer family
# ---------------------------------------------------------------------------

def test_dirichlet_partition_spec_reachable():
    spec = dataclasses.replace(
        BASE, mission=None,
        data=DataSpec(kind="synthetic", image_size=16, partition="dirichlet",
                      dirichlet_alpha=0.2))
    plan = compile_experiment(spec)
    sizes = [len(p) for p in plan.parts]
    assert sum(sizes) == len(plan.y_train)
    assert all(s >= 1 for s in sizes)        # min_size floor held
    assert np.std(sizes) > 0                 # alpha=0.2 actually skews
    _, recs = plan.run()
    assert all(np.isfinite(r.loss) for r in recs)
    # alpha sweeps are one-field edits
    smooth = compile_experiment(dataclasses.replace(
        spec, data=dataclasses.replace(spec.data, dirichlet_alpha=100.0)))
    assert np.std([len(p) for p in smooth.parts]) <= np.std(sizes)


def test_dirichlet_min_size_floor():
    from repro.data.partition import partition_dirichlet
    labels = np.repeat(np.arange(4), 25)
    parts = partition_dirichlet(labels, 10, alpha=0.05, seed=0, min_size=2)
    assert all(len(p) >= 2 for p in parts)
    assert sorted(np.concatenate(parts).tolist()) == list(range(100))
    with pytest.raises(ValueError):
        partition_dirichlet(labels, 10, alpha=0.05, min_size=11)


def _tf_spec(**kw):
    from repro.configs.base import ArchConfig
    arch = ArchConfig(name="tiny-attn", family="dense", n_layers=4,
                      d_model=16, n_heads=2, n_kv_heads=2, d_ff=32, vocab=64)
    base = ExperimentSpec(
        model=ModelSpec(family="transformer", arch=arch),
        data=DataSpec(kind="tokens", seq_len=16, partition="iid"),
        clients=ClientSpec(num_clients=4),
        cut_policy=CutPolicy(mode="fraction", fraction=0.5),
        engine=EngineSpec(kind="sl", client_axis="vmap"),
        global_rounds=3, local_steps=2, batch_size=4)
    return dataclasses.replace(base, **kw)


def test_transformer_spec_trains_and_bills():
    plan = compile_experiment(_tf_spec())
    assert plan.cut_of_client == [2] * 4
    _, recs = plan.run()
    assert recs[-1].loss < recs[0].loss      # the LM actually trains
    assert recs[0].link_bytes > 0 and recs[0].client_energy_j > 0
    assert 0.0 <= recs[-1].accuracy <= 1.0
    # int8 residual-stream link: ~3.2x fewer wire bytes at d_model=16
    plan8 = compile_experiment(_tf_spec(
        link_policy=LinkPolicy(compress="int8")))
    _, recs8 = plan8.run()
    assert recs8[0].link_bytes < recs[0].link_bytes / 3


def test_transformer_scan_engine_and_validation():
    _, recs = compile_experiment(_tf_spec(
        engine=EngineSpec(kind="sl", client_axis="scan"),
        global_rounds=2)).run()
    assert all(np.isfinite(r.loss) for r in recs)
    with pytest.raises(ValueError):          # needs an ArchConfig
        compile_experiment(_tf_spec(model=ModelSpec(family="transformer")))
    with pytest.raises(ValueError):          # FL is a CNN-family path
        compile_experiment(_tf_spec(
            engine=EngineSpec(kind="fl", client_axis="vmap")))
    with pytest.raises(ValueError):          # tokens carry no label classes
        compile_experiment(_tf_spec(
            data=DataSpec(kind="tokens", partition="classes")))
    with pytest.raises(ValueError):          # tokens are transformer-only
        compile_experiment(dataclasses.replace(
            BASE, mission=None,
            data=DataSpec(kind="tokens", partition="iid")))
    with pytest.raises(ValueError):          # unknown data kind
        compile_experiment(dataclasses.replace(
            BASE, mission=None, data=DataSpec(kind="token")))
    with pytest.raises(ValueError, match="server_mesh"):
        compile_experiment(_tf_spec(
            engine=EngineSpec(kind="sl", client_axis="vmap",
                              server_mesh=(2, 1))))
