"""Model components: attention chunks, MoE dispatch, SSM consistency, CNNs."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.core.split import apply_stages, init_stages
from repro.models.attention import (chunked_causal_attention, decode_attention,
                                    gqa_repeat, reference_attention)
from repro.models.cnn import CNN_BUILDERS
from repro.models.moe import moe_apply, moe_init, moe_ref
from repro.models.ssm import (mamba_apply, mamba_empty_state, mamba_init,
                              mamba_step, rwkv6_apply, rwkv6_empty_state,
                              rwkv6_init, rwkv6_step)
from repro.models.transformer import (decode_state_init, model_decode_step,
                                      model_forward, model_init)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([(64, 32), (32, 64), (128, 128)]),
       st.sampled_from([None, 17, 64]),
       st.integers(0, 10**6))
def test_chunked_attention_property(blocks, window, seed):
    qb, kb = blocks
    B, S, H, KH, D = 1, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    out = chunked_causal_attention(q, k, v, window=window, q_block=qb,
                                   kv_block=kb)
    ref = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_decode_attention_matches_reference():
    B, S, H, KH, D = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    n = 40
    out = decode_attention(q, k, v, jnp.asarray(n))
    kk = gqa_repeat(k[:, :n], 2)
    vv = gqa_repeat(v[:, :n], 2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(D)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(4, 2), (8, 2), (4, 1), (8, 6)]),
       st.booleans(), st.integers(0, 10**6))
def test_moe_matches_dense_oracle(ek, shared, seed):
    E, K = ek
    B, S, D, F = 2, 8, 16, 32
    key = jax.random.PRNGKey(seed)
    p = moe_init(key, D, E, F, K, n_shared=2 if shared else 0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D)) * 0.5
    y, aux = moe_apply(p, x, top_k=K, capacity_factor=float(E))  # no drops
    yr, auxr = moe_ref(p, x, top_k=K)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    assert float(aux) == pytest.approx(float(auxr))


def test_moe_capacity_drops_are_bounded():
    """With tight capacity, outputs stay finite and dropped tokens pass
    through with zero expert contribution (residual handled by caller)."""
    E, K = 4, 2
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 16, E, 32, K)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 16))
    y, _ = moe_apply(p, x, top_k=K, capacity_factor=0.5)
    assert bool(jnp.isfinite(y).all())


def test_moe_router_gradient_flows():
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 16, 4, 32, 2)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 16))
    g = jax.grad(lambda pp: moe_apply(pp, x, top_k=2)[0].sum())(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0


def test_moe_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux = E * E*(1/E)*(1/E) ... = 1."""
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 16, 4, 32, 1)
    p = jax.tree_util.tree_map(jnp.zeros_like, p)  # zero router -> uniform
    x = jax.random.normal(key, (1, 64, 16))
    _, aux = moe_apply(p, x, top_k=1)
    assert float(aux) == pytest.approx(1.0, rel=0.3)


# ---------------------------------------------------------------------------
# SSM chunk/step consistency
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64]), st.integers(0, 10**6))
def test_rwkv_chunk_consistency(b, d_factor, seed):
    D = 2 * d_factor
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, 24, D)) * 0.3
    p = rwkv6_init(key, D, head_size=16)
    full, _ = rwkv6_apply(p, x, head_size=16)
    y1, st1 = rwkv6_apply(p, x[:, :8], head_size=16)
    y2, _ = rwkv6_apply(p, x[:, 8:], st1, head_size=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=1e-4)


def test_mamba_step_equals_scan():
    key = jax.random.PRNGKey(0)
    D = 32
    x = jax.random.normal(key, (2, 12, D)) * 0.3
    p = mamba_init(key, D)
    full, _ = mamba_apply(p, x)
    st = mamba_empty_state(2, D)
    ys = []
    for t in range(12):
        y, st = mamba_step(p, x[:, t:t + 1], st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(full), atol=1e-5)


# ---------------------------------------------------------------------------
# CNNs (paper backbones)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [
    pytest.param(n, marks=pytest.mark.slow) if n == "googlenet" else n
    for n in sorted(CNN_BUILDERS)])  # googlenet: slowest eager forward
def test_cnn_forward_shapes(name):
    stages = CNN_BUILDERS[name](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    x = jax.random.uniform(key, (2, 64, 64, 3))
    out = apply_stages(stages, params, x)
    assert out.shape == (2, 12)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# full-capacity MoE decode == forward (transformer level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deepseek-moe-16b"])
def test_moe_decode_consistency_full_capacity(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    ref, _ = model_forward(cfg, params, {"tokens": tokens})
    state = decode_state_init(cfg, 2, 8)
    outs = []
    for t in range(8):
        lg, state = model_decode_step(cfg, params, state, tokens[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(ref), atol=1e-4)
