"""Deliverable (f): per-architecture smoke tests.

For each assigned architecture, instantiate the REDUCED variant of the same
family (<=2 layers/super-blocks, d_model<=256, <=4 experts) and run one
forward and one train step on CPU, asserting output shapes and no NaNs.
Decode smoke for the decode-capable archs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.transformer import (decode_state_init, default_cut_layer,
                                      lm_loss, model_decode_step,
                                      model_forward, model_init, vocab_padded)
from repro.optim import adamw, apply_updates

ALL_ARCHS = sorted(ARCHS)


# the slowest CPU compiles (hybrid scan blocks, encoder-decoder, 480b MoE)
# keep their smokes for the slow job; every family still has default-run
# coverage through the remaining archs
def _mark_heavy(archs, heavy):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in archs]


def make_batch(cfg, key, b=2, s=16, labels=True):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "patch_embed":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.frontend_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (b, cfg.enc_seq_len, cfg.d_model))
    if labels:
        batch["labels"] = batch["tokens"]
    return batch


def test_registry_complete():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "vlm", "audio", "moe", "hybrid", "ssm"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_limits(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 2 * max(r.attn_period, 1)
    assert r.d_model <= 512
    assert r.n_experts <= 4


@pytest.mark.parametrize("arch", _mark_heavy(ALL_ARCHS,
                                             {"jamba-1.5-large-398b"}))
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key)
    b, s = 2, 16
    batch = make_batch(cfg, key, b, s)
    logits, aux = model_forward(cfg, params, batch)
    s_out = s + (cfg.frontend_tokens if cfg.frontend == "patch_embed" else 0)
    assert logits.shape == (b, s_out, vocab_padded(cfg))
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


_TRAIN_SMOKE_ARCHS = _mark_heavy(
    ALL_ARCHS, {"jamba-1.5-large-398b", "whisper-tiny", "arctic-480b"})


@pytest.mark.parametrize("arch", _TRAIN_SMOKE_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    cut = default_cut_layer(cfg, 0.15)
    params = model_init(cfg, key, cut_layer=cut)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch, cut_layer=cut), has_aux=True)(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)

    assert bool(jnp.isfinite(loss))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_params),
        jax.tree_util.tree_leaves(params)))
    assert delta > 0
    # no grad is NaN
    for g in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("arch", _mark_heavy(
    [a for a in ALL_ARCHS if not ARCHS[a].enc_dec],
    {"jamba-1.5-large-398b"}))
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = model_init(cfg, key)
    b, max_len = 2, 8
    state = decode_state_init(cfg, b, max_len)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, state2 = model_decode_step(cfg, params, state, tok,
                                       jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, 1, vocab_padded(cfg))
    assert bool(jnp.isfinite(logits).all())
    # state changed (cache write happened)
    changed = any(
        float(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)).sum()) > 0
        for a, b_ in zip(jax.tree_util.tree_leaves(state2),
                         jax.tree_util.tree_leaves(state)))
    assert changed


def test_whisper_decode_with_cross_cache():
    cfg = get_config("whisper-tiny").reduced()
    key = jax.random.PRNGKey(3)
    params = model_init(cfg, key)
    b = 2
    state = decode_state_init(cfg, b, 8)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, _ = model_decode_step(cfg, params, state, tok,
                                  jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, 1, vocab_padded(cfg))
    assert bool(jnp.isfinite(logits).all())


def test_swa_config_respected():
    cfg = get_config("h2o-danube-1.8b")
    assert cfg.swa_window == 4096
    r = cfg.reduced()
    assert r.swa_window and r.swa_window <= 32
