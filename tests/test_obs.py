"""``repro.obs`` — run-wide telemetry behind every compiled plan.

The contract under test:

  * disabled telemetry is genuinely free-ish: ``obs=None`` and a disabled
    ``ObsConfig`` share the no-op code path (shared null span, no files),
    and the disabled path adds < 2% to a measured 20-round run;
  * spans nest, fence device work into ``sync_s``, and emit clean
    hierarchical paths (no duplicated segments);
  * the JSONL sink buffers, the manifest merges, ``plan``/``sweep``
    entries append;
  * ``RoundRecord.to_dict`` is JSON-round-trippable (numpy scalars and
    cohort tuples coerced);
  * the recompile counter demonstrably fires on a forced shape change;
  * an obs-enabled ``plan.run`` writes a run dir whose phase breakdown
    covers >= 95% of the root spans' wall clock, renders via
    ``tools/obs_report.py``, and decomposes UAV missions into
    travel/hover/comm dwell on the simulated clock;
  * Monte-Carlo sweeps stream ``mc/*`` spans + a ``sweep`` manifest entry
    without changing ``wall_s`` semantics;
  * the perf trend gate warns (not KeyError) on variants missing from the
    latest commit and passes vacuously on single-commit logs.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, MissionSpec, ModelSpec,
                       compile_experiment)
from repro.api.records import RoundRecord
from repro.obs import (NULL_OBS, Obs, ObsConfig, fenced, host_rss_bytes,
                       pytree_bytes, time_fenced)
from repro.obs.gauges import RecompileCounter, global_counter
from repro.obs.profiler import ProfilerCapture
from repro.obs.sink import JsonlSink, NullSink, json_default
from repro.obs.timeline import NULL_SPAN, Timeline

NUM_CLASSES = 4

BASE = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
    data=DataSpec(kind="synthetic", image_size=16, classes_per_client=2),
    clients=ClientSpec(num_clients=4),
    cut_policy=CutPolicy(mode="fraction", fraction=0.4),
    engine=EngineSpec(kind="sl", client_axis="vmap"),
    global_rounds=2, local_steps=2, batch_size=4)


class ListSink:
    run_dir = None

    def __init__(self):
        self.events = []
        self.manifest = {}

    def emit(self, event):
        self.events.append(event)

    def write_manifest(self, fields):
        self.manifest.update(fields)

    def flush(self):
        pass

    def close(self):
        pass


def _load_events(run_dir):
    with open(os.path.join(run_dir, "events.jsonl")) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# records: JSON-serializable to_dict (satellite 1)
# ---------------------------------------------------------------------------

def test_round_record_to_dict_json_round_trip():
    rec = RoundRecord(
        round=np.int64(3), loss=np.float32(0.5),
        accuracy=np.float64("nan"), link_bytes=np.float32(1e6),
        link_time_s=0.1, link_energy_j=np.float64(2.0),
        client_energy_j=jnp.float32(3.0), server_energy_j=4.0,
        uav_energy_j=5.0, active_clients=np.int32(4),
        engine="sl/vmap",
        cohort_pids=tuple(np.asarray([7, 9], np.int64)))
    d = rec.to_dict()
    s = json.dumps(d)                      # must not raise on numpy scalars
    back = json.loads(s)
    assert back["round"] == 3
    assert isinstance(back["round"], int)
    assert back["cohort_pids"] == [7, 9]
    assert back["engine"] == "sl/vmap"
    assert abs(back["loss"] - 0.5) < 1e-6
    assert back["accuracy"] != back["accuracy"]        # NaN survives as NaN
    for v in d.values():                   # every leaf is a Python native
        if isinstance(v, tuple):
            assert all(isinstance(x, int) for x in v)
        else:
            assert not hasattr(v, "dtype")


# ---------------------------------------------------------------------------
# timeline
# ---------------------------------------------------------------------------

def test_span_nesting_paths_and_depth():
    sink = ListSink()
    tl = Timeline(sink)
    with tl.span("run", rounds=2):
        with tl.span("round", round=0):
            with tl.span("round/execute"):
                pass
        with tl.span("round", round=1):
            pass
    evs = sink.events
    assert [e["path"] for e in evs] == \
        ["run/round/execute", "run/round", "run/round", "run"]
    assert [e["depth"] for e in evs] == [2, 1, 1, 0]
    # hierarchical names splice without duplicating shared segments
    assert "round/round" not in evs[0]["path"]
    assert evs[0]["name"] == "round/execute"
    assert evs[-1]["rounds"] == 2
    # children are contained in the parent's wall clock
    assert evs[1]["dur_s"] >= evs[0]["dur_s"]
    assert evs[-1]["dur_s"] >= evs[1]["dur_s"] + evs[2]["dur_s"] - 1e-6


def test_span_fence_books_sync_and_note():
    sink = ListSink()
    tl = Timeline(sink)
    with tl.span("execute") as sp:
        y = jnp.dot(jnp.ones((64, 64)), jnp.ones((64, 64)))
        out = sp.fence(y)
        sp.note(flavor="matmul")
    ev = sink.events[0]
    assert out is y
    assert 0.0 <= ev["sync_s"] <= ev["dur_s"]
    assert ev["flavor"] == "matmul"
    # host-only values fence as no-ops
    with tl.span("host") as sp:
        assert sp.fence({"a": 1}) == {"a": 1}


def test_fenced_helpers():
    out, wall = fenced(lambda: jnp.arange(8).sum())
    assert int(out) == 28 and wall > 0
    calls = []
    wall = time_fenced(lambda: calls.append(1) or jnp.ones(4), repeats=5)
    assert len(calls) == 5 and wall > 0


def test_disabled_timeline_hands_out_shared_null_span():
    tl = Timeline(ListSink(), enabled=False)
    sp = tl.span("anything", round=3)
    assert sp is NULL_SPAN and tl.span("other") is NULL_SPAN
    with sp as s:
        assert s.fence(5) == 5
        s.note(ignored=True)


# ---------------------------------------------------------------------------
# sink
# ---------------------------------------------------------------------------

def test_jsonl_sink_buffers_and_manifest_appends(tmp_path):
    run_dir = str(tmp_path / "run")
    sink = JsonlSink(run_dir, buffer=3)
    ev_path = os.path.join(run_dir, "events.jsonl")
    sink.emit({"ev": "note", "i": 0})
    sink.emit({"ev": "note", "i": 1})
    assert not os.path.exists(ev_path)          # buffered, not yet on disk
    sink.emit({"ev": "note", "i": 2})           # buffer full -> flushed
    assert len(open(ev_path).readlines()) == 3
    sink.emit({"ev": "note", "i": 3, "x": np.float32(1.5)})
    sink.close()                                # close flushes the tail
    lines = [json.loads(line) for line in open(ev_path)]
    assert [e["i"] for e in lines] == [0, 1, 2, 3]
    assert lines[-1]["x"] == 1.5                # numpy coerced by default=

    sink.write_manifest({"a": 1, "plan": {"model": "m1"}})
    sink.write_manifest({"b": 2, "plan": {"model": "m2"},
                         "sweep": {"num_seeds": 4}})
    man = json.load(open(os.path.join(run_dir, "manifest.json")))
    assert man["a"] == 1 and man["b"] == 2
    assert [p["model"] for p in man["plans"]] == ["m1", "m2"]
    assert man["sweeps"] == [{"num_seeds": 4}]


def test_json_default_coercions():
    assert json_default(np.float32(2.5)) == 2.5
    assert json_default(np.arange(3)) == [0, 1, 2]
    assert json_default(object()).startswith("<object")


# ---------------------------------------------------------------------------
# gauges
# ---------------------------------------------------------------------------

def test_pytree_bytes_and_rss():
    tree = {"a": jnp.zeros((4, 4), jnp.float32),
            "b": (np.zeros(10, np.int64), "not-an-array", 3.0)}
    assert pytree_bytes(tree) == 4 * 4 * 4 + 10 * 8
    assert pytree_bytes(None) == 0
    assert host_rss_bytes() > 0


def test_recompile_counter_fires_on_shape_change():
    counter = global_counter()
    if not counter.available:
        pytest.skip("jax monitoring hooks unavailable in this jax build")

    @jax.jit
    def f(x):
        return (x * 2.0).sum()

    # unique prime-ish shapes so earlier tests' compile cache can't absorb
    # them; each new shape forces a fresh backend compile
    c0, s0 = counter.snapshot()
    jax.block_until_ready(f(jnp.zeros((3, 41))))
    c1, s1 = counter.snapshot()
    assert c1 > c0 and s1 >= s0
    jax.block_until_ready(f(jnp.zeros((3, 43))))   # forced shape change
    c2, _ = counter.snapshot()
    assert c2 > c1
    jax.block_until_ready(f(jnp.zeros((3, 43))))   # cache hit: no compile
    c3, _ = counter.snapshot()
    assert c3 == c2


def test_recompile_counter_install_uninstall():
    c = RecompileCounter()
    c.install()
    if c.available:
        n0 = c.snapshot()[0]
        jax.block_until_ready(jax.jit(lambda x: x + 1)(jnp.zeros(37)))
        assert c.snapshot()[0] > n0
    c.uninstall()
    assert not c.available


# ---------------------------------------------------------------------------
# Obs facade
# ---------------------------------------------------------------------------

def test_null_obs_is_shared_and_writes_nothing(tmp_path):
    assert Obs.ensure(None) is NULL_OBS
    assert not NULL_OBS and NULL_OBS.run_dir is None
    assert isinstance(NULL_OBS.sink, NullSink)
    assert NULL_OBS.span("x") is NULL_SPAN
    NULL_OBS.event("note", x=1)
    NULL_OBS.gauge(0, engine_state={"w": jnp.zeros(4)})
    NULL_OBS.record(RoundRecord(0, 0., 0., 0., 0., 0., 0., 0., 0.))
    NULL_OBS.manifest(a=1)
    NULL_OBS.round_started(0)
    NULL_OBS.round_finished(0)
    NULL_OBS.flush()
    assert NULL_OBS.compiles_total() == 0
    assert list(tmp_path.iterdir()) == []
    # a disabled config behaves identically (and is its own instance)
    off = Obs.ensure(ObsConfig(enabled=False))
    assert not off and off.span("x") is NULL_SPAN and off.run_dir is None


def test_obs_ensure_normalization(tmp_path):
    cfg = ObsConfig(run_root=str(tmp_path), run_id="r1", gauge_every=2)
    obs = Obs.ensure(cfg)
    assert obs and obs.run_dir == str(tmp_path / "r1")
    assert Obs.ensure(obs) is obs
    obs.gauge(0, tally=1)
    obs.gauge(1, tally=1)      # throttled: gauge_every=2 skips odd rounds
    obs.gauge(2, tally=1)
    obs.close()
    gauges = [e for e in _load_events(obs.run_dir) if e["ev"] == "gauge"]
    assert [g["round"] for g in gauges] == [0, 2]
    assert all(g["rss_bytes"] > 0 for g in gauges)
    man = json.load(open(os.path.join(obs.run_dir, "manifest.json")))
    assert man["run_id"] == "r1" and man["jax_version"] == jax.__version__


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_profiler_capture_window(tmp_path):
    cap = ProfilerCapture((1, 2), str(tmp_path / "prof"))
    assert cap.status == "armed"
    cap.round_started(0)
    assert cap.status == "armed"               # before the window: idle
    cap.round_started(1)                       # window opens
    cap.round_finished(1)
    cap.round_started(2)
    cap.round_finished(2)                      # window closes
    cap.close()
    # capture is best-effort (profiler availability varies by build): the
    # status line must say what happened either way
    assert cap.status.startswith(("captured", "unavailable", "stop failed"))
    if cap.status.startswith("captured"):
        assert os.path.isdir(str(tmp_path / "prof"))


def test_profiler_validates_window():
    with pytest.raises(ValueError):
        ProfilerCapture((3, 1), "x")
    off = ProfilerCapture(None, "x")
    off.round_started(0)
    off.close()
    assert off.status == "off"


# ---------------------------------------------------------------------------
# plan integration: the acceptance criteria
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mission_run(tmp_path_factory):
    """One obs-enabled mission campaign, shared across assertions."""
    root = str(tmp_path_factory.mktemp("runs"))
    spec = ExperimentSpec(
        model=BASE.model, data=BASE.data, clients=BASE.clients,
        cut_policy=BASE.cut_policy, engine=BASE.engine,
        mission=MissionSpec(farm_acres=100.0),
        global_rounds=3, local_steps=2, batch_size=4)
    plan = compile_experiment(
        spec, obs=ObsConfig(run_root=root, run_id="trun"))
    state, records = plan.run()
    plan.obs.close()
    return plan, records, plan.obs.run_dir


def test_plan_run_writes_run_dir(mission_run):
    plan, records, run_dir = mission_run
    assert sorted(os.listdir(run_dir)) == ["events.jsonl", "manifest.json"]
    man = json.load(open(os.path.join(run_dir, "manifest.json")))
    assert man["backend"] == jax.default_backend()
    assert len(man["plans"]) == 1
    p = man["plans"][0]
    assert p["engine"] == "sl/vmap" and p["num_clients"] == 4
    evs = _load_events(run_dir)
    kinds = {e["ev"] for e in evs}
    assert {"span", "gauge", "record", "mission_span"} <= kinds
    recs = [e for e in evs if e["ev"] == "record"]
    assert [r["round"] for r in recs] == [0, 1, 2]
    # the record stream round-trips the RoundRecord values verbatim
    assert abs(recs[-1]["loss"] - records[-1].loss) < 1e-9
    gauges = [e for e in evs if e["ev"] == "gauge"]
    assert len(gauges) == 3
    assert all(g["state_bytes"] > 0 and g["rss_bytes"] > 0 for g in gauges)
    assert all(g["cohort"] == 0 and g["dropped"] == 0 for g in gauges)


def test_phase_breakdown_covers_95pct(mission_run):
    import obs_report
    _, _, run_dir = mission_run
    manifest, events = obs_report.load_run(run_dir)
    spans = [e for e in events if e["ev"] == "span"]
    # EVERY root phase (compile, run) must be >=95% accounted for by its
    # direct children — the "no unexplained time" acceptance bar
    for root in (e for e in spans if e["depth"] == 0):
        prefix = root["path"] + "/"
        child_s = sum(e["dur_s"] for e in spans
                      if e["depth"] == 1 and e["path"].startswith(prefix))
        assert child_s >= 0.95 * root["dur_s"], root["path"]
    cov, root = obs_report.root_coverage(events)
    assert root is not None and cov >= 0.95
    # and the report renders without touching jax
    lines = obs_report.render(run_dir, manifest, events)
    text = "\n".join(lines)
    assert "coverage" in text and "round/execute" in text
    assert "mission dwell" in text


def test_mission_span_decomposition(mission_run):
    plan, records, run_dir = mission_run
    evs = [e for e in _load_events(run_dir) if e["ev"] == "mission_span"]
    assert {e["name"] for e in evs} == \
        {"mission/travel", "mission/hover", "mission/comm"}
    assert all(e["clock"] == "mission" for e in evs)
    per_round = [e for e in evs if e["round"] == 0]
    travel = [e for e in per_round if e["name"] == "mission/travel"][0]
    hover = [e for e in per_round if e["name"] == "mission/hover"][0]
    comm = [e for e in per_round if e["name"] == "mission/comm"][0]
    n = plan.spec.clients.num_clients
    mission = plan.spec.mission
    assert travel["dur_s"] == pytest.approx(
        plan.tour.tour_length / mission.uav.V, abs=1e-2)
    assert hover["dur_s"] == pytest.approx(n * mission.hover_s_per_stop)
    assert comm["dur_s"] == pytest.approx(n * mission.comm_s_per_stop)
    # legs are laid end-to-end on the simulated clock
    assert hover["t_mission_s"] == pytest.approx(
        travel["t_mission_s"] + travel["dur_s"], abs=1e-2)
    # one (travel, hover, comm) triple per executed round
    assert len(evs) == 3 * len(records)


def test_profile_rounds_capture_via_plan(tmp_path):
    plan = compile_experiment(
        BASE, obs=ObsConfig(run_root=str(tmp_path), run_id="prof",
                            profile_rounds=(0, 0)))
    plan.run(rounds=2, with_eval=False)
    plan.obs.close()
    man = json.load(open(os.path.join(plan.obs.run_dir, "manifest.json")))
    assert man["profiler"].startswith(("captured", "unavailable"))


def test_obs_overhead_under_2pct():
    """The disabled-telemetry hot path (shared NULL_OBS vs a per-plan
    disabled Obs — both pay one branch + no-op span per seam) stays
    within 2% on a measured 20-round run (satellite 6)."""
    spec = ExperimentSpec(
        model=BASE.model, data=BASE.data, clients=BASE.clients,
        cut_policy=BASE.cut_policy, engine=BASE.engine,
        global_rounds=20, local_steps=2, batch_size=4)
    plan_none = compile_experiment(spec)                 # obs=None -> NULL_OBS
    plan_off = compile_experiment(spec, obs=ObsConfig(enabled=False))
    assert plan_none.obs is NULL_OBS and not plan_off.obs

    batches = plan_none.round_batches(plan_none.init())

    def trial(plan):
        st = plan.init()
        _, wall = fenced(lambda: [
            plan.run_round(st, batches, with_eval=False)
            for _ in range(20)])
        return wall

    for plan in (plan_none, plan_off):                   # warmup / compile
        trial(plan)
    # interleave A/B trials so machine-load drift hits both arms equally;
    # min-of-N is the standard low-noise wall estimator
    best = {"none": float("inf"), "off": float("inf")}
    for _ in range(8):
        best["none"] = min(best["none"], trial(plan_none))
        best["off"] = min(best["off"], trial(plan_off))
    ratio = max(best.values()) / min(best.values())
    assert ratio < 1.02, f"disabled-telemetry overhead {ratio:.4f}x"


# ---------------------------------------------------------------------------
# monte-carlo sweeps
# ---------------------------------------------------------------------------

def test_monte_carlo_emits_sweep_telemetry(tmp_path):
    plan = compile_experiment(
        BASE, obs=ObsConfig(run_root=str(tmp_path), run_id="mc"))
    from repro.sim import run_monte_carlo
    mc = run_monte_carlo(plan, 2, rounds=2)      # inherits plan.obs
    plan.obs.close()
    evs = _load_events(plan.obs.run_dir)
    paths = {e["path"] for e in evs if e["ev"] == "span"}
    assert {"mc/setup", "mc/compile", "mc/execute",
            "mc/summarize"} <= paths
    note = [e for e in evs if e["ev"] == "note"
            and e.get("kind") == "monte_carlo"][0]
    assert note["num_seeds"] == 2 and note["mode"] == "vmap"
    assert note["wall_s"] == pytest.approx(mc.wall_s, abs=1e-5)
    man = json.load(open(os.path.join(plan.obs.run_dir, "manifest.json")))
    sweep = man["sweeps"][0]
    assert sweep["seeds"] == [0, 1] and sweep["rounds"] == 2


def test_monte_carlo_without_obs_writes_nothing(tmp_path):
    plan = compile_experiment(BASE)
    from repro.sim import run_monte_carlo
    mc = run_monte_carlo(plan, 2, rounds=2)
    assert mc.rounds == 2 and plan.obs is NULL_OBS
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# reports: trend-gate edges (satellite 3) + obs_report + --runs cross-link
# ---------------------------------------------------------------------------

def _perf_row(commit, variant, sps, case="c4s4b16"):
    return {"commit": commit, "bench": "engine_perf", "model": "tinycnn",
            "case": case, "variant": variant, "steps_per_s": sps}


def test_trend_gate_warns_on_missing_variant(tmp_path, capsys):
    from benchmarks.report import check_perf, missing_variants, perf_trend
    rows = [_perf_row("aaa", "sl_fleet", 100.0),
            _perf_row("aaa", "mc_vmap", 500.0, case="c4s2b8x16"),
            _perf_row("bbb", "sl_fleet", 99.0)]     # mc_vmap gone (shrunk)
    # no KeyError; the shared key still compares
    comps, regs = perf_trend(rows, threshold=0.10)
    assert len(comps) == 1 and regs == []
    assert missing_variants(rows) == ["tinycnn/c4s2b8x16/mc_vmap"]
    path = tmp_path / "engine_perf.json"
    path.write_text(json.dumps(rows))
    assert check_perf(str(path), threshold=0.10) == 0   # warn, don't fail
    out = capsys.readouterr().out
    assert "warning" in out and "mc_vmap" in out


def test_trend_gate_single_commit_vacuous(tmp_path, capsys):
    from benchmarks.report import check_perf, missing_variants
    rows = [_perf_row("aaa", "sl_fleet", 100.0),
            _perf_row("aaa", "fl_vmap", 200.0)]
    assert missing_variants(rows) == []
    path = tmp_path / "engine_perf.json"
    path.write_text(json.dumps(rows))
    assert check_perf(str(path)) == 0                   # passes vacuously
    assert "nothing to compare" in capsys.readouterr().out
    path.write_text("[]")
    assert check_perf(str(path)) == 0


def test_runs_overview_cross_links_gate_commits(tmp_path):
    from benchmarks.report import runs_overview
    root = tmp_path / "runs"
    for rid, commit in [("r-aaa", "aaa"), ("r-bbb", "bbb"),
                        ("r-zzz", "zzz")]:
        d = root / rid
        d.mkdir(parents=True)
        (d / "manifest.json").write_text(json.dumps(
            {"run_id": rid, "git_commit": commit, "created_utc": "t",
             "plans": [{"model": "tinycnn"}]}))
        (d / "events.jsonl").write_text('{"ev": "span"}\n')
    perf = tmp_path / "engine_perf.json"
    perf.write_text(json.dumps([_perf_row("aaa", "sl_fleet", 100.0),
                                _perf_row("bbb", "sl_fleet", 99.0)]))
    rows = runs_overview(str(root), perf_log=str(perf))
    by_id = {r["run_id"]: r for r in rows}
    assert by_id["r-aaa"]["gate_side"] == "prev"
    assert by_id["r-bbb"]["gate_side"] == "cur"
    assert by_id["r-zzz"]["gate_side"] is None
    assert not by_id["r-zzz"]["in_perf_log"]
    assert all(r["events"] == 1 and r["plans"] == 1 for r in rows)


def test_obs_report_spark_and_cli(tmp_path, capsys):
    import obs_report
    assert obs_report.spark([1.0, 2.0, 3.0]) == "▁▄█"
    assert obs_report.spark([float("nan"), 1.0]) == " ▁"
    assert obs_report.spark([]) == ""
    # latest_run_dir picks the newest (ids sort chronologically)
    (tmp_path / "20250101-000000-1").mkdir()
    (tmp_path / "20250102-000000-1").mkdir()
    assert obs_report.latest_run_dir(str(tmp_path)).endswith("0102-000000-1")
