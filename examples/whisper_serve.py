"""Enc-dec serving: "transcribe" synthetic audio frames with whisper-tiny
(reduced). The encoder runs once per request (prefill); the decoder greedy-
decodes against its self-cache + the precomputed cross-attention KV.

    PYTHONPATH=src python examples/whisper_serve.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import modules as nn
from repro.models.transformer import (_norm_apply, build_groups,
                                      decode_state_init, group_apply,
                                      model_decode_step, model_init)

cfg = ARCHS["whisper-tiny"].reduced()
key = jax.random.PRNGKey(0)
params = model_init(cfg, key)
B, GEN = 2, 12

# --- encoder prefill (the conv/mel frontend is a stub: precomputed frames)
frames = 0.02 * jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model))
groups = build_groups(cfg)
enc_x = frames
aux = jnp.zeros((), jnp.float32)
epos = jnp.broadcast_to(jnp.arange(cfg.enc_seq_len, dtype=jnp.int32),
                        (B, cfg.enc_seq_len))
for g, gp in zip(groups, params["groups"]):
    if g.kind == "enc":
        enc_x, aux = group_apply(cfg, g, gp, enc_x, aux, positions=epos,
                                 window=None)
enc_out = _norm_apply(cfg, params["enc_norm"], enc_x)

# --- fill cross-attention KV into the decode state
state = decode_state_init(cfg, B, GEN + 1)
for gi, (g, gp) in enumerate(zip(groups, params["groups"])):
    if g.kind != "xdec":
        continue
    def fill(layer_xattn):
        k = nn.linear_apply(layer_xattn["wk"], enc_out)
        v = nn.linear_apply(layer_xattn["wv"], enc_out)
        return (k.reshape(B, cfg.enc_seq_len, cfg.n_kv_heads, cfg.hd),
                v.reshape(B, cfg.enc_seq_len, cfg.n_kv_heads, cfg.hd))
    ck, cv = jax.vmap(fill)(
        jax.tree_util.tree_map(lambda x: x, params["groups"][gi])["xattn"])
    state[gi]["ck"] = ck
    state[gi]["cv"] = cv

# --- greedy decode
step = jax.jit(lambda p, s, t, pos: model_decode_step(cfg, p, s, t, pos))
tok = jnp.zeros((B, 1), jnp.int32)      # BOS
t0 = time.time()
out = []
for t in range(GEN):
    logits, state = step(params, state, tok, jnp.asarray(t, jnp.int32))
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], -1)[:, None].astype(jnp.int32)
    out.append(tok)
ids = jnp.concatenate(out, 1)
print(f"[whisper] encoded {cfg.enc_seq_len} frames -> decoded {GEN} tokens "
      f"in {time.time()-t0:.2f}s")
print("[whisper] token ids:", ids.tolist())
