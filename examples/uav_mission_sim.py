"""UAV mission simulation: sweep farm sizes and compare deployment +
trajectory strategies end-to-end (devices, tour, energy, rounds, and the
SL communication payload per round for each backbone/split).

    PYTHONPATH=src python examples/uav_mission_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.deployment import (deploy_edge_devices, deploy_gasbac,
                                   deploy_kmeans, uniform_grid_sensors)
from repro.core.link import LinkConfig
from repro.core.trajectory import greedy_tour_plan, plan_tour

print(f"{'farm':>6} {'method':>14} {'devices':>8} {'tour_m':>8} "
      f"{'kJ/round':>9} {'rounds':>7}")
for acres, n in ((100, 25), (140, 36), (200, 49), (250, 64)):
    pts = uniform_grid_sensors(acres, n)
    base = np.zeros(2)
    for name, dep_fn, planner in (
            ("eEnergy-Split", deploy_edge_devices, plan_tour),
            ("K-means", deploy_kmeans, greedy_tour_plan),
            ("GASBAC", deploy_gasbac, greedy_tour_plan)):
        dep = dep_fn(pts, 200.0)
        plan = planner(dep.edge_coords, base)
        print(f"{acres:>5}a {name:>14} {len(dep.edge_indices):>8} "
              f"{plan.tour_length:>8.0f} {plan.e_per_round/1e3:>9.1f} "
              f"{plan.rounds:>7}")

# SL link payload per round: smashed bytes for a ResNet18 SL_15,85 batch
link = LinkConfig(rate_bps=100e6)
smashed = 16 * 16 * 16 * 64 * 4          # B x H x W x C f32 after stem
t_plain = link.transfer_time_s(smashed)
link8 = LinkConfig(rate_bps=100e6, compress="int8")
t_int8 = link8.transfer_time_s(smashed)
print(f"\nSL link per batch: {smashed/1e6:.2f} MB -> "
      f"{t_plain:.2f}s plain / {t_int8:.2f}s int8 "
      f"({t_plain/t_int8:.1f}x faster with the Pallas quant kernel)")
