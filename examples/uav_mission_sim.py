"""UAV mission simulation, fleet edition: deployment/trajectory sweep plus a
full fleet *campaign* — the sharded parallel-SL engine training an 8-client
fleet under the UAV's energy budget, with fp32 vs int8 link modes compared
per round (energy / accuracy / wire bytes).

    PYTHONPATH=src python examples/uav_mission_sim.py

``--monte-carlo N`` additionally sweeps N stochastic scenario seeds (a2g
channel fading/shadowing + markov client availability, 2 relay UAVs) in one
vectorized rollout (``repro.sim.run_monte_carlo``) and prints the spread of
mission energy and final loss across realizations.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402

from repro.api import (ClientSpec, DataSpec, EngineSpec, ExperimentSpec,  # noqa: E402
                       LinkPolicy, MissionSpec, ModelSpec,
                       compile_experiment)

args = argparse.ArgumentParser()
args.add_argument("--monte-carlo", type=int, default=0, metavar="N",
                  help="also sweep N stochastic scenario seeds")
args = args.parse_args()
from repro.core.deployment import (deploy_edge_devices, deploy_gasbac,  # noqa: E402
                                   deploy_kmeans, uniform_grid_sensors)
from repro.core.trajectory import greedy_tour_plan, plan_tour  # noqa: E402

# ---- deployment + trajectory sweep (paper Fig. 2 / Table II) --------------
print(f"{'farm':>6} {'method':>14} {'devices':>8} {'tour_m':>8} "
      f"{'kJ/round':>9} {'rounds':>7}")
for acres, n in ((100, 25), (140, 36), (200, 49), (250, 64)):
    pts = uniform_grid_sensors(acres, n)
    base = np.zeros(2)
    for name, dep_fn, planner in (
            ("eEnergy-Split", deploy_edge_devices, plan_tour),
            ("K-means", deploy_kmeans, greedy_tour_plan),
            ("GASBAC", deploy_gasbac, greedy_tour_plan)):
        dep = dep_fn(pts, 200.0)
        plan = planner(dep.edge_coords, base)
        print(f"{acres:>5}a {name:>14} {len(dep.edge_indices):>8} "
              f"{plan.tour_length:>8.0f} {plan.e_per_round/1e3:>9.1f} "
              f"{plan.rounds:>7}")

# ---- fleet campaign: 8 clients, fp32 vs int8 link -------------------------
# One declarative spec; the link sweep edits ONLY the LinkPolicy field.
base = ExperimentSpec(
    model=ModelSpec(name="tinycnn", num_classes=12),
    data=DataSpec(kind="synthetic", image_size=16),
    clients=ClientSpec(num_clients=8),
    engine=EngineSpec(kind="sl", client_axis="vmap"),     # parallel fleet SL
    mission=MissionSpec(farm_acres=100.0),                # UAV budget caps rounds
    global_rounds=3, local_steps=2, batch_size=8)
print(f"\nfleet campaign: {base.clients.num_clients} clients, "
      f"{base.model.name}, {base.mission.farm_acres:.0f} acres")
results = {}
for mode in ("none", "int8"):
    spec = dataclasses.replace(base, link_policy=LinkPolicy(
        rate_bps=100e6, compress=mode))
    exp = compile_experiment(spec)
    _, records = exp.run()
    results[mode] = records
    if mode == "none":
        tour = exp.tour
        print(f"tour {tour.tour_length:.0f} m, budget affords {tour.rounds} "
              f"rounds ({tour.e_per_round/1e3:.0f} kJ/round)")
print(f"{'link':>5} {'rnd':>4} {'loss':>7} {'acc':>6} {'wire_MB':>8} "
      f"{'link_s':>7} {'link_J':>7} {'client_J':>9} {'uav_kJ':>8}")
for mode, records in results.items():
    for r in records:
        print(f"{mode:>5} {r.round:>4} {r.loss:>7.3f} {r.accuracy:>6.3f} "
              f"{r.link_bytes/1e6:>8.3f} {r.link_time_s:>7.3f} "
              f"{r.link_energy_j:>7.3f} "
              f"{r.client_energy_j:>9.4f} {r.uav_energy_j/1e3:>8.1f}")
b_none, b_int8 = (sum(r.link_bytes for r in results[m])
                  for m in ("none", "int8"))
print(f"\nint8 link moves {b_none/b_int8:.2f}x "
      f"fewer wire bytes than fp32 on the same campaign "
      f"({b_none/1e6:.2f} MB -> {b_int8/1e6:.2f} MB)")

# ---- Monte-Carlo scenario sweep (--monte-carlo N) -------------------------
# The campaign above is ONE realization with an idealized constant-rate
# link. A ScenarioSpec attaches the stochastic environment; run_monte_carlo
# sweeps seeds in one jitted vmapped rollout.
if args.monte_carlo > 0:
    from repro.sim import (AvailabilityParams, ChannelParams, ScenarioSpec,
                           run_monte_carlo)

    scn = ScenarioSpec(
        channel=ChannelParams(kind="a2g"),
        availability=AvailabilityParams(kind="markov", p_drop=0.25,
                                        p_recover=0.5),
        num_uavs=2, serve_mode="relay")
    plan = compile_experiment(dataclasses.replace(base, scenario=scn))
    mc = run_monte_carlo(plan, args.monte_carlo)
    s = mc.summary()
    print(f"\nmonte-carlo: {mc.num_seeds} scenario seeds x {mc.rounds} "
          f"rounds (a2g channel, markov availability, "
          f"{scn.num_uavs} relay UAVs) in {mc.wall_s*1e3:.0f} ms vectorized")
    print(f"{'metric':>22} {'mean':>10} {'std':>9} {'p10':>10} {'p90':>10}")
    for name in ("final_loss", "mean_active_clients", "total_link_time_s",
                 "total_link_energy_j", "total_energy_j"):
        st = s[name]
        print(f"{name:>22} {st['mean']:>10.3g} {st['std']:>9.3g} "
              f"{st['p10']:>10.3g} {st['p90']:>10.3g}")
