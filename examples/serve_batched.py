"""Batched serving example: greedy decode with KV caches through the
split-learning tiers (client prefix + server suffix).

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced", "--batch", "4",
                "--prompt-len", "16", "--gen", "16"])
