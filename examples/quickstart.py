"""Quickstart: the whole eEnergy-Split stack in one script.

    PYTHONPATH=src python examples/quickstart.py

1. deploy edge devices on a simulated 100-acre farm (Algorithm 1)
2. plan the energy-optimal UAV tour (Algorithm 2, exact TSP)
3. run a few rounds of split learning on synthetic pest images
   (Algorithm 3) and report accuracy + per-tier energy
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.deployment import deploy_edge_devices, uniform_grid_sensors
from repro.core.trajectory import plan_tour
from repro.core.paper_train import PaperTrainConfig, train_sl
from repro.data.synthetic import SyntheticPestImages

# 1. deployment -------------------------------------------------------------
sensors = uniform_grid_sensors(acres=100, n_sensors=25)
dep = deploy_edge_devices(sensors, cr=200.0)
print(f"[1] {len(sensors)} sensors -> {len(dep.edge_indices)} edge devices "
      f"(loads: {dep.loads.tolist()})")

# 2. UAV tour ---------------------------------------------------------------
plan = plan_tour(dep.edge_coords, base=np.zeros(2))
print(f"[2] optimal tour {plan.tour_length:.0f} m, "
      f"{plan.e_per_round/1e3:.1f} kJ/round, gamma={plan.rounds} rounds "
      f"on one battery")

# 3. split learning ---------------------------------------------------------
gen = SyntheticPestImages(image_size=32)
x, y = map(np.asarray, gen.dataset(800))
xt, yt = map(np.asarray, gen.sample(jax.random.PRNGKey(99), 160))
cfg = PaperTrainConfig(model="mobilenetv2", client_fraction=0.25,
                       num_clients=len(dep.edge_indices) if
                       len(dep.edge_indices) >= 2 else 4,
                       global_rounds=min(4, plan.rounds), local_steps=3)
res = train_sl(cfg, x, y, xt, yt)
m = res["metrics"]
print(f"[3] SL_25,75 after {cfg.global_rounds} UAV rounds: "
      f"acc={m['accuracy']:.3f} f1={m['f1']:.3f} "
      f"client={res['client_energy'].energy_j/1e3:.3f}kJ "
      f"server={res['server_energy'].energy_j/1e3:.4f}kJ "
      f"link={res['link_bytes']/1e6:.1f}MB "
      f"({res['steps_per_s']:.1f} steps/s, scanned rounds)")
print("done — see benchmarks/ for the full paper tables.")
