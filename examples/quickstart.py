"""Quickstart: the whole eEnergy-Split stack in one script.

    PYTHONPATH=src python examples/quickstart.py

1. deploy edge devices on a simulated 100-acre farm (Algorithm 1)
2. plan the energy-optimal UAV tour (Algorithm 2, exact TSP)
3. declare a split-learning experiment as ONE ``ExperimentSpec``
   (Algorithm 3), compile it, and stream per-round records with
   accuracy + per-tier energy
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,  # noqa: E402
                       ExperimentSpec, ModelSpec, compile_experiment)
from repro.core.deployment import deploy_edge_devices, uniform_grid_sensors
from repro.core.trajectory import plan_tour
from repro.data.synthetic import SyntheticPestImages

# 1. deployment -------------------------------------------------------------
sensors = uniform_grid_sensors(acres=100, n_sensors=25)
dep = deploy_edge_devices(sensors, cr=200.0)
print(f"[1] {len(sensors)} sensors -> {len(dep.edge_indices)} edge devices "
      f"(loads: {dep.loads.tolist()})")

# 2. UAV tour ---------------------------------------------------------------
plan = plan_tour(dep.edge_coords, base=np.zeros(2))
print(f"[2] optimal tour {plan.tour_length:.0f} m, "
      f"{plan.e_per_round/1e3:.1f} kJ/round, gamma={plan.rounds} rounds "
      f"on one battery")

# 3. split learning: one declarative spec -----------------------------------
gen = SyntheticPestImages(image_size=32)
x, y = map(np.asarray, gen.dataset(800))
xt, yt = map(np.asarray, gen.sample(jax.random.PRNGKey(99), 160))
num_clients = len(dep.edge_indices) if len(dep.edge_indices) >= 2 else 4
spec = ExperimentSpec(
    model=ModelSpec(name="mobilenetv2", num_classes=12),
    data=DataSpec(kind="arrays", image_size=32, shrink_batches=True),
    clients=ClientSpec(num_clients=num_clients),
    cut_policy=CutPolicy(mode="fraction", fraction=0.25),   # SL_{25,75}
    engine=EngineSpec(kind="sl", client_axis="scan"),       # sequential Alg. 3
    global_rounds=min(4, plan.rounds), local_steps=3, batch_size=16)
exp = compile_experiment(spec, data=(x, y, xt, yt))
state, records = exp.run()
m = state.last_metrics
print(f"[3] SL_25,75 ({exp.engine_label}) after {len(records)} UAV rounds: "
      f"acc={m['accuracy']:.3f} f1={m['f1']:.3f} "
      f"client={sum(r.client_energy_j for r in records)/1e3:.3f}kJ "
      f"server={sum(r.server_energy_j for r in records)/1e3:.4f}kJ "
      f"link={sum(r.link_bytes for r in records)/1e6:.1f}MB")
print("    swap EngineSpec(kind='fl') for the FL baseline, "
      "client_axis='vmap' for the fleet engine,")
print("    CutPolicy(mode='adaptive') for per-client cuts — same spec, "
      "same records.")
print("done — see benchmarks/ for the full paper tables.")
