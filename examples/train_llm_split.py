"""End-to-end driver: train a ~100M-param LLM (smollm-135m, full config)
with the eEnergy-Split cut for a few hundred steps on synthetic tokens.

    PYTHONPATH=src python examples/train_llm_split.py --steps 300

This is the deliverable-(b) end-to-end run: full-size smollm-135m (30
layers, d_model 576, vocab 49152 — 135M params), split at SL_15,85, AdamW,
loss curve printed. On the production mesh the same step lowers via
repro.launch.steps; here it runs on CPU with a small batch.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    train_main(["--arch", "smollm-135m", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--client-fraction", "0.15",
                "--ckpt", "results/smollm_split.msgpack"])
