#!/usr/bin/env python
"""repro_lint — the repo's static-analysis gate (CI job: lint).

    PYTHONPATH=src python tools/repro_lint.py --jaxpr --ast
    PYTHONPATH=src python tools/repro_lint.py --ast --paths src/repro/sim
    PYTHONPATH=src python tools/repro_lint.py --jaxpr --variant sl/vmap
    PYTHONPATH=src python tools/repro_lint.py --ast --json results/lint.json

Two passes (see ``src/repro/analyze`` and the "Static analysis" section of
docs/ARCHITECTURE.md):

* ``--jaxpr``: compile the engine-variant matrix (fl/sl x scan/vmap/
  shard_map, dropout, population cohorts, the Monte-Carlo vmap rollout)
  and audit each compiled round structurally — donation aliasing, host
  callbacks, f64 leaks, collective axes, trace stability, closure-const
  budget, plus the PRNG fold-slot registry.
* ``--ast``: lint the source tree for repo-specific JAX hazards
  (traced-value branching, raw timers, key reuse, magic fold literals,
  unhoisted constants, bare excepts, labels crossing the link).

Exit status: 0 iff zero findings. ``--json PATH`` additionally writes the
machine-readable findings report (the CI artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--jaxpr", action="store_true",
                    help="pass 1: jaxpr/HLO audit of the compiled variant "
                         "matrix")
    ap.add_argument("--ast", action="store_true",
                    help="pass 2: stdlib-ast lint over --paths")
    ap.add_argument("--paths", nargs="*", default=["src/repro"],
                    help="files/dirs for --ast (default: src/repro)")
    ap.add_argument("--variant", default=None,
                    help="audit only variants whose name contains this "
                         "substring (e.g. 'sl/vmap', 'mc/')")
    ap.add_argument("--no-mc", action="store_true",
                    help="skip the Monte-Carlo rollout audits")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the findings report as JSON (CI artifact)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print findings only, no per-check progress")
    args = ap.parse_args(argv)
    if not (args.jaxpr or args.ast):
        ap.error("nothing to do: pass --jaxpr and/or --ast")

    from repro.analyze import Report, lint_paths
    combined = Report()

    if args.ast:
        report = lint_paths([REPO_ROOT / p for p in args.paths],
                            repo_root=REPO_ROOT)
        if not args.quiet:
            print(f"[ast]   linted {len(report.checked)} files: "
                  f"{len(report.findings)} finding(s)")
        combined.extend(report)

    if args.jaxpr:
        from repro.analyze import (audit_keys, audit_mc, audit_plan,
                                   compiled_variants)
        combined.extend(audit_keys())
        for name, plan, with_mc in compiled_variants(mc=not args.no_mc,
                                                     match=args.variant):
            report = audit_plan(plan)
            if with_mc:
                report.extend(audit_mc(plan))
            if not args.quiet:
                print(f"[jaxpr] {name}: {len(report.findings)} finding(s)")
            report.checked = [f"{name}: {c}" for c in report.checked]
            combined.extend(report)

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(combined.to_dict(), indent=2) + "\n")
        if not args.quiet:
            print(f"[lint]  report -> {out}")

    for f in combined.findings:
        print(f)
    n = len(combined.findings)
    print(f"[lint]  {n} finding(s) across {len(combined.checked)} "
          f"checked target(s)")
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
