#!/usr/bin/env python
"""Render a ``repro.obs`` telemetry run dir into a readable report.

    python tools/obs_report.py results/runs/<run_id>
    python tools/obs_report.py results/runs            # latest run under root
    python tools/obs_report.py <run_dir> --coverage-min 0.95   # CI smoke gate
    python tools/obs_report.py <run_dir> --health-gate         # 0 nonfinite
    python tools/obs_report.py --compare <run_a> <run_b>       # phase deltas

Reads ``manifest.json`` + ``events.jsonl`` (the schema ``repro.obs``
writes — see ``docs/ARCHITECTURE.md`` §Observability) and prints:

  * the run header (commit, jax/backend, created, plans compiled);
  * a phase-breakdown table aggregated by span ``path``: calls, total
    wall seconds, device-sync seconds (``sync_s``, booked by
    ``span.fence``), host seconds (wall - sync), and share of the root
    span's wall clock;
  * a coverage line: how much of the root span's wall clock its direct
    children account for (the "no unexplained time" acceptance bar —
    ``--coverage-min`` turns it into an exit-status gate);
  * per-round sparklines of loss / round wall / recompiles from the
    ``record`` + ``gauge`` event streams;
  * metrics-bus tap sparklines + the training-health table from the
    ``metrics`` event stream, when the run was compiled with
    ``ObsConfig(metrics=MetricsConfig(...))`` (``--health-gate`` turns
    "zero nonfinite slot-steps" into an exit-status gate);
  * the simulated-clock mission dwell decomposition (travel/hover/comm)
    when the run carried a UAV mission.

``--compare run_a run_b`` instead renders the two runs' phase tables side
by side with wall/share deltas (same ``path`` aggregation).

Zero dependencies beyond the stdlib: the report must render on a machine
that cannot import jax (e.g. inspecting a CI artifact locally).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BLOCKS = "▁▂▃▄▅▆▇█"


def latest_run_dir(root: str) -> str:
    """The newest run dir under ``root`` (run ids sort chronologically)."""
    runs = sorted(d for d in os.listdir(root)
                  if os.path.isdir(os.path.join(root, d)))
    if not runs:
        raise FileNotFoundError(f"no run dirs under {root}")
    return os.path.join(root, runs[-1])


def load_run(run_dir: str) -> tuple[dict, list[dict]]:
    """``(manifest, events)`` of one run dir. A missing events file is an
    empty stream (a run that crashed before its first flush)."""
    manifest, events = {}, []
    man_path = os.path.join(run_dir, "manifest.json")
    if os.path.exists(man_path):
        with open(man_path) as f:
            manifest = json.load(f)
    ev_path = os.path.join(run_dir, "events.jsonl")
    if os.path.exists(ev_path):
        with open(ev_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    return manifest, events


def spark(values: list[float]) -> str:
    """Unicode sparkline of ``values`` (NaNs render as spaces)."""
    vals = [v for v in values if v == v]          # drop NaN
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    out = []
    for v in values:
        if v != v:
            out.append(" ")
        else:
            out.append(BLOCKS[min(int((v - lo) / span * (len(BLOCKS) - 1)),
                                  len(BLOCKS) - 1)])
    return "".join(out)


def phase_table(events: list[dict]) -> list[dict]:
    """Span events aggregated by ``path``: one row per distinct phase,
    ordered by first occurrence, with calls / wall / sync / host sums."""
    rows: dict[str, dict] = {}
    for ev in events:
        if ev.get("ev") != "span":
            continue
        path = ev.get("path", ev.get("name", "?"))
        row = rows.setdefault(path, {"path": path, "depth": ev.get("depth", 0),
                                     "calls": 0, "wall_s": 0.0, "sync_s": 0.0})
        row["calls"] += 1
        row["wall_s"] += ev.get("dur_s", 0.0)
        row["sync_s"] += ev.get("sync_s", 0.0)
    for row in rows.values():
        row["host_s"] = row["wall_s"] - row["sync_s"]
    return list(rows.values())


def root_coverage(events: list[dict]) -> tuple[float, dict | None]:
    """``(coverage, root_row)``: the fraction of the longest depth-0
    span's wall clock accounted for by its direct (depth-1) children.
    ``(1.0, None)`` when the stream has no root span to cover."""
    spans = [ev for ev in events if ev.get("ev") == "span"]
    roots = [ev for ev in spans if ev.get("depth", 0) == 0]
    if not roots:
        return 1.0, None
    root = max(roots, key=lambda ev: ev.get("dur_s", 0.0))
    prefix = root.get("path", "") + "/"
    child_s = sum(ev.get("dur_s", 0.0) for ev in spans
                  if ev.get("depth") == 1
                  and ev.get("path", "").startswith(prefix))
    wall = root.get("dur_s", 0.0)
    return (child_s / wall if wall > 0 else 1.0), root


def metrics_rounds(events: list[dict]) -> list[dict]:
    """The run's ``metrics`` events in round order (the per-round dict the
    metrics bus summarized into ``RoundRecord.metrics``)."""
    mev = [ev for ev in events if ev.get("ev") == "metrics"]
    mev.sort(key=lambda ev: ev.get("round", 0))
    return mev


def health_nonfinite_total(events: list[dict]) -> int:
    """Total nonfinite slot-steps the run's health monitor flagged."""
    return sum(int(ev.get("health/nonfinite", 0))
               for ev in metrics_rounds(events))


def metrics_section(events: list[dict]) -> list[str]:
    """Tap sparklines + the training-health table (empty without a
    ``metrics`` event stream)."""
    mev = metrics_rounds(events)
    if not mev:
        return []
    chans = sorted({k for ev in mev for k in ev
                    if "/" in k and not k.startswith("health/")})
    out = ["", f"  metrics taps ({len(mev)} rounds):"]
    for k in chans:
        vals = [float(ev[k]) if k in ev else float("nan") for ev in mev]
        fin = [v for v in vals if v == v]
        last = fin[-1] if fin else float("nan")
        out.append(f"    {k:<26} {spark(vals)}  last={last:.4g}")
    tot = health_nonfinite_total(events)
    out += ["", f"  training health: {tot} nonfinite slot-step(s)"]
    if tot:
        out.append(f"    {'round':>6} {'count':>6} {'first_step':>11} "
                   f"{'first_client':>13}")
        for ev in mev:
            c = int(ev.get("health/nonfinite", 0))
            if c:
                out.append(f"    {ev.get('round', '?'):>6} {c:>6} "
                           f"{int(ev.get('health/first_step', -1)):>11} "
                           f"{int(ev.get('health/first_client', -1)):>13}")
    return out


def compare_runs(run_a: str, run_b: str) -> list[str]:
    """Side-by-side phase table of two run dirs: per shared ``path``, both
    wall clocks and root-share percentages plus their deltas (phases only
    one run hit render with a ``—`` on the other side)."""
    rows_by, totals, labels = [], [], []
    for run_dir in (run_a, run_b):
        _, events = load_run(run_dir)
        rows = phase_table(events)
        cov, root = root_coverage(events)
        total = (root.get("dur_s", 0.0) if root
                 else sum(r["wall_s"] for r in rows if r["depth"] == 0))
        rows_by.append({r["path"]: r for r in rows})
        totals.append(total)
        labels.append(os.path.basename(os.path.normpath(run_dir)))
    order = list(rows_by[0])
    order += [p for p in rows_by[1] if p not in rows_by[0]]
    out = [f"compare  A={labels[0]}  B={labels[1]}",
           f"  {'phase':<40} {'wall_A':>9} {'wall_B':>9} {'d_wall':>9} "
           f"{'share_A':>8} {'share_B':>8} {'d_share':>8}"]
    for path in order:
        a, b = rows_by[0].get(path), rows_by[1].get(path)
        wa = a["wall_s"] if a else None
        wb = b["wall_s"] if b else None
        sa = (wa / totals[0] if a and totals[0] > 0 else None)
        sb = (wb / totals[1] if b and totals[1] > 0 else None)
        fmt_w = lambda w: f"{w:9.4f}" if w is not None else f"{'—':>9}"
        fmt_s = lambda s: f"{s:8.1%}" if s is not None else f"{'—':>8}"
        d_w = (f"{wb - wa:+9.4f}" if wa is not None and wb is not None
               else f"{'—':>9}")
        d_s = (f"{sb - sa:+8.1%}" if sa is not None and sb is not None
               else f"{'—':>8}")
        depth = (a or b)["depth"]
        name = "  " * min(depth, 4) + path
        out.append(f"  {name:<40} {fmt_w(wa)} {fmt_w(wb)} {d_w} "
                   f"{fmt_s(sa)} {fmt_s(sb)} {d_s}")
    out.append(f"  root wall: A={totals[0]:.4f}s  B={totals[1]:.4f}s  "
               f"delta={totals[1] - totals[0]:+.4f}s")
    return out


def render(run_dir: str, manifest: dict, events: list[dict]) -> list[str]:
    out = [f"run {manifest.get('run_id', os.path.basename(run_dir))}  "
           f"({run_dir})",
           f"  created {manifest.get('created_utc', '?')}  "
           f"commit {manifest.get('git_commit', '?')}  "
           f"jax {manifest.get('jax_version', '?')}/"
           f"{manifest.get('backend', '?')} "
           f"x{manifest.get('device_count', '?')}"]
    plans = manifest.get("plans", [])
    for p in plans:
        out.append(f"  plan: {p.get('engine', '?')} {p.get('model', '?')} "
                   f"clients={p.get('num_clients', '?')} "
                   f"rounds={p.get('rounds', '?')}")
    for s in manifest.get("sweeps", []):
        out.append(f"  sweep: {s.get('kind', '?')}/{s.get('mode', '?')} "
                   f"seeds={s.get('num_seeds', '?')} "
                   f"rounds={s.get('rounds', '?')} "
                   f"wall={s.get('wall_s', '?')}s")
    if "profiler" in manifest:
        out.append(f"  profiler: {manifest['profiler']}")

    rows = phase_table(events)
    cov, root = root_coverage(events)
    if rows:
        total = (root.get("dur_s", 0.0) if root
                 else sum(r["wall_s"] for r in rows if r["depth"] == 0))
        out += ["", f"  {'phase':<44} {'calls':>6} {'wall_s':>10} "
                    f"{'sync_s':>10} {'host_s':>10} {'share':>7}"]
        for r in rows:
            share = (f"{r['wall_s'] / total:6.1%}" if total > 0 else "     —")
            pad = "  " * min(r["depth"], 4)
            name = pad + r["path"]
            out.append(f"  {name:<44} {r['calls']:>6} {r['wall_s']:>10.4f} "
                       f"{r['sync_s']:>10.4f} {r['host_s']:>10.4f} {share:>7}")
        if root is not None:
            out.append(f"  coverage: {cov:.1%} of root span "
                       f"'{root.get('path')}' ({root.get('dur_s', 0):.4f}s) "
                       f"accounted for by its direct children")
    else:
        out += ["", "  (no span events)"]

    records = [ev for ev in events if ev.get("ev") == "record"]
    if records:
        records.sort(key=lambda ev: ev.get("round", 0))
        loss = [ev.get("loss", float("nan")) for ev in records]
        out += ["", f"  rounds: {len(records)}"]
        out.append(f"    loss      {spark(loss)}  "
                   f"last={loss[-1]:.4f}" if loss else "")
        acc = [ev.get("accuracy", float("nan")) for ev in records]
        if any(a == a for a in acc):
            last = [a for a in acc if a == a][-1]
            out.append(f"    accuracy  {spark(acc)}  last={last:.4f}")
        active = [ev.get("active_clients", float("nan")) for ev in records]
        if any(a == a and a >= 0 for a in active):
            out.append(f"    active    {spark(active)}")
    round_spans = [ev for ev in events if ev.get("ev") == "span"
                   and ev.get("name") == "round"]
    if round_spans:
        round_spans.sort(key=lambda ev: ev.get("round", 0))
        walls = [ev.get("dur_s", 0.0) for ev in round_spans]
        out.append(f"    round_s   {spark(walls)}  "
                   f"mean={sum(walls) / len(walls):.4f}s")
    gauges = [ev for ev in events if ev.get("ev") == "gauge"]
    if gauges:
        gauges.sort(key=lambda ev: ev.get("round", 0))
        comps = [g.get("compiles") for g in gauges]
        if any(c is not None for c in comps):
            vals = [float(c if c is not None else 0) for c in comps]
            out.append(f"    compiles  {spark(vals)}  "
                       f"total={int(sum(vals))}")
        rss = [g.get("rss_bytes", 0) for g in gauges]
        if any(rss):
            out.append(f"    rss       {spark([float(b) for b in rss])}  "
                       f"last={rss[-1] / 1e6:.1f}MB")
        sb = [g.get("state_bytes") for g in gauges if g.get("state_bytes")]
        if sb:
            out.append(f"    state     {sb[-1] / 1e6:.2f}MB (engine state)")

    out += metrics_section(events)

    mission = [ev for ev in events if ev.get("ev") == "mission_span"]
    if mission:
        legs: dict[str, float] = {}
        for ev in mission:
            legs[ev.get("name", "?")] = (legs.get(ev.get("name", "?"), 0.0)
                                         + ev.get("dur_s", 0.0))
        total_m = sum(legs.values()) or 1.0
        out += ["", "  mission dwell (simulated clock):"]
        for name, dur in sorted(legs.items()):
            out.append(f"    {name:<18} {dur:>10.1f}s  {dur / total_m:6.1%}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="results/runs",
                    help="a run dir, or a runs root (uses the latest run)")
    ap.add_argument("--coverage-min", type=float, default=None,
                    help="exit nonzero unless the root span's direct "
                         "children cover at least this fraction of its "
                         "wall clock (CI smoke gate, e.g. 0.95)")
    ap.add_argument("--health-gate", action="store_true",
                    help="exit nonzero if the run's metrics stream flagged "
                         "any nonfinite slot-step (CI smoke gate; also "
                         "fails when the run carried no metrics events)")
    ap.add_argument("--compare", nargs=2, metavar=("RUN_A", "RUN_B"),
                    help="render two run dirs' phase tables side by side "
                         "with wall/share deltas, then exit")
    args = ap.parse_args()
    if args.compare:
        print("\n".join(compare_runs(*args.compare)))
        return
    run_dir = args.path
    if not os.path.exists(os.path.join(run_dir, "events.jsonl")) and \
            not os.path.exists(os.path.join(run_dir, "manifest.json")):
        run_dir = latest_run_dir(args.path)
    manifest, events = load_run(run_dir)
    print("\n".join(render(run_dir, manifest, events)))
    if args.coverage_min is not None:
        cov, root = root_coverage(events)
        if root is None:
            print("obs-report: no root span to gate coverage on")
            sys.exit(1)
        if cov < args.coverage_min:
            print(f"obs-report: coverage {cov:.1%} < "
                  f"required {args.coverage_min:.1%}")
            sys.exit(1)
        print(f"obs-report: coverage ok ({cov:.1%} >= "
              f"{args.coverage_min:.1%})")
    if args.health_gate:
        if not metrics_rounds(events):
            print("obs-report: health gate needs a metrics event stream "
                  "(compile with ObsConfig(metrics=MetricsConfig()))")
            sys.exit(1)
        tot = health_nonfinite_total(events)
        if tot:
            print(f"obs-report: health gate FAILED — {tot} nonfinite "
                  f"slot-step(s) flagged")
            sys.exit(1)
        print("obs-report: health ok (0 nonfinite slot-steps)")


if __name__ == "__main__":
    main()
