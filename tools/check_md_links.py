#!/usr/bin/env python
"""Fail on broken intra-repo links in the repo's markdown files.

    python tools/check_md_links.py [root]

Checks every ``[text](target)`` and ``[text]: target`` reference in
tracked ``*.md`` files (skipping dot-directories and caches):

  * external schemes (http/https/mailto) are ignored — CI must not depend
    on the network;
  * pure-anchor targets (``#section``) are resolved against the SAME
    file's headings (GitHub slug rules: lowercase, punctuation stripped,
    spaces -> dashes);
  * everything else is a repo path, resolved relative to the referencing
    file (or the root for ``/``-prefixed targets); an optional
    ``#anchor`` suffix is checked against that file's headings when it is
    markdown.

Exit status: 0 = every link resolves, 1 = at least one broken link
(listed on stdout). Used by the CI docs job next to
``python -m doctest docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", ".github", ".pytest_cache", "__pycache__", ".claude",
             "node_modules", ".venv"}
# inline [text](target) — target ends at the first unescaped ')';
# reference defs [label]: target
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+(\S+)", re.M)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out += [os.path.join(dirpath, f) for f in filenames
                if f.endswith(".md")]
    return sorted(out)


def strip_fences(text: str) -> str:
    """Drop fenced code blocks — example links in code are not contracts."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (close enough for intra-repo use)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: str) -> set[str]:
    try:
        text = open(path, encoding="utf-8").read()
    except OSError:
        return set()
    return {slugify(m.group(1)) for m in HEADING.finditer(text)}


def check(root: str) -> list[str]:
    errors = []
    for path in md_files(root):
        text = strip_fences(open(path, encoding="utf-8").read())
        targets = INLINE.findall(text) + REFDEF.findall(text)
        for target in targets:
            if target.startswith(EXTERNAL) or target.startswith("<"):
                continue
            rel = os.path.relpath(path, root)
            if target.startswith("#"):
                if slugify(target[1:]) not in anchors_of(path):
                    errors.append(f"{rel}: broken anchor {target!r}")
                continue
            dest, _, frag = target.partition("#")
            base = root if dest.startswith("/") else os.path.dirname(path)
            full = os.path.normpath(os.path.join(base, dest.lstrip("/")))
            if not os.path.exists(full):
                errors.append(f"{rel}: broken link {target!r} "
                              f"(resolved {os.path.relpath(full, root)})")
            elif frag and full.endswith(".md") and \
                    slugify(frag) not in anchors_of(full):
                errors.append(f"{rel}: broken anchor {target!r}")
    return errors


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else ".")
    errors = check(root)
    if errors:
        print(f"check_md_links: {len(errors)} broken link(s):")
        for e in errors:
            print(f"  !! {e}")
        return 1
    print(f"check_md_links: ok ({len(md_files(root))} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
