"""Compressed link boundary for the fleet engine.

Wires the existing-but-previously-unused ``kernels/quant`` int8 Pallas link
compressor into ``SplitStep`` as an opt-in boundary, and turns smashed
tensor shapes into the per-step link constants (wire bytes / time / energy)
that flow into the campaign's energy accounting next to the FLOP-derived
compute constants from ``core.flops``.

Byte accounting follows ``core.link.LinkConfig.wire_bytes``: the int8 wire
format is 1 byte per element (``dtype_bytes=1`` effective payload) plus one
f32 scale per quantizer row — the kernel scales per row of the flattened
(rows, last_dim) tensor, so the overhead is 4/last_dim bytes per element
(``scale_block=last_dim`` is passed through). That makes the shrink vs f32
shape-dependent: ~3.98x for wide (>=256-channel) smashed tensors, ~3.2x for
a 16-channel CNN cut. The compressor itself is the
straight-through estimator from ``kernels.quant.ops``: forward
quantize→dequantize, backward identity, so the cut gradient keeps flowing
through one autodiff program (and vmaps over the fleet's client axis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from ..core.link import LinkConfig
from ..core.split import SplitStep


@dataclasses.dataclass(frozen=True)
class FleetLink:
    """One edge<->server link: config + the kernel path of its compressor.

    ``use_pallas``/``interpret`` select the Pallas TPU kernel vs its jnp
    oracle (the oracle is the right default on CPU containers; interpret
    mode runs the Pallas kernel off-TPU for parity tests).
    """
    config: LinkConfig = LinkConfig()
    use_pallas: bool = False
    interpret: bool = True

    @property
    def compressed(self) -> bool:
        return self.config.compress == "int8"

    def boundary(self) -> Optional[Callable]:
        """The smashed-tensor boundary fn, or None for an uncompressed link."""
        if not self.compressed:
            return None
        from ..kernels.quant.ops import make_link_compress
        return make_link_compress(use_pallas=self.use_pallas,
                                  interpret=self.interpret)

    def attach(self, step: SplitStep) -> SplitStep:
        """Opt the split step into this link (compose with any existing
        boundary, e.g. a sharding constraint: compress first, constrain the
        compressed activations after)."""
        boundary = self.boundary()
        if boundary is None:
            return step
        existing = step.link_constraint
        if existing is not None:
            inner = boundary
            boundary = lambda sm: existing(inner(sm))  # noqa: E731
        return dataclasses.replace(step, link_constraint=boundary)

    # ---- per-step link constants (hoisted out of the hot loop) ----

    def step_wire_bytes(self, smashed_sd) -> float:
        """Wire bytes of ONE split step: smashed fwd + cut-gradient return,
        both compressed when the link is int8. The scale overhead uses the
        actual quantizer row length (the smashed tensor's last dim)."""
        sm_bytes = float(smashed_sd.size) * smashed_sd.dtype.itemsize
        return self.config.roundtrip_bytes(sm_bytes, smashed_sd.dtype.itemsize,
                                           scale_block=smashed_sd.shape[-1])

    def step_time_s(self, smashed_sd) -> float:
        """Eq. (8) on the roundtrip wire volume (delegates to LinkConfig so
        the formula lives in one place)."""
        sm_bytes = float(smashed_sd.size) * smashed_sd.dtype.itemsize
        return 2.0 * self.config.transfer_time_s(
            sm_bytes, smashed_sd.dtype.itemsize,
            scale_block=smashed_sd.shape[-1])

    def step_energy_j(self, smashed_sd) -> float:
        """Radio energy of one step's link roundtrip (edge-side transmit)."""
        return self.step_time_s(smashed_sd) * self.config.radio_power_w
