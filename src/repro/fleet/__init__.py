"""Fleet subsystem — many heterogeneous split-learning clients as one system.

PR 1's scanned-round engine (``repro.core.split``) made a single round one
compiled XLA program; this package scales that engine along the *client*
axis so a whole edge fleet trains as one SPMD program, and wraps it in the
mission-level simulator the paper's energy claims need at scale.

Layout
------
``engine.py``   sharded fleet rounds: the stacked client axis of the FL and
                SL round builders is batched (independent clients — Efficient
                Parallel Split Learning, Lin et al., arXiv:2303.15991) and
                sharded over the ``data`` mesh axis, so N clients run as one
                SPMD program — either ``client_axis='vmap'`` (GSPMD-inferred
                collectives via sharding constraints) or
                ``client_axis='shard_map'`` (explicit ``fedavg_pmean`` /
                in-map ``lax.pmean`` collectives, pinned schedule; the
                multi-host path). ``launch.mesh.make_fleet_mesh`` builds the
                2D ``('data','fsdp','tp')`` mesh; ``server_pspecs`` shards
                the SL server suffix fsdp x tp. Defines ``FLEET_EQUIV_ATOL``,
                the documented loosened equivalence tolerance vs the
                sequential reference.
``hetero.py``   per-client cut personalization (P3SL, arXiv:2507.17228):
                clients are assigned cut indices via
                ``core.adaptive_cut.select_cut`` on their own hardware/link
                profile, bucketed by cut, and each cut-group runs its own
                compiled fleet round. Works for both CNN ``Stage`` lists and
                transformer ``split_stack`` models.
``link.py``     the compressed link boundary: wires the
                ``kernels/quant`` int8 straight-through compressor into
                ``SplitStep`` (opt-in) and turns smashed-tensor shapes into
                per-step wire-bytes/time/energy constants via
                ``core.link.LinkConfig`` (int8 payload = 1 byte/elem + f32
                scale overhead).
``campaign.py`` campaign configs: ``CampaignConfig`` -> ``campaign_spec``
                maps the historical mission surface onto one
                ``repro.api.ExperimentSpec`` (fleet SL engine + TSP tour +
                UAV round budget + link/energy accounting); run it through
                ``compile_experiment`` for the paper's rounds-vs-energy
                tradeoff across fleet sizes, cuts and link modes.
"""
from .engine import (CLIENT_AXES, FLEET_EQUIV_ATOL, fleet_sharding,
                     make_fleet_fl_round, make_fleet_sl_round,
                     server_mesh_sizes, shard_client_stack,
                     shard_server_state, validate_fleet_mesh)
from .hetero import (CutBucket, HeteroFleet, SplitProgram,
                     arch_split_program, assign_cuts_cnn,
                     assign_cuts_transformer, bucket_by_cut,
                     cnn_split_program, stack_split_program,
                     transformer_block_apply)
from .link import FleetLink
from .campaign import (CampaignConfig, RoundRecord, campaign_spec,
                       campaign_totals)

__all__ = [n for n in dir() if not n.startswith("_")]
