"""Per-client cut personalization with bucketed dispatch.

Heterogeneous edge fleets (P3SL, arXiv:2507.17228) don't share one best cut:
a Jetson-class client wants a deeper prefix than a microcontroller-class
one, and a starved link moves the optimum toward smaller smashed tensors.
Here every client gets its own cut from ``core.adaptive_cut.select_cut`` on
its own (hardware, link) profile, clients are grouped into *cut buckets*,
and each bucket runs its own compiled fleet round (``engine``): XLA programs
are shape-specialized per cut, so the bucket — not the client — is the
compilation unit. Every client belongs to exactly one bucket.

Both model families split the same way through ``SplitProgram``:

  * CNN ``Stage`` lists — slice the stage/param lists at k
    (``cnn_split_program``).
  * transformer ``split_stack`` models — slice the stacked layer axis at k
    and scan blocks on each side (``stack_split_program``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptive_cut import (profile_cuts_cnn, profile_cuts_transformer,
                                 select_cut)
from ..core.energy import HardwareProfile
from ..core.link import LinkConfig
from ..core.split import SplitStep, Stage, apply_stages, split_stack
from ..optim.optimizers import init_stacked
from .engine import make_fleet_sl_round, validate_fleet_mesh


# ---------------------------------------------------------------------------
# cut assignment + bucketing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CutBucket:
    cut_index: int
    client_ids: tuple[int, ...]   # global client indices, ascending


def bucket_by_cut(cut_indices: Sequence[int]) -> list[CutBucket]:
    """Group clients by cut index. Deterministic (ascending cut, ascending
    client id); the buckets partition the fleet — every client exactly once."""
    by_cut: dict[int, list[int]] = {}
    for cid, k in enumerate(cut_indices):
        by_cut.setdefault(int(k), []).append(cid)
    return [CutBucket(k, tuple(ids)) for k, ids in sorted(by_cut.items())]


def _assign_cuts(profile_fn: Callable, edges: Sequence[HardwareProfile],
                 links: Optional[Sequence[LinkConfig]],
                 max_link_s: Optional[float]) -> list[int]:
    """Shared per-client selection loop: identical (hardware, link) profiles
    share one cut-curve evaluation. ``profile_fn(edge, link)`` returns the
    cut choices for one profile."""
    links = list(links) if links is not None else [LinkConfig()] * len(edges)
    if len(links) != len(edges):
        raise ValueError("edges and links must be per-client (same length)")
    cache: dict[tuple, int] = {}
    cuts = []
    for edge, link in zip(edges, links):
        key = (edge, link)
        if key not in cache:
            cache[key] = select_cut(profile_fn(edge, link),
                                    max_link_s=max_link_s).cut_index
        cuts.append(cache[key])
    return cuts


def assign_cuts_cnn(stages: Sequence[Stage], params, sample_x, *,
                    edges: Sequence[HardwareProfile],
                    links: Optional[Sequence[LinkConfig]] = None,
                    min_client_layers: int = 1,
                    max_link_s: Optional[float] = None) -> list[int]:
    """Per-client minimum-energy cut for a CNN stage list. ``edges`` (and
    optionally ``links``) give each client its own profile."""
    return _assign_cuts(
        lambda edge, link: profile_cuts_cnn(
            stages, params, sample_x, edge=edge, link=link,
            min_client_layers=min_client_layers),
        edges, links, max_link_s)


def assign_cuts_transformer(cfg, *, batch: int, seq: int,
                            edges: Sequence[HardwareProfile],
                            links: Optional[Sequence[LinkConfig]] = None,
                            max_link_s: Optional[float] = None) -> list[int]:
    """Per-client minimum-energy cut for a transformer ArchConfig stack."""
    return _assign_cuts(
        lambda edge, link: profile_cuts_transformer(
            cfg, batch=batch, seq=seq, edge=edge, link=link),
        edges, links, max_link_s)


# ---------------------------------------------------------------------------
# split programs: one cut of one model family, as a SplitStep + params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SplitProgram:
    """A model split at one cut: the differentiable step + per-tier inits
    (every client in a bucket starts from the same prefix init)."""
    step: SplitStep
    params_c0: object
    params_s0: object
    cut_index: int


def cnn_split_program(stages: Sequence[Stage], params, k: int, *,
                      loss_fn: Callable,
                      link_boundary: Optional[Callable] = None,
                      taps: tuple = ()) -> SplitProgram:
    """Split a CNN stage list at stage index ``k``. ``loss_fn(logits,
    targets) -> scalar`` closes the server side. ``taps`` are the
    step-level metrics-bus channels (``SplitStep.taps``)."""
    if not 1 <= k <= len(stages) - 1:
        raise ValueError(f"cut {k} outside (0, {len(stages)})")
    cs, cp = list(stages[:k]), list(params[:k])
    ss, sp = list(stages[k:]), list(params[k:])
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (loss_fn(apply_stages(ss, ps, sm), yy),
                                        {}),
        link_constraint=link_boundary,
        taps=taps,
    )
    return SplitProgram(step=step, params_c0=cp, params_s0=sp, cut_index=k)


def transformer_block_apply(cfg, *, window="cfg",
                            attn_impl: str = "xla") -> Callable:
    """``block_apply`` for ``stack_split_program`` backed by the *real*
    transformer forward (``models.transformer.group_apply``).

    Applies ONE attention layer of an ``ArchConfig`` stack: the un-stacked
    layer params are re-lifted to a one-layer stack and run through the
    same ``group_apply`` scan the production launcher uses, so the split
    model is bit-identical to slicing the full model's layer axis. Dense
    attention groups only (MoE groups carry a router-aux scalar that the
    stacked-block interface has no channel for).
    """
    from ..models.transformer import GroupSpec, group_apply

    if cfg.n_experts:
        raise ValueError("transformer_block_apply serves dense attention "
                         "stacks; MoE groups need the aux-carrying "
                         "launch-layer forward")
    g = GroupSpec("attn", 1, 0)
    win = cfg.swa_window if window == "cfg" else window

    def block_apply(blk, h):
        stacked = jax.tree_util.tree_map(lambda v: v[None], blk)
        b, s = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h, _aux = group_apply(cfg, g, stacked, h,
                              jnp.zeros((), jnp.float32),
                              positions=positions, window=win,
                              attn_impl=attn_impl)
        return h

    return block_apply


def arch_split_program(cfg, key, k: int, *, loss_fn: Callable,
                       link_boundary: Optional[Callable] = None,
                       window="cfg", attn_impl: str = "xla") -> SplitProgram:
    """Split a real transformer ``ArchConfig`` at layer ``k`` through the
    stacked-block interface: init one homogeneous attention stack
    (``models.transformer.group_init``) and cut its layer axis. The smashed
    tensor is the (batch, seq, d_model) residual stream at the cut — the
    paper's transformer SL boundary."""
    from ..models.transformer import GroupSpec, group_init

    if not 1 <= k <= cfg.n_layers - 1:
        raise ValueError(f"cut {k} outside (0, {cfg.n_layers})")
    stacked = group_init(key, cfg, GroupSpec("attn", cfg.n_layers, 0))
    return stack_split_program(stacked, k,
                               block_apply=transformer_block_apply(
                                   cfg, window=window, attn_impl=attn_impl),
                               loss_fn=loss_fn, link_boundary=link_boundary)


@dataclasses.dataclass(frozen=True)
class LMSplitProgram:
    """A trainable split *language model*: embed + block stack + LM head.

    Extends ``SplitProgram``'s contract with the pieces a real token
    pipeline needs — the client tier owns the embedding (raw tokens never
    cross the link, the split-learning privacy floor), the server tier owns
    its block slice plus the output head, and ``server_logits`` exposes the
    full forward for held-out evaluation.
    """
    step: SplitStep
    params_c0: object             # {"embed": (V, d), "blocks": client stack}
    params_s0: object             # {"blocks": server stack, "head": (d, V)}
    cut_index: int
    server_logits: Callable       # (params_s, smashed) -> (B, S, V)


def lm_split_program(cfg, key, k: int, *,
                     link_boundary: Optional[Callable] = None,
                     window="cfg", attn_impl: str = "xla",
                     taps: tuple = ()) -> LMSplitProgram:
    """Split a next-token LM built on a real transformer ``ArchConfig``
    stack (``models.transformer.group_apply`` blocks) at layer ``k``.

    The differentiable program is: client = embed + first ``k`` blocks
    (smashed tensor: the (B, S, d_model) residual stream at the cut);
    server = remaining blocks + output head + next-token cross entropy.
    Batches are ``{"inputs": tokens (B, S), "targets": next tokens (B, S)}``
    — what ``repro.api``'s token data pipeline feeds (``ModelSpec(family=
    "transformer")``).
    """
    from ..models.transformer import GroupSpec, group_init

    if not 1 <= k <= cfg.n_layers - 1:
        raise ValueError(f"cut {k} outside (0, {cfg.n_layers})")
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    stacked = group_init(k_blocks, cfg, GroupSpec("attn", cfg.n_layers, 0))
    blocks_c, blocks_s = split_stack(stacked, k)
    scale = 0.02
    embed = scale * jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                      jnp.float32)
    head = scale * jax.random.normal(k_head, (cfg.d_model, cfg.vocab),
                                     jnp.float32)
    block_apply = transformer_block_apply(cfg, window=window,
                                          attn_impl=attn_impl)

    def run_blocks(stack, h):
        def body(h, blk):
            return block_apply(blk, h), None
        h, _ = jax.lax.scan(body, h, stack)
        return h

    def client_fwd(pc, tokens):
        return run_blocks(pc["blocks"], pc["embed"][tokens])

    def server_logits(ps, smashed):
        return run_blocks(ps["blocks"], smashed) @ ps["head"]

    def server_loss(ps, smashed, targets):
        logits = server_logits(ps, smashed)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll), {}

    step = SplitStep(client_fwd=client_fwd, server_loss=server_loss,
                     link_constraint=link_boundary, taps=taps)
    return LMSplitProgram(step=step,
                          params_c0={"embed": embed, "blocks": blocks_c},
                          params_s0={"blocks": blocks_s, "head": head},
                          cut_index=k, server_logits=server_logits)


def stack_split_program(stacked_params, k: int, *, block_apply: Callable,
                        loss_fn: Callable,
                        link_boundary: Optional[Callable] = None,
                        taps: tuple = ()) -> SplitProgram:
    """Split a stacked-block (scan-over-layers) model at layer ``k``.

    ``block_apply(block_params, h) -> h`` applies ONE block (params without
    the stacked layer axis); ``loss_fn(h, targets) -> scalar`` closes the
    server side on the last hidden state. Each tier scans its slice of the
    stack, so the same program serves any transformer ``split_stack`` model
    (``arch_split_program`` builds one straight from an ``ArchConfig``).
    """
    params_c, params_s = split_stack(stacked_params, k)

    def run_blocks(stack, h):
        def body(h, blk):
            return block_apply(blk, h), None
        h, _ = jax.lax.scan(body, h, stack)
        return h

    step = SplitStep(
        client_fwd=run_blocks,
        server_loss=lambda ps, sm, yy: (loss_fn(run_blocks(ps, sm), yy), {}),
        link_constraint=link_boundary,
        taps=taps,
    )
    return SplitProgram(step=step, params_c0=params_c, params_s0=params_s,
                        cut_index=k)


# ---------------------------------------------------------------------------
# bucketed dispatch
# ---------------------------------------------------------------------------

def _stack_replicas(tree, n: int):
    return jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), tree)


def _server_only_mesh(mesh):
    """The fleet mesh with its ``data`` axis collapsed to 1: same
    ``fsdp``/``tp`` server sub-mesh, no client-axis sharding. Used by
    buckets whose size does not divide ``data``."""
    if mesh is None or "data" not in mesh.axis_names:
        return None
    i = mesh.axis_names.index("data")
    if mesh.devices.shape[i] == 1:
        return mesh
    sl = [slice(None)] * mesh.devices.ndim
    sl[i] = slice(0, 1)
    return jax.sharding.Mesh(mesh.devices[tuple(sl)], mesh.axis_names)


class HeteroFleet:
    """Per-cut-bucket fleet engines over one shared client population.

    ``build_program(k) -> SplitProgram`` specializes the model to a cut;
    each bucket owns a compiled ``make_fleet_sl_round`` (its own server
    suffix — a cut-group is also a server-model group) and the stacked state
    of its clients. ``run_round(batches)`` slices the global
    (clients, local_steps, ...) batch stack per bucket, runs every bucket's
    compiled round, and reassembles losses into (local_steps, clients).
    """

    def __init__(self, build_program: Callable[[int], SplitProgram],
                 cut_indices: Sequence[int], opt_c, opt_s, *,
                 local_rounds: int, mesh=None, client_dropout: bool = False,
                 server_reduce: str = "mean", client_axis: str = "vmap",
                 server_pspecs_fn: Optional[Callable] = None,
                 taps: tuple = ()):
        """``client_axis`` ('vmap' | 'shard_map') and ``server_pspecs_fn``
        (``lambda params_s, mesh: pspecs`` — e.g. wrapping
        ``launch.steps.fleet_server_pspecs``) pass through to each bucket's
        ``make_fleet_sl_round``; a bucket whose size does not divide the
        mesh's data axis falls back to its unsharded (single-device for
        shard_map) engine rather than padding. ``taps`` (engine-level
        metrics-bus channels) also pass through: ``run_round_on`` then
        reassembles each bucket's tap stacks into global
        (local_rounds, num_clients) arrays — a bucket's one-update-per-step
        server channel is broadcast to its client columns, since each cut
        bucket owns its own server suffix."""
        self.buckets = bucket_by_cut(cut_indices)
        self.local_rounds = local_rounds
        self.num_clients = len(cut_indices)
        self.client_dropout = client_dropout
        self.client_axis = client_axis
        self.taps = tuple(taps)
        self._ids: list[np.ndarray] = []
        self._engines = []
        self._init_states = []
        self.programs: dict[int, SplitProgram] = {}
        for bucket in self.buckets:
            prog = build_program(bucket.cut_index)
            if prog.cut_index != bucket.cut_index:
                raise ValueError("build_program returned a different cut")
            n = len(bucket.client_ids)
            # shard a bucket's CLIENT axis only when its size divides the
            # data axis; a non-dividing bucket keeps the server fsdp x tp
            # sub-mesh (data collapsed to 1) rather than silently dropping
            # the requested server sharding
            b_mesh = mesh
            try:
                validate_fleet_mesh(b_mesh, n)
            except ValueError:
                b_mesh = _server_only_mesh(mesh)
            pspecs = (server_pspecs_fn(prog.params_s0, b_mesh)
                      if server_pspecs_fn is not None and b_mesh is not None
                      else None)
            # donate the bucket's stacked state round-over-round (batches
            # and the dropout mask are fresh each round and not donated)
            engine = jax.jit(make_fleet_sl_round(
                prog.step, opt_c, opt_s, local_rounds=local_rounds,
                mesh=b_mesh, client_dropout=client_dropout,
                server_reduce=server_reduce, client_axis=client_axis,
                server_pspecs=pspecs, taps=self.taps),
                donate_argnums=(0, 1, 2, 3))
            state = (_stack_replicas(prog.params_c0, n), prog.params_s0,
                     init_stacked(opt_c, prog.params_c0, n),
                     opt_s.init(prog.params_s0))
            self.programs[bucket.cut_index] = prog
            self._ids.append(np.asarray(bucket.client_ids))
            self._engines.append(engine)
            # the engine donates its state buffers; the initial tiers alias
            # the caller's (shared) model params, so fresh copies are made
            # whenever live/external state is materialized
            self._init_states.append(state)
        # the fleet's OWN live state (run_round/bucket_state surface) is
        # materialized lazily: callers threading state externally through
        # init_states()/run_round_on never pay for the internal copy
        self._states = None

    def reset(self) -> None:
        """Re-initialize every bucket's live state (compiled engines are
        kept), so one fleet can run several independent experiments."""
        self._states = self.init_states()

    def _live_states(self) -> list[tuple]:
        if self._states is None:
            self._states = self.init_states()
        return self._states

    def init_states(self) -> list[tuple]:
        """Fresh per-bucket state tuples, independent of the fleet's own
        live state — for callers that thread state externally through
        ``run_round_on`` (each copy may be donated exactly once)."""
        return [jax.tree_util.tree_map(jnp.copy, s)
                for s in self._init_states]

    @property
    def cut_of_client(self) -> list[int]:
        cuts = [0] * self.num_clients
        for bucket in self.buckets:
            for cid in bucket.client_ids:
                cuts[cid] = bucket.cut_index
        return cuts

    def bucket_state(self, i: int):
        """(params_c_stack, params_s, oc_stack, os) of bucket ``i``."""
        return self._live_states()[i]

    def run_round(self, batches, client_mask=None):
        """One global round. ``batches`` is a pytree with leading
        (num_clients, local_rounds) axes; returns losses
        (local_rounds, num_clients) with every client filled exactly once —
        plus the reassembled tap dict when the fleet was built with
        metrics ``taps``.

        ``client_mask`` (global (num_clients,) 0/1 vector) drops stragglers
        for the round; requires the fleet to be built with
        ``client_dropout=True`` (the mask is sliced per bucket and fed to
        each bucket's compiled round).
        """
        out = self.run_round_on(self._live_states(), batches, client_mask)
        self._states = out[0]
        return out[1] if not self.taps else out[1:]

    def run_round_on(self, states: list[tuple], batches, client_mask=None):
        """``run_round`` over caller-owned per-bucket states (as produced
        by ``init_states``): returns ``(new_states, losses)`` —
        ``(new_states, losses, taps)`` when built with metrics ``taps``,
        every tap a (local_rounds, num_clients) float32 array. The input
        state buffers are donated to the compiled rounds — reuse the
        returned list, never the argument."""
        if client_mask is not None and not self.client_dropout:
            raise ValueError("client_mask needs HeteroFleet("
                             "client_dropout=True)")
        losses = np.zeros((self.local_rounds, self.num_clients), np.float32)
        tap_out = {name: np.zeros((self.local_rounds, self.num_clients),
                                  np.float32) for name in self.taps}
        new_states = list(states)
        for i, ids in enumerate(self._ids):
            sub = jax.tree_util.tree_map(
                lambda x: jnp.take(x, jnp.asarray(ids), axis=0), batches)
            if self.client_dropout:
                mask = (np.ones(len(ids), np.float32) if client_mask is None
                        else np.asarray(client_mask, np.float32)[ids])
                out = self._engines[i](*states[i], sub, jnp.asarray(mask))
            else:
                out = self._engines[i](*states[i], sub)
            if self.taps:
                *state, bucket_losses, bucket_taps = out
                for name, v in bucket_taps.items():
                    v = np.asarray(v, np.float32)
                    # (local_rounds,) channels = this bucket's one server
                    # update per step, broadcast to its client columns
                    tap_out[name][:, ids] = v if v.ndim == 2 else v[:, None]
            else:
                *state, bucket_losses = out
            new_states[i] = tuple(state)
            losses[:, ids] = np.asarray(bucket_losses)
        if self.taps:
            return new_states, losses, tap_out
        return new_states, losses
