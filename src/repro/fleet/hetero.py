"""Per-client cut personalization with bucketed dispatch.

Heterogeneous edge fleets (P3SL, arXiv:2507.17228) don't share one best cut:
a Jetson-class client wants a deeper prefix than a microcontroller-class
one, and a starved link moves the optimum toward smaller smashed tensors.
Here every client gets its own cut from ``core.adaptive_cut.select_cut`` on
its own (hardware, link) profile, clients are grouped into *cut buckets*,
and each bucket runs its own compiled fleet round (``engine``): XLA programs
are shape-specialized per cut, so the bucket — not the client — is the
compilation unit. Every client belongs to exactly one bucket.

Both model families split the same way through ``SplitProgram``:

  * CNN ``Stage`` lists — slice the stage/param lists at k
    (``cnn_split_program``).
  * transformer ``split_stack`` models — slice the stacked layer axis at k
    and scan blocks on each side (``stack_split_program``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptive_cut import (profile_cuts_cnn, profile_cuts_transformer,
                                 select_cut)
from ..core.energy import HardwareProfile
from ..core.link import LinkConfig
from ..core.split import SplitStep, Stage, apply_stages, split_stack
from ..optim.optimizers import init_stacked
from .engine import make_fleet_sl_round, validate_fleet_mesh


# ---------------------------------------------------------------------------
# cut assignment + bucketing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CutBucket:
    cut_index: int
    client_ids: tuple[int, ...]   # global client indices, ascending


def bucket_by_cut(cut_indices: Sequence[int]) -> list[CutBucket]:
    """Group clients by cut index. Deterministic (ascending cut, ascending
    client id); the buckets partition the fleet — every client exactly once."""
    by_cut: dict[int, list[int]] = {}
    for cid, k in enumerate(cut_indices):
        by_cut.setdefault(int(k), []).append(cid)
    return [CutBucket(k, tuple(ids)) for k, ids in sorted(by_cut.items())]


def _assign_cuts(profile_fn: Callable, edges: Sequence[HardwareProfile],
                 links: Optional[Sequence[LinkConfig]],
                 max_link_s: Optional[float]) -> list[int]:
    """Shared per-client selection loop: identical (hardware, link) profiles
    share one cut-curve evaluation. ``profile_fn(edge, link)`` returns the
    cut choices for one profile."""
    links = list(links) if links is not None else [LinkConfig()] * len(edges)
    if len(links) != len(edges):
        raise ValueError("edges and links must be per-client (same length)")
    cache: dict[tuple, int] = {}
    cuts = []
    for edge, link in zip(edges, links):
        key = (edge, link)
        if key not in cache:
            cache[key] = select_cut(profile_fn(edge, link),
                                    max_link_s=max_link_s).cut_index
        cuts.append(cache[key])
    return cuts


def assign_cuts_cnn(stages: Sequence[Stage], params, sample_x, *,
                    edges: Sequence[HardwareProfile],
                    links: Optional[Sequence[LinkConfig]] = None,
                    min_client_layers: int = 1,
                    max_link_s: Optional[float] = None) -> list[int]:
    """Per-client minimum-energy cut for a CNN stage list. ``edges`` (and
    optionally ``links``) give each client its own profile."""
    return _assign_cuts(
        lambda edge, link: profile_cuts_cnn(
            stages, params, sample_x, edge=edge, link=link,
            min_client_layers=min_client_layers),
        edges, links, max_link_s)


def assign_cuts_transformer(cfg, *, batch: int, seq: int,
                            edges: Sequence[HardwareProfile],
                            links: Optional[Sequence[LinkConfig]] = None,
                            max_link_s: Optional[float] = None) -> list[int]:
    """Per-client minimum-energy cut for a transformer ArchConfig stack."""
    return _assign_cuts(
        lambda edge, link: profile_cuts_transformer(
            cfg, batch=batch, seq=seq, edge=edge, link=link),
        edges, links, max_link_s)


# ---------------------------------------------------------------------------
# split programs: one cut of one model family, as a SplitStep + params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SplitProgram:
    """A model split at one cut: the differentiable step + per-tier inits
    (every client in a bucket starts from the same prefix init)."""
    step: SplitStep
    params_c0: object
    params_s0: object
    cut_index: int


def cnn_split_program(stages: Sequence[Stage], params, k: int, *,
                      loss_fn: Callable,
                      link_boundary: Optional[Callable] = None) -> SplitProgram:
    """Split a CNN stage list at stage index ``k``. ``loss_fn(logits,
    targets) -> scalar`` closes the server side."""
    if not 1 <= k <= len(stages) - 1:
        raise ValueError(f"cut {k} outside (0, {len(stages)})")
    cs, cp = list(stages[:k]), list(params[:k])
    ss, sp = list(stages[k:]), list(params[k:])
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (loss_fn(apply_stages(ss, ps, sm), yy),
                                        {}),
        link_constraint=link_boundary,
    )
    return SplitProgram(step=step, params_c0=cp, params_s0=sp, cut_index=k)


def stack_split_program(stacked_params, k: int, *, block_apply: Callable,
                        loss_fn: Callable,
                        link_boundary: Optional[Callable] = None) -> SplitProgram:
    """Split a stacked-block (scan-over-layers) model at layer ``k``.

    ``block_apply(block_params, h) -> h`` applies ONE block (params without
    the stacked layer axis); ``loss_fn(h, targets) -> scalar`` closes the
    server side on the last hidden state. Each tier scans its slice of the
    stack, so the same program serves any transformer ``split_stack`` model.
    """
    params_c, params_s = split_stack(stacked_params, k)

    def run_blocks(stack, h):
        def body(h, blk):
            return block_apply(blk, h), None
        h, _ = jax.lax.scan(body, h, stack)
        return h

    step = SplitStep(
        client_fwd=run_blocks,
        server_loss=lambda ps, sm, yy: (loss_fn(run_blocks(ps, sm), yy), {}),
        link_constraint=link_boundary,
    )
    return SplitProgram(step=step, params_c0=params_c, params_s0=params_s,
                        cut_index=k)


# ---------------------------------------------------------------------------
# bucketed dispatch
# ---------------------------------------------------------------------------

def _stack_replicas(tree, n: int):
    return jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), tree)


class HeteroFleet:
    """Per-cut-bucket fleet engines over one shared client population.

    ``build_program(k) -> SplitProgram`` specializes the model to a cut;
    each bucket owns a compiled ``make_fleet_sl_round`` (its own server
    suffix — a cut-group is also a server-model group) and the stacked state
    of its clients. ``run_round(batches)`` slices the global
    (clients, local_steps, ...) batch stack per bucket, runs every bucket's
    compiled round, and reassembles losses into (local_steps, clients).
    """

    def __init__(self, build_program: Callable[[int], SplitProgram],
                 cut_indices: Sequence[int], opt_c, opt_s, *,
                 local_rounds: int, mesh=None):
        self.buckets = bucket_by_cut(cut_indices)
        self.local_rounds = local_rounds
        self.num_clients = len(cut_indices)
        self._ids: list[np.ndarray] = []
        self._engines = []
        self._states = []
        self.programs: dict[int, SplitProgram] = {}
        for bucket in self.buckets:
            prog = build_program(bucket.cut_index)
            if prog.cut_index != bucket.cut_index:
                raise ValueError("build_program returned a different cut")
            n = len(bucket.client_ids)
            # shard a bucket only when its size divides the data axis
            b_mesh = mesh
            try:
                validate_fleet_mesh(b_mesh, n)
            except ValueError:
                b_mesh = None
            # donate the bucket's stacked state round-over-round (batches,
            # argnum 4, are fresh each round and not donated)
            engine = jax.jit(make_fleet_sl_round(
                prog.step, opt_c, opt_s, local_rounds=local_rounds,
                mesh=b_mesh), donate_argnums=(0, 1, 2, 3))
            state = (_stack_replicas(prog.params_c0, n), prog.params_s0,
                     init_stacked(opt_c, prog.params_c0, n),
                     opt_s.init(prog.params_s0))
            # the engine donates its state buffers; the initial tiers alias
            # the caller's (shared) model params, so copy before donating
            state = jax.tree_util.tree_map(jnp.copy, state)
            self.programs[bucket.cut_index] = prog
            self._ids.append(np.asarray(bucket.client_ids))
            self._engines.append(engine)
            self._states.append(state)

    @property
    def cut_of_client(self) -> list[int]:
        cuts = [0] * self.num_clients
        for bucket in self.buckets:
            for cid in bucket.client_ids:
                cuts[cid] = bucket.cut_index
        return cuts

    def bucket_state(self, i: int):
        """(params_c_stack, params_s, oc_stack, os) of bucket ``i``."""
        return self._states[i]

    def run_round(self, batches) -> np.ndarray:
        """One global round. ``batches`` is a pytree with leading
        (num_clients, local_rounds) axes; returns losses
        (local_rounds, num_clients) with every client filled exactly once."""
        losses = np.zeros((self.local_rounds, self.num_clients), np.float32)
        for i, ids in enumerate(self._ids):
            sub = jax.tree_util.tree_map(
                lambda x: jnp.take(x, jnp.asarray(ids), axis=0), batches)
            *state, bucket_losses = self._engines[i](*self._states[i], sub)
            self._states[i] = tuple(state)
            losses[:, ids] = np.asarray(bucket_losses)
        return losses
