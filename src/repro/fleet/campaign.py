"""Multi-round fleet campaign simulator — the paper's mission, at fleet scale.

DEPRECATED SHIM — ``run_campaign`` keeps its ``CampaignConfig`` ->
``CampaignResult`` surface for one release, but the round loop now lives in
the unified experiment layer: ``campaign_spec`` maps the config to an
``repro.api.ExperimentSpec`` with a ``MissionSpec`` attached, and
``compile_experiment`` lowers it to the same sharded fleet engine +
bucketed hetero cuts + link/energy/UAV accounting this module used to
hand-assemble. New code should build specs directly (see
``src/repro/api/README.md``).

One campaign still composes the repo's layers end-to-end:

  field      client placement on a farm (``api.plan.client_coords``)
  tour       exact-TSP UAV tour + Algorithm 2's delayed-return round budget
  training   the sharded fleet SL engine — homogeneous cut, or per-client
             cuts bucketed by ``fleet.hetero``; optional P3SL-style client
             dropout (``dropout_rate``)
  link       fp32 or int8-compressed boundary, wire bytes/time/energy per
             step; under adaptive cuts the UAV hover window bounds each
             step's link time (``runtime.mission_max_link_s``)
  energy     per-step compute constants from symmetric FLOP counting,
             scaled to each client's edge profile via Eq. (9)

and emits one ``RoundRecord`` per executed global round. The number of
executed rounds is ``min(cfg.global_rounds, tour.rounds)``: the UAV's
energy budget, not the caller, caps the campaign.
"""
from __future__ import annotations

import dataclasses

# Re-exported: the campaign's record type IS the uniform api record now,
# and client_coords moved to the (import-neutral) api runtime module.
from ..api.records import RoundRecord  # noqa: F401
from ..api.runtime import client_coords  # noqa: F401
from ..core.energy import HardwareProfile, JETSON_AGX_ORIN
from ..core.link import LinkConfig
from ..core.trajectory import TourPlan
from ..core.uav_energy import DEFAULT_UAV, UAVParams


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    model: str = "tinycnn"
    num_classes: int = 12
    num_clients: int = 8
    client_fraction: float = 0.4       # homogeneous cut (adaptive_cuts off)
    adaptive_cuts: bool = False        # per-client cuts via fleet.hetero
    global_rounds: int = 4             # cap; the UAV budget may cut it short
    local_steps: int = 2
    batch_size: int = 8
    image_size: int = 16
    classes_per_client: int = 3
    lr: float = 1e-3
    link: LinkConfig = LinkConfig()
    farm_acres: float = 100.0
    uav: UAVParams = DEFAULT_UAV
    hover_s_per_stop: float = 30.0
    comm_s_per_stop: float = 10.0
    # heterogeneity source for adaptive cuts: profiles cycled across clients
    edge_profiles: tuple[HardwareProfile, ...] = (JETSON_AGX_ORIN,)
    # P3SL-style straggler masking: per-round client dropout probability
    dropout_rate: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class CampaignResult:
    config: CampaignConfig
    tour: TourPlan
    rounds_budget: int           # rounds the UAV battery affords (gamma)
    records: list[RoundRecord]
    metrics: dict                # final held-out classification metrics
    cut_of_client: list[int]

    def totals(self) -> dict:
        return {
            "rounds_run": len(self.records),
            "link_bytes": sum(r.link_bytes for r in self.records),
            "link_energy_j": sum(r.link_energy_j for r in self.records),
            "client_energy_j": sum(r.client_energy_j for r in self.records),
            "server_energy_j": sum(r.server_energy_j for r in self.records),
            "uav_energy_j": sum(r.uav_energy_j for r in self.records)
            + self.tour.e_return,
            "final_accuracy": self.metrics.get("accuracy", 0.0),
        }


def campaign_spec(cfg: CampaignConfig):
    """The ``ExperimentSpec`` a legacy ``CampaignConfig`` stands for: the
    parallel fleet SL engine (``sl/vmap``) under a UAV mission."""
    # deferred: repro.api imports fleet.engine/hetero, so a module-level
    # import here would cycle through this package's own __init__
    from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec)
    return ExperimentSpec(
        model=ModelSpec(name=cfg.model, num_classes=cfg.num_classes),
        data=DataSpec(kind="synthetic", image_size=cfg.image_size,
                      classes_per_client=cfg.classes_per_client),
        clients=ClientSpec(num_clients=cfg.num_clients,
                           edge_profiles=cfg.edge_profiles,
                           dropout_rate=cfg.dropout_rate),
        cut_policy=CutPolicy(
            mode="adaptive" if cfg.adaptive_cuts else "fraction",
            fraction=cfg.client_fraction),
        link_policy=LinkPolicy(rate_bps=cfg.link.rate_bps,
                               compress=cfg.link.compress,
                               radio_power_w=cfg.link.radio_power_w),
        engine=EngineSpec(kind="sl", client_axis="vmap"),
        mission=MissionSpec(farm_acres=cfg.farm_acres, uav=cfg.uav,
                            hover_s_per_stop=cfg.hover_s_per_stop,
                            comm_s_per_stop=cfg.comm_s_per_stop),
        global_rounds=cfg.global_rounds, local_steps=cfg.local_steps,
        batch_size=cfg.batch_size, lr=cfg.lr, seed=cfg.seed)


def run_campaign(cfg: CampaignConfig, *, data=None, mesh=None) -> CampaignResult:
    """Run one fleet campaign (deprecated shim over ``compile_experiment``).
    ``data`` is an optional ``(x_train, y_train, x_test, y_test)`` tuple
    (synthetic pests when omitted); ``mesh`` an optional ('data','model')
    fleet mesh — the client axis shards over ``data``."""
    from ..api.plan import compile_experiment
    spec = campaign_spec(cfg)
    if data is not None:
        spec = dataclasses.replace(spec, data=dataclasses.replace(
            spec.data, kind="arrays"))
    plan = compile_experiment(spec, mesh=mesh, data=data)
    state, records = plan.run()
    metrics = (state.last_metrics if state.last_metrics is not None
               else plan.evaluate(state))   # budget afforded zero rounds
    return CampaignResult(config=cfg, tour=plan.tour,
                          rounds_budget=plan.rounds_budget,
                          records=records, metrics=metrics,
                          cut_of_client=plan.cut_of_client)


def run_link_sweep(cfg: CampaignConfig, *, data=None,
                   mesh=None) -> dict[str, CampaignResult]:
    """The fp32-vs-int8 link comparison on one scenario: same fleet, same
    tour, same seeds — only the link boundary and its wire bytes change."""
    out = {}
    for mode in ("none", "int8"):
        link = dataclasses.replace(cfg.link, compress=mode)
        out[mode] = run_campaign(dataclasses.replace(cfg, link=link),
                                 data=data, mesh=mesh)
    return out
