"""Fleet campaign configs — the paper's UAV mission, at fleet scale, as specs.

The legacy ``run_campaign`` / ``run_link_sweep`` runners are GONE (one
release as deprecated shims over the unified experiment layer — see
CHANGES.md). What remains is the mapping layer: ``CampaignConfig`` is the
historical config surface and ``campaign_spec`` turns one into the
``repro.api.ExperimentSpec`` (with a ``MissionSpec`` attached) the old
runner stood for. Run it with::

    plan = repro.api.compile_experiment(campaign_spec(cfg), mesh=...)
    state, records = plan.run()        # one RoundRecord per executed round

One campaign still composes the repo's layers end-to-end:

  field      client placement on a farm (``api.runtime.client_coords``)
  tour       exact-TSP UAV tour + Algorithm 2's delayed-return round budget
             (``plan.tour`` / ``plan.rounds_budget``)
  training   the sharded fleet SL engine — homogeneous cut, or per-client
             cuts bucketed by ``fleet.hetero``; optional P3SL-style client
             dropout (``dropout_rate``)
  link       fp32 or int8-compressed boundary, wire bytes/time/energy per
             step; under adaptive cuts the UAV hover window bounds each
             step's link time (``runtime.mission_max_link_s``)
  energy     per-step compute constants from symmetric FLOP counting,
             scaled to each client's edge profile via Eq. (9)

The number of executed rounds is ``min(cfg.global_rounds, tour.rounds)``:
the UAV's energy budget, not the caller, caps the campaign. The fp32-vs-
int8 link sweep is two specs differing only in ``LinkPolicy.compress``
(``dataclasses.replace(cfg, link=...)`` — see ``tests/test_fleet.py`` and
``examples/uav_mission_sim.py``).
"""
from __future__ import annotations

import dataclasses

# Re-exported: the campaign's record type IS the uniform api record, and
# client_coords lives in the (import-neutral) api runtime module.
from ..api.records import RoundRecord  # noqa: F401
from ..api.runtime import client_coords  # noqa: F401
from ..core.energy import HardwareProfile, JETSON_AGX_ORIN
from ..core.link import LinkConfig
from ..core.uav_energy import DEFAULT_UAV, UAVParams


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    model: str = "tinycnn"
    num_classes: int = 12
    num_clients: int = 8
    client_fraction: float = 0.4       # homogeneous cut (adaptive_cuts off)
    adaptive_cuts: bool = False        # per-client cuts via fleet.hetero
    global_rounds: int = 4             # cap; the UAV budget may cut it short
    local_steps: int = 2
    batch_size: int = 8
    image_size: int = 16
    classes_per_client: int = 3
    lr: float = 1e-3
    link: LinkConfig = LinkConfig()
    farm_acres: float = 100.0
    uav: UAVParams = DEFAULT_UAV
    hover_s_per_stop: float = 30.0
    comm_s_per_stop: float = 10.0
    # heterogeneity source for adaptive cuts: profiles cycled across clients
    edge_profiles: tuple[HardwareProfile, ...] = (JETSON_AGX_ORIN,)
    # P3SL-style straggler masking: per-round client dropout probability
    dropout_rate: float = 0.0
    # population-scale rounds: total registered fleet; each round samples a
    # cohort of num_clients from it (None == fully-materialized fleet).
    # See ClientSpec.population.
    population: int | None = None
    # stochastic environment (repro.sim.ScenarioSpec): A2G channel draws,
    # availability traces, multi-UAV dispatch; None keeps the idealized
    # constant-rate / always-available campaign
    scenario: object = None
    seed: int = 0


def campaign_totals(records, tour) -> dict:
    """Mission totals over a campaign's ``RoundRecord`` stream.

    Per-round ``uav_energy_j`` bills the tour legs actually flown that
    round; the return-to-base leg (``tour.e_return``) is flown once at
    mission end and appears in NO record — Algorithm 2's delayed-return
    budget (``core.trajectory.budget_rounds``) reserves it, so summing
    records alone under-counts the mission by exactly that leg. This
    helper is the bookkeeping the old ``CampaignResult.totals()``
    carried; pass ``plan.tour``.
    """
    return {
        "rounds_run": len(records),
        "link_bytes": sum(r.link_bytes for r in records),
        "link_energy_j": sum(r.link_energy_j for r in records),
        "client_energy_j": sum(r.client_energy_j for r in records),
        "server_energy_j": sum(r.server_energy_j for r in records),
        "uav_energy_j": sum(r.uav_energy_j for r in records)
        + (tour.e_return if tour is not None else 0.0),
        "final_accuracy": records[-1].accuracy if records else 0.0,
    }


def mission_obs_events(plan, records) -> list[dict]:
    """Tour legs as telemetry spans: one event per (round, UAV, leg) on the
    SIMULATED mission clock, so a run's UAV dwell decomposes into

      travel  cruise between stops (tour length / cruise speed V)
      hover   serve-window dwell while clients compute (the paper's
              ``hover_s_per_stop`` budget — this is the compute window)
      comm    the per-stop communication dwell that prices the link
              (``comm_s_per_stop`` — the window ``mission_max_link_s``
              bounds adaptive cuts against)

    Events carry ``clock: "mission"`` and ``t_mission_s`` (seconds into the
    mission) instead of the wall-clock ``t`` of ordinary spans — wall time
    of a simulated campaign says nothing about UAV endurance. Aggregation
    is per round (hover/comm dwell interleave per stop in flight; the
    decomposition bills their totals). ``Plan.run`` emits these into the
    event stream when telemetry is on and a mission is attached;
    ``tools/obs_report.py`` renders the breakdown next to the wall-clock
    phases.
    """
    mission = plan.spec.mission
    if mission is None or not records:
        return []
    v = max(mission.uav.V, 1e-9)
    events = []
    if plan.timeline is not None:
        tl = plan.timeline
        starts = tl.round_start_s
        for rec in records:
            r = rec.round
            t0 = float(starts[r]) if r < len(starts) else float(
                starts[-1] + (r - len(starts) + 1) * tl.round_duration_s)
            for route in tl.routes:
                legs = (("travel", route.tour.tour_length / v),
                        ("hover", len(route.client_ids)
                         * mission.hover_s_per_stop),
                        ("comm", len(route.client_ids)
                         * mission.comm_s_per_stop))
                t = t0
                for name, dur in legs:
                    events.append({"ev": "mission_span",
                                   "name": f"mission/{name}",
                                   "round": r, "uav": route.uav,
                                   "clock": "mission",
                                   "t_mission_s": round(t, 3),
                                   "dur_s": round(float(dur), 3)})
                    t += dur
        return events
    tour = plan.tour
    n = plan.spec.clients.num_clients
    legs = (("travel", tour.tour_length / v),
            ("hover", n * mission.hover_s_per_stop),
            ("comm", n * mission.comm_s_per_stop))
    round_s = sum(d for _, d in legs)
    for rec in records:
        t = rec.round * round_s
        for name, dur in legs:
            events.append({"ev": "mission_span", "name": f"mission/{name}",
                           "round": rec.round, "uav": 0, "clock": "mission",
                           "t_mission_s": round(t, 3),
                           "dur_s": round(float(dur), 3)})
            t += dur
    return events


def campaign_spec(cfg: CampaignConfig):
    """The ``ExperimentSpec`` a legacy ``CampaignConfig`` stands for: the
    parallel fleet SL engine (``sl/vmap``) under a UAV mission."""
    # deferred: repro.api imports fleet.engine/hetero, so a module-level
    # import here would cycle through this package's own __init__
    from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec)
    return ExperimentSpec(
        model=ModelSpec(name=cfg.model, num_classes=cfg.num_classes),
        data=DataSpec(kind="synthetic", image_size=cfg.image_size,
                      classes_per_client=cfg.classes_per_client),
        clients=ClientSpec(num_clients=cfg.num_clients,
                           edge_profiles=cfg.edge_profiles,
                           dropout_rate=cfg.dropout_rate,
                           population=cfg.population),
        cut_policy=CutPolicy(
            mode="adaptive" if cfg.adaptive_cuts else "fraction",
            fraction=cfg.client_fraction),
        link_policy=LinkPolicy(rate_bps=cfg.link.rate_bps,
                               compress=cfg.link.compress,
                               radio_power_w=cfg.link.radio_power_w),
        engine=EngineSpec(kind="sl", client_axis="vmap"),
        mission=MissionSpec(farm_acres=cfg.farm_acres, uav=cfg.uav,
                            hover_s_per_stop=cfg.hover_s_per_stop,
                            comm_s_per_stop=cfg.comm_s_per_stop),
        scenario=cfg.scenario,
        global_rounds=cfg.global_rounds, local_steps=cfg.local_steps,
        batch_size=cfg.batch_size, lr=cfg.lr, seed=cfg.seed)
