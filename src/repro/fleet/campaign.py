"""Multi-round fleet campaign simulator — the paper's mission, at fleet scale.

One campaign composes the repo's layers end-to-end:

  field      client placement on a farm (jittered grid over ``farm_acres``)
  tour       exact-TSP UAV tour + Algorithm 2's delayed-return round budget
             (``core.trajectory`` / ``core.uav_energy``)
  training   the sharded fleet SL engine (``fleet.engine``) — homogeneous
             cut, or per-client cuts bucketed by ``fleet.hetero``
  link       fp32 or int8-compressed boundary (``fleet.link``), with wire
             bytes/time/energy accounted per step
  energy     per-step compute constants from symmetric FLOP counting
             (``core.paper_train.count_sl_step_flops`` over ``core.flops``),
             scaled to each client's edge profile via Eq. (9)

and emits one ``RoundRecord`` per executed global round — loss, accuracy,
link bytes, client/server/UAV energy — i.e. the paper's rounds-vs-energy
tradeoff curves, sweepable over fleet sizes, models, cuts and link modes
(``run_link_sweep`` runs the fp32-vs-int8 pair on one config).

The number of executed rounds is ``min(cfg.global_rounds, tour.rounds)``:
the UAV's energy budget, not the caller, caps the campaign.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core.energy import (HardwareProfile, JETSON_AGX_ORIN, RTX_A5000,
                           scale_time)
from ..core.link import LinkConfig
from ..core.paper_train import classification_metrics, count_sl_step_flops
from ..models.cnn import CNN_BUILDERS, cross_entropy_loss
from ..core.split import apply_stages, init_stages
from ..core.trajectory import TourPlan, plan_tour
from ..core.uav_energy import DEFAULT_UAV, UAVParams
from ..data.partition import partition_non_iid
from ..data.synthetic import SyntheticPestImages
from ..optim import adamw
from .engine import validate_fleet_mesh
from .hetero import HeteroFleet, assign_cuts_cnn, cnn_split_program
from .link import FleetLink


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    model: str = "tinycnn"
    num_classes: int = 12
    num_clients: int = 8
    client_fraction: float = 0.4       # homogeneous cut (adaptive_cuts off)
    adaptive_cuts: bool = False        # per-client cuts via fleet.hetero
    global_rounds: int = 4             # cap; the UAV budget may cut it short
    local_steps: int = 2
    batch_size: int = 8
    image_size: int = 16
    classes_per_client: int = 3
    lr: float = 1e-3
    link: LinkConfig = LinkConfig()
    farm_acres: float = 100.0
    uav: UAVParams = DEFAULT_UAV
    hover_s_per_stop: float = 30.0
    comm_s_per_stop: float = 10.0
    # heterogeneity source for adaptive cuts: profiles cycled across clients
    edge_profiles: tuple[HardwareProfile, ...] = (JETSON_AGX_ORIN,)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    round: int
    loss: float                  # fleet-mean training loss this round
    accuracy: float              # held-out accuracy after the round
    link_bytes: float            # wire bytes this round (all clients/steps)
    link_time_s: float
    link_energy_j: float         # edge radio transmit energy (L/R * P_radio)
    client_energy_j: float       # edge compute, Eq. (9)-scaled
    server_energy_j: float
    uav_energy_j: float          # tour energy for this round (Alg. 2)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CampaignResult:
    config: CampaignConfig
    tour: TourPlan
    rounds_budget: int           # rounds the UAV battery affords (gamma)
    records: list[RoundRecord]
    metrics: dict                # final held-out classification metrics
    cut_of_client: list[int]

    def totals(self) -> dict:
        return {
            "rounds_run": len(self.records),
            "link_bytes": sum(r.link_bytes for r in self.records),
            "link_energy_j": sum(r.link_energy_j for r in self.records),
            "client_energy_j": sum(r.client_energy_j for r in self.records),
            "server_energy_j": sum(r.server_energy_j for r in self.records),
            "uav_energy_j": sum(r.uav_energy_j for r in self.records)
            + self.tour.e_return,
            "final_accuracy": self.metrics.get("accuracy", 0.0),
        }


def client_coords(acres: float, n: int, *, seed: int = 0) -> np.ndarray:
    """``n`` edge-device positions on a square farm: a jittered uniform grid
    over the next square count, truncated to ``n`` (deterministic)."""
    from ..core.deployment import field_side_meters
    side = field_side_meters(acres)
    g = int(math.ceil(math.sqrt(n)))
    xs = (np.arange(g) + 0.5) * side / g
    pts = np.stack(np.meshgrid(xs, xs, indexing="ij"), axis=-1).reshape(-1, 2)
    rng = np.random.RandomState(seed)
    pts = pts + rng.uniform(-0.05, 0.05, size=pts.shape) * side / g
    return pts[:n]


def _round_batches(x, y, parts, batch_size, steps, rng):
    """(clients, steps, batch_size, ...) minibatch stacks for one global
    round. Sampling is with replacement, so small partitions still yield
    full batches — the hoisted per-step link/energy constants (computed for
    ``batch_size``) stay exact."""
    empty = [ci for ci, idx in enumerate(parts) if len(idx) == 0]
    if empty:
        raise ValueError(f"clients {empty} drew no data; increase the "
                         f"training set or classes_per_client")
    sel = np.stack([rng.choice(idx, size=(steps, batch_size), replace=True)
                    for idx in parts])
    return jnp.asarray(x[sel]), jnp.asarray(y[sel])


def _client_step_time_s(flops: float, edge: HardwareProfile) -> float:
    return scale_time(flops / (RTX_A5000.fp32_tflops * 1e12), RTX_A5000, edge)


def run_campaign(cfg: CampaignConfig, *, data=None, mesh=None) -> CampaignResult:
    """Run one fleet campaign. ``data`` is an optional
    ``(x_train, y_train, x_test, y_test)`` tuple (synthetic pests when
    omitted); ``mesh`` an optional ('data','model') fleet mesh — the client
    axis shards over ``data`` (see ``launch.mesh.make_fleet_mesh``)."""
    validate_fleet_mesh(mesh, cfg.num_clients)
    link = FleetLink(config=cfg.link)

    # ---- data -------------------------------------------------------------
    if data is None:
        gen = SyntheticPestImages(num_classes=cfg.num_classes,
                                  image_size=cfg.image_size, seed=cfg.seed)
        key = jax.random.PRNGKey(cfg.seed)
        n_train = max(24 * cfg.num_clients, 12 * cfg.num_classes)
        x_train, y_train = gen.sample(jax.random.fold_in(key, 0), n_train)
        x_test, y_test = gen.sample(jax.random.fold_in(key, 1),
                                    max(n_train // 4, 48))
        x_train, y_train = np.asarray(x_train), np.asarray(y_train)
        x_test, y_test = np.asarray(x_test), np.asarray(y_test)
    else:
        x_train, y_train, x_test, y_test = (np.asarray(a) for a in data)
    parts = partition_non_iid(y_train, cfg.num_clients, cfg.classes_per_client,
                              num_classes=cfg.num_classes, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)

    # ---- mission: placement, tour, round budget ---------------------------
    coords = client_coords(cfg.farm_acres, cfg.num_clients, seed=cfg.seed)
    tour = plan_tour(coords, np.zeros(2), params=cfg.uav,
                     hover_s_per_stop=cfg.hover_s_per_stop,
                     comm_s_per_stop=cfg.comm_s_per_stop)
    rounds_run = min(cfg.global_rounds, tour.rounds)

    # ---- model + per-client cuts ------------------------------------------
    stages = CNN_BUILDERS[cfg.model](cfg.num_classes)
    params = init_stages(jax.random.PRNGKey(cfg.seed), stages)
    sample_x = jnp.asarray(x_train[:cfg.batch_size])
    sample_y = jnp.asarray(y_train[:cfg.batch_size])
    edges = [cfg.edge_profiles[i % len(cfg.edge_profiles)]
             for i in range(cfg.num_clients)]
    if cfg.adaptive_cuts:
        cuts = assign_cuts_cnn(stages, params, sample_x, edges=edges,
                               links=[cfg.link] * cfg.num_clients)
    else:
        from ..core.split import cut_index_for_fraction
        cuts = [cut_index_for_fraction(stages, cfg.client_fraction)
                ] * cfg.num_clients
    opt_c, opt_s = adamw(cfg.lr), adamw(cfg.lr)

    def build_program(k):
        return cnn_split_program(stages, params, k,
                                 loss_fn=cross_entropy_loss,
                                 link_boundary=link.boundary())

    fleet = HeteroFleet(build_program, cuts, opt_c, opt_s,
                        local_rounds=cfg.local_steps, mesh=mesh)

    # ---- hoisted per-step constants (per bucket: flops + link bytes) ------
    x_test_j = jnp.asarray(x_test)
    per_client_t = np.zeros(cfg.num_clients)
    per_client_t_server = np.zeros(cfg.num_clients)
    per_client_link_bytes = np.zeros(cfg.num_clients)
    per_client_link_time = np.zeros(cfg.num_clients)
    per_client_link_energy = np.zeros(cfg.num_clients)
    bucket_eval = []
    for bucket in fleet.buckets:
        prog = fleet.programs[bucket.cut_index]
        cs, ss = list(stages[:bucket.cut_index]), list(stages[bucket.cut_index:])
        fl_client, fl_server, smashed_sd = count_sl_step_flops(
            cs, prog.params_c0, ss, prog.params_s0, sample_x, sample_y)
        for cid in bucket.client_ids:
            per_client_t[cid] = _client_step_time_s(fl_client, edges[cid])
            # each bucket has its own server suffix — bill its own step time
            per_client_t_server[cid] = fl_server / (RTX_A5000.fp32_tflops
                                                    * 1e12)
            per_client_link_bytes[cid] = link.step_wire_bytes(smashed_sd)
            per_client_link_time[cid] = link.step_time_s(smashed_sd)
            per_client_link_energy[cid] = link.step_energy_j(smashed_sd)
        bucket_eval.append(jax.jit(
            lambda cp, sp_, cs=cs, ss=ss: apply_stages(
                ss, sp_, apply_stages(cs, cp, x_test_j))))

    # ---- evaluation: every bucket's model votes on the held-out set -------
    def evaluate() -> dict:
        logits = jnp.zeros((len(x_test), cfg.num_classes), jnp.float32)
        for i, bucket in enumerate(fleet.buckets):
            client_stack, params_s, _, _ = fleet.bucket_state(i)
            prefix = jax.tree_util.tree_map(lambda v: v[0], client_stack)
            out = bucket_eval[i](prefix, params_s)
            logits = logits + out.astype(jnp.float32) * len(bucket.client_ids)
        return classification_metrics(logits / cfg.num_clients, y_test,
                                      cfg.num_classes)

    # ---- the campaign loop ------------------------------------------------
    records: list[RoundRecord] = []
    metrics = None
    for rnd in range(rounds_run):
        bx, by = _round_batches(x_train, y_train, parts, cfg.batch_size,
                                cfg.local_steps, rng)
        losses = fleet.run_round({"inputs": bx, "targets": by})
        metrics = evaluate()
        steps = cfg.local_steps
        records.append(RoundRecord(
            round=rnd,
            loss=float(losses.mean()),
            accuracy=metrics["accuracy"],
            link_bytes=float(per_client_link_bytes.sum() * steps),
            link_time_s=float(per_client_link_time.sum() * steps),
            link_energy_j=float(per_client_link_energy.sum() * steps),
            client_energy_j=float(sum(
                per_client_t[c] * steps * edges[c].power_w
                for c in range(cfg.num_clients))),
            server_energy_j=float(per_client_t_server.sum() * steps
                                  * RTX_A5000.power_w),
            uav_energy_j=float(tour.e_first if rnd == 0 else tour.e_per_round),
        ))
    if metrics is None:           # budget afforded zero rounds
        metrics = evaluate()
    return CampaignResult(config=cfg, tour=tour, rounds_budget=tour.rounds,
                          records=records, metrics=metrics,
                          cut_of_client=fleet.cut_of_client)


def run_link_sweep(cfg: CampaignConfig, *, data=None,
                   mesh=None) -> dict[str, CampaignResult]:
    """The fp32-vs-int8 link comparison on one scenario: same fleet, same
    tour, same seeds — only the link boundary and its wire bytes change."""
    out = {}
    for mode in ("none", "int8"):
        link = dataclasses.replace(cfg.link, compress=mode)
        out[mode] = run_campaign(dataclasses.replace(cfg, link=link),
                                 data=data, mesh=mesh)
    return out
