"""Sharded SPMD fleet rounds: the stacked client axis over the `data` mesh axis.

PR 1's round builders carry every per-client quantity (params, Adam moments,
minibatches) on a leading client axis but walk that axis with ``lax.scan`` —
sequential by construction. Here the client axis becomes a *batch* axis:

  * FL — ``make_fleet_fl_round``: ``jax.vmap`` over clients of the local-step
    scan (clients are fully independent until FedAvg), i.e.
    ``make_fl_round(..., client_axis='vmap')`` plus sharding constraints.
  * SL — ``make_fleet_sl_round``: Efficient *Parallel* Split Learning (Lin et
    al., arXiv:2303.15991): every client's prefix fwd/bwd runs batched via
    vmap against the shared server suffix, and the server applies ONE update
    per local step on the client-mean gradient, instead of Algorithm 3's
    sequential per-client server updates. This is a deliberate semantic
    variant (the UAV relays all clients' smashed data per hover window); it
    is NOT numerically equivalent to ``make_multi_client_round`` — its
    reference is the parallel host loop in ``tests/test_fleet.py``.

With a ``('data','model')`` mesh the leading client axis is
sharding-constrained to ``data``, so XLA partitions the fleet across
devices and FedAvg / the server's client-mean gradient lower to all-reduces
over ``data`` — N clients, one SPMD program, zero host round-trips.

Equivalence tolerance
---------------------
``FLEET_EQUIV_ATOL`` is the documented loosened bound for fleet-vs-scan
comparisons. The scanned engine matches the per-client host loop to 1e-4;
vmapping the client axis batches the convolutions and reassociates their
fp32 reductions (and sharding re-tiles them again), which drifts losses by
up to ~1e-3 after a few Adam steps on the tiny test models. Independent
clients make this pure arithmetic reassociation, not a semantic change.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.fedavg import (fedavg_mean_masked, fedavg_stack,
                           fedavg_stack_masked)
from ..core.split import SplitStep, make_fl_round
from ..optim.optimizers import apply_updates

# Documented loosened tolerance for vmapped/sharded vs sequential rounds
# (see module docstring; tests and benches assert against this bound).
FLEET_EQUIV_ATOL = 1e-3


def fleet_sharding(mesh) -> NamedSharding:
    """Sharding of a client-stacked leaf: leading axis over ``data``."""
    return NamedSharding(mesh, P("data"))


def validate_fleet_mesh(mesh, num_clients: int) -> None:
    """The client axis must divide evenly over ``data`` — no silent padding."""
    if mesh is None:
        return
    data = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    if num_clients % data:
        raise ValueError(
            f"{num_clients} clients do not divide over data={data}; pick a "
            f"fleet size divisible by the mesh's data axis (launch.mesh."
            f"make_fleet_mesh chooses one automatically)")


def shard_client_stack(tree, mesh):
    """Host-side placement of a client-stacked pytree onto the fleet mesh."""
    if mesh is None:
        return tree
    s = fleet_sharding(mesh)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, s), tree)


def _constrain(tree, mesh):
    if mesh is None:
        return tree
    s = fleet_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(x, s), tree)


def make_fleet_fl_round(grad_fn: Callable, opt, *, mesh=None,
                        client_dropout: bool = False):
    """FL baseline round with the client axis vmapped and (optionally)
    sharded over ``data``. Same signature/returns as ``make_fl_round``:
    ``f(global_params, batches) -> (new_global_params, losses[C, S])``.

    With ``client_dropout`` the round takes a trailing ``client_mask``
    (clients,) 0/1 argument: masked clients still execute (the program is
    shape-static) but are excluded from FedAvg — stragglers that missed
    the round contribute nothing to the new global model. All-masked
    rounds leave the global params unchanged.
    """
    vmapped = make_fl_round(grad_fn, opt, client_axis="vmap",
                            aggregate=not client_dropout)

    if not client_dropout:
        def global_round(global_params, batches):
            batches = _constrain(batches, mesh)
            new_params, losses = vmapped(global_params, batches)
            # FedAvg already reduced the client axis (all-reduce over `data`
            # when sharded); losses keep the client-sharded layout.
            return new_params, _constrain(losses, mesh)

        return global_round

    def global_round_masked(global_params, batches, client_mask):
        batches = _constrain(batches, mesh)
        client_stack, losses = vmapped(global_params, batches)
        new_params = fedavg_mean_masked(client_stack, client_mask,
                                        global_params)
        return new_params, _constrain(losses, mesh)

    return global_round_masked


def make_fleet_sl_round(step: SplitStep, opt_c, opt_s, *, local_rounds: int,
                        mesh=None, server_reduce: str = "mean",
                        client_dropout: bool = False):
    """One global round of *parallel* split learning over a sharded fleet.

    Per local step: every client's prefix runs fwd/bwd batched (vmap over
    the stacked client params/opt-states/batches) against the shared server
    suffix; client updates are per-client, the server takes one update on
    the ``server_reduce`` ('mean' | 'sum') of the per-client server
    gradients. After ``local_rounds`` steps the client prefixes are
    FedAvg'd, all inside the one compiled program.

    Signature matches ``make_multi_client_round``:
    ``f(params_c_stack, params_s, oc_stack, os_, batches)`` with ``batches``
    leading (clients, local_rounds) axes; losses return as
    ``(local_rounds, clients)``.

    With ``client_dropout`` the round takes a trailing ``client_mask``
    (clients,) 0/1 argument (traced — one compile serves every mask):
    P3SL-style stragglers. Masked clients keep their params/opt state
    frozen for the round, contribute nothing to the server's reduced
    gradient, and are excluded from the closing FedAvg (they rejoin later
    from their stale prefix). A fully-masked round is a no-op on all state.
    """
    if server_reduce not in ("mean", "sum"):
        raise ValueError(server_reduce)

    def _run_round(params_c_stack, params_s, oc_stack, os_, batches, mask):
        params_c_stack = _constrain(params_c_stack, mesh)
        oc_stack = _constrain(oc_stack, mesh)
        batches = _constrain(batches, mesh)
        # (clients, local_rounds, ...) -> (local_rounds, clients, ...)
        batches_rm = jax.tree_util.tree_map(
            lambda x: jnp.swapaxes(x, 0, 1), batches)
        n_active = None if mask is None else jnp.maximum(mask.sum(), 1.0)

        def per_client_grads(pc, batch, ps):
            loss, _aux, g_c, g_s = step.grads(pc, ps, batch)
            return loss, g_c, g_s

        def masked_rows(new, old):
            """Keep masked clients' leading-axis rows at their old value."""
            def sel(n, o):
                w = mask.reshape((n.shape[0],) + (1,) * (n.ndim - 1))
                return jnp.where(w > 0, n, o)
            return jax.tree_util.tree_map(sel, new, old)

        def round_body(carry, batch_r):
            params_c_stack, oc_stack, params_s, os_ = carry
            losses, g_c_stack, g_s_stack = jax.vmap(
                per_client_grads, in_axes=(0, 0, None))(
                    params_c_stack, batch_r, params_s)
            up_c, oc_new = jax.vmap(opt_c.update)(
                g_c_stack, oc_stack, params_c_stack)
            pc_new = apply_updates(params_c_stack, up_c)
            if mask is not None:
                pc_new = masked_rows(pc_new, params_c_stack)
                oc_new = masked_rows(oc_new, oc_stack)
            params_c_stack, oc_stack = pc_new, oc_new
            # server: ONE update on the fleet-reduced gradient (all-reduce
            # over `data` when the client axis is sharded)
            def reduce_g(g):
                g32 = g.astype(jnp.float32)
                if mask is None:
                    r = jnp.mean if server_reduce == "mean" else jnp.sum
                    return r(g32, axis=0).astype(g.dtype)
                w = mask.reshape((g.shape[0],) + (1,) * (g.ndim - 1))
                s = (g32 * w).sum(axis=0)
                if server_reduce == "mean":
                    s = s / n_active
                return s.astype(g.dtype)
            g_s = jax.tree_util.tree_map(reduce_g, g_s_stack)
            up_s, os_new = opt_s.update(g_s, os_, params_s)
            ps_new = apply_updates(params_s, up_s)
            if mask is not None:
                # zero active clients -> the server also sits the round out
                any_active = mask.sum() > 0
                ps_new = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(any_active, n, o), ps_new, params_s)
                os_new = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(any_active, n, o), os_new, os_)
            return (params_c_stack, oc_stack, ps_new, os_new), losses

        carry = (params_c_stack, oc_stack, params_s, os_)
        carry, losses = jax.lax.scan(round_body, carry, batches_rm)
        params_c_stack, oc_stack, params_s, os_ = carry
        agg = (fedavg_stack(params_c_stack) if mask is None
               else fedavg_stack_masked(params_c_stack, mask))
        params_c_stack = _constrain(agg, mesh)
        return params_c_stack, params_s, oc_stack, os_, losses

    if client_dropout:
        def global_round_masked(params_c_stack, params_s, oc_stack, os_,
                                batches, client_mask):
            mask = jnp.asarray(client_mask, jnp.float32)
            return _run_round(params_c_stack, params_s, oc_stack, os_,
                              batches, mask)
        return global_round_masked

    def global_round(params_c_stack, params_s, oc_stack, os_, batches):
        return _run_round(params_c_stack, params_s, oc_stack, os_, batches,
                          None)

    return global_round
