"""Sharded SPMD fleet rounds: the stacked client axis over the `data` mesh axis.

PR 1's round builders carry every per-client quantity (params, Adam moments,
minibatches) on a leading client axis but walk that axis with ``lax.scan`` —
sequential by construction. Here the client axis becomes a *batch* axis, in
one of two layouts (``client_axis=``):

  * ``'vmap'`` — ``jax.vmap`` over clients plus ``with_sharding_constraint``
    hints: XLA's GSPMD partitioner infers the collective schedule (FedAvg
    and the server's client-mean gradient lower to all-reduces over
    ``data``). One-host friendly; layout is advisory.
  * ``'shard_map'`` — the per-client step runs INSIDE ``jax.shard_map`` over
    the ``data`` mesh axis: every device owns ``clients/data`` rows of the
    stack, FedAvg is the explicit ``core.fedavg.fedavg_pmean`` family
    (masked variants included, so dropout semantics survive the
    collective), and the parallel-SL server gradient is an in-map
    ``lax.pmean``. The collective schedule is pinned in the program — the
    prerequisite for multi-host meshes, where GSPMD inference can differ
    per host. The non-``data`` mesh axes (``fsdp``, ``tp``) stay
    GSPMD-``auto``.

The 2D (clients x server-model) layout: ``launch.mesh.make_fleet_mesh``
builds the ``('data','fsdp','tp')`` mesh, ``launch.steps
.fleet_server_pspecs`` derives the server suffix's tier specs (the same
DESIGN.md §3 rule ``build_step`` applies), and ``server_pspecs=`` wires
them into the SL round — server params/optimizer state shard fsdp x tp
(place live state with ``shard_server_state``) while the client stack
shards over ``data``. The combination with ``shard_map`` is gated to
fsdp = tp = 1 on this repo's XLA:CPU toolchain (partitioner abort, see
``make_fleet_sl_round``); the vmap engine runs the full 2D layout today.

Round semantics per engine:

  * FL — ``make_fleet_fl_round``: clients are fully independent until
    FedAvg, i.e. ``make_fl_round(..., client_axis='vmap')`` per shard.
  * SL — ``make_fleet_sl_round``: Efficient *Parallel* Split Learning (Lin
    et al., arXiv:2303.15991): every client's prefix fwd/bwd runs batched
    against the shared server suffix, and the server applies ONE update per
    local step on the client-mean gradient, instead of Algorithm 3's
    sequential per-client server updates. This is a deliberate semantic
    variant (the UAV relays all clients' smashed data per hover window); it
    is NOT numerically equivalent to ``make_multi_client_round`` — its
    reference is the parallel host loop in ``tests/test_fleet.py``.

Equivalence tolerance
---------------------
``FLEET_EQUIV_ATOL`` is the documented loosened bound for fleet-vs-scan
comparisons. The scanned engine matches the per-client host loop to 1e-4;
vmapping the client axis batches the convolutions and reassociates their
fp32 reductions (and sharding/shard_map re-tiles them again), which drifts
losses by up to ~1e-3 after a few Adam steps on the tiny test models.
Independent clients make this pure arithmetic reassociation, not a semantic
change. The shard_map engines are gated against the vmap engines by the
same bound (``tests/test_fleet.py``, forced multi-device host mesh).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.fedavg import (fedavg_mean, fedavg_mean_masked, fedavg_pmean,
                           fedavg_pmean_masked, fedavg_pmean_stack,
                           fedavg_pmean_stack_masked, fedavg_stack,
                           fedavg_stack_masked)
from ..core.split import SplitStep, make_fl_round
from ..obs.metrics import tree_nonfinite, tree_norm
from ..optim.optimizers import apply_updates

# Documented loosened tolerance for vmapped/sharded vs sequential rounds
# (see module docstring; tests and benches assert against this bound).
FLEET_EQUIV_ATOL = 1e-3

# the mesh axis the stacked client dimension shards over — every other
# fleet-mesh axis belongs to the server suffix (fsdp x tp) and stays
# GSPMD-auto inside the shard_map engines
CLIENT_AXIS_NAME = "data"

CLIENT_AXES = ("vmap", "shard_map")


def fleet_sharding(mesh) -> NamedSharding:
    """Sharding of a client-stacked leaf: leading axis over ``data``."""
    return NamedSharding(mesh, P(CLIENT_AXIS_NAME))


def validate_fleet_mesh(mesh, num_clients: int) -> None:
    """The client axis must divide evenly over ``data`` — no silent padding."""
    if mesh is None:
        return
    data = dict(zip(mesh.axis_names,
                    mesh.devices.shape)).get(CLIENT_AXIS_NAME, 1)
    if num_clients % data:
        raise ValueError(
            f"{num_clients} clients do not divide over data={data}; pick a "
            f"fleet size divisible by the mesh's data axis (launch.mesh."
            f"make_fleet_mesh chooses one automatically)")


def shard_client_stack(tree, mesh):
    """Host-side placement of a client-stacked pytree onto the fleet mesh."""
    if mesh is None:
        return tree
    s = fleet_sharding(mesh)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, s), tree)


def _constrain(tree, mesh):
    if mesh is None:
        return tree
    s = fleet_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(x, s), tree)


def _resolve_shard_map_mesh(mesh):
    """A shard_map engine always needs a concrete mesh: default to the
    degenerate single-device fleet mesh (collectives become no-ops) so the
    explicit-collective path compiles anywhere."""
    if mesh is None:
        from ..launch.mesh import single_device_fleet_mesh
        return single_device_fleet_mesh()
    if CLIENT_AXIS_NAME not in mesh.axis_names:
        raise ValueError(f"fleet shard_map mesh needs a '{CLIENT_AXIS_NAME}' "
                         f"axis, got {mesh.axis_names}")
    return mesh


def _client_shard_map(body, mesh, in_specs, out_specs):
    """shard_map manual over ``data`` only; every other mesh axis (fsdp/tp)
    is left to GSPMD (``auto``) so in-map sharding constraints can lay out
    the server suffix."""
    auto = frozenset(mesh.axis_names) - {CLIENT_AXIS_NAME}
    return shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False, auto=auto)


def server_mesh_sizes(mesh) -> tuple[int, int]:
    """(fsdp, tp) sizes of the fleet mesh's server sub-mesh (1, 1 when the
    axes are absent — e.g. the legacy ('data','model') mesh)."""
    if mesh is None:
        return 1, 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("fsdp", 1), sizes.get("tp", 1)


def shard_server_state(tree, mesh, server_pspecs):
    """Host-side placement of the server suffix (params, or a matching
    state tree such as ``OptState(step=P(), mu=specs, nu=specs)``) onto the
    fleet mesh's ``fsdp`` x ``tp`` server sub-mesh — the counterpart of
    ``shard_client_stack`` for the 2D (clients x server-model) layout."""
    if mesh is None or server_pspecs is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, server_pspecs)


def _server_constrainer(mesh, server_pspecs) -> Optional[Callable]:
    """tree -> tree applying the fsdp x tp tier specs to the server suffix
    at round/map-body entry; GSPMD propagates the layout through the
    round's scan carry. Trivial spec trees (every dim replicated — fsdp =
    tp = 1) collapse to None so the shard_map body stays constraint-free
    on 1D meshes. (Inside a manual-over-``data`` body the constraint must
    also stay OUTSIDE the scan: this toolchain's SPMD partitioner aborts
    on auto-axis resharding inside a while-loop of a manual computation —
    see ``api.plan`` for the backend gate.)"""
    if mesh is None or server_pspecs is None:
        return None
    if all(all(ax is None for ax in s)
           for s in jax.tree_util.tree_leaves(
               server_pspecs, is_leaf=lambda s: isinstance(s, P))):
        return None
    def constrain(tree):
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)),
            tree, server_pspecs)
    return constrain


def _check_client_axis(client_axis: str) -> None:
    if client_axis not in CLIENT_AXES:
        raise ValueError(f"fleet client_axis must be one of {CLIENT_AXES}, "
                         f"got {client_axis!r} (the sequential engine is "
                         f"core.split's client_axis='scan')")


# ---------------------------------------------------------------------------
# FL rounds
# ---------------------------------------------------------------------------

def make_fleet_fl_round(grad_fn: Callable, opt, *, mesh=None,
                        client_dropout: bool = False,
                        client_axis: str = "vmap", taps: tuple = ()):
    """FL baseline round with the client axis batched and (optionally)
    sharded over ``data``. Same signature/returns as ``make_fl_round``:
    ``f(global_params, batches) -> (new_global_params, losses[C, S])``;
    with ``taps`` the round additionally returns the (clients, steps)
    metrics-bus tap stacks (see ``make_fl_round``), sharded like the
    losses.

    ``client_axis='vmap'`` leaves layout to GSPMD via sharding constraints
    (``mesh`` optional); ``client_axis='shard_map'`` runs the per-client
    local scan inside ``jax.shard_map`` over ``data`` and aggregates with
    the explicit ``fedavg_pmean`` collective (``mesh`` defaults to the
    single-device fleet mesh).

    With ``client_dropout`` the round takes a trailing ``client_mask``
    (clients,) 0/1 argument: masked clients still execute (the program is
    shape-static) but are excluded from FedAvg — stragglers that missed
    the round contribute nothing to the new global model (the shard_map
    path psums the masked sums and active count: ``fedavg_pmean_masked``).
    All-masked rounds leave the global params unchanged.
    """
    _check_client_axis(client_axis)
    vmapped = make_fl_round(grad_fn, opt, client_axis="vmap",
                            aggregate=False, taps=taps)

    if client_axis == "shard_map":
        mesh = _resolve_shard_map_mesh(mesh)
        spec_c = P(CLIENT_AXIS_NAME)
        # every FL tap leaf is (clients, steps): sharded like the losses
        tap_specs = ({name: spec_c for name in taps},) if taps else ()

        if not client_dropout:
            def body(global_params, batches):
                out = vmapped(global_params, batches)
                agg = fedavg_pmean(out[0], CLIENT_AXIS_NAME)
                return (agg,) + out[1:]

            return _client_shard_map(body, mesh, in_specs=(P(), spec_c),
                                     out_specs=(P(), spec_c) + tap_specs)

        def body_masked(global_params, batches, client_mask):
            out = vmapped(global_params, batches)
            new_params = fedavg_pmean_masked(out[0], client_mask,
                                             global_params, CLIENT_AXIS_NAME)
            return (new_params,) + out[1:]

        return _client_shard_map(body_masked, mesh,
                                 in_specs=(P(), spec_c, spec_c),
                                 out_specs=(P(), spec_c) + tap_specs)

    if not client_dropout:
        def global_round(global_params, batches):
            batches = _constrain(batches, mesh)
            out = vmapped(global_params, batches)
            # FedAvg reduces the client axis (an all-reduce over `data`
            # when sharded); losses/taps keep the client-sharded layout.
            return (fedavg_mean(out[0]),) + tuple(
                _constrain(o, mesh) for o in out[1:])

        return global_round

    def global_round_masked(global_params, batches, client_mask):
        batches = _constrain(batches, mesh)
        out = vmapped(global_params, batches)
        new_params = fedavg_mean_masked(out[0], client_mask,
                                        global_params)
        return (new_params,) + tuple(_constrain(o, mesh) for o in out[1:])

    return global_round_masked


# ---------------------------------------------------------------------------
# parallel-SL rounds
# ---------------------------------------------------------------------------

def make_fleet_sl_round(step: SplitStep, opt_c, opt_s, *, local_rounds: int,
                        mesh=None, server_reduce: str = "mean",
                        client_dropout: bool = False,
                        client_axis: str = "vmap", server_pspecs=None,
                        client_tier: str = "stacked", taps: tuple = ()):
    """One global round of *parallel* split learning over a sharded fleet.

    Per local step: every client's prefix runs fwd/bwd batched (vmap over
    the stacked client params/opt-states/batches) against the shared server
    suffix; client updates are per-client, the server takes one update on
    the ``server_reduce`` ('mean' | 'sum') of the per-client server
    gradients. After ``local_rounds`` steps the client prefixes are
    FedAvg'd, all inside the one compiled program.

    ``client_axis='shard_map'`` runs the whole round body inside
    ``jax.shard_map`` over ``data``: the server gradient is reduced with an
    in-map ``lax.pmean`` (``lax.psum`` of masked sums under dropout), the
    closing FedAvg is ``fedavg_pmean_stack(_masked)``, and the server
    update — fed the identical all-reduced gradient on every shard — stays
    replicated over ``data``.

    ``server_pspecs`` (a PartitionSpec tree from
    ``launch.steps.fleet_server_pspecs``) constrains the server suffix over
    the mesh's ``fsdp`` x ``tp`` axes at round entry, giving the 2D
    (clients x server-model) layout; ``shard_server_state`` places the live
    state to match. Fully supported under ``client_axis='vmap'`` (pure
    GSPMD). Under ``shard_map`` those axes are GSPMD-``auto`` and the
    combination is the intended multi-host layout, but this repo's pinned
    XLA:CPU toolchain aborts on fsdp/tp-sharded operands entering the
    manual body's scan — ``api.plan`` gates the CPU backend to fsdp = tp =
    1 for shard_map (see ROADMAP, re-test when the toolchain moves past
    jax 0.5).

    Signature matches ``make_multi_client_round``:
    ``f(params_c_stack, params_s, oc_stack, os_, batches)`` with ``batches``
    leading (clients, local_rounds) axes; losses return as
    ``(local_rounds, clients)``.

    With ``client_dropout`` the round takes a trailing ``client_mask``
    (clients,) 0/1 argument (traced — one compile serves every mask):
    P3SL-style stragglers. Masked clients keep their params/opt state
    frozen for the round, contribute nothing to the server's reduced
    gradient, and are excluded from the closing FedAvg (they rejoin later
    from their stale prefix). A fully-masked round is a no-op on all state.

    ``client_tier`` picks the client-state representation:

      "stacked" — today's resident fleet: per-client params + Adam moments
                  on the leading client axis, closing FedAvg. State is
                  O(clients).
      "shared"  — EPSL cohort mode (Lin et al.): ONE set of client params +
                  opt state serves every cohort slot. Per local step the
                  prefix fwd/bwd is vmapped over cohort batches with the
                  shared params broadcast (``in_axes=(0, None, None)``) and
                  the client takes one update on the masked cohort-MEAN
                  gradient — mirroring the server's update, so there is no
                  closing FedAvg and no per-slot state to leak between the
                  different population clients occupying a slot across
                  rounds. Signature/state shape changes: ``params_c`` /
                  ``oc`` are UNSTACKED; losses stay (local_rounds, clients).
                  Under ``shard_map`` the client state is replicated and
                  its gradient all-reduced (psum of masked sums / active
                  count) exactly like the server's, so every shard applies
                  the identical update. State is O(1) in both the cohort
                  and the population.

    ``taps`` enables the metrics bus (``repro.obs.metrics``): the round
    additionally returns a dict of float32 tap stacks riding the same
    local-step scan as the losses. Per-slot channels (grad norms,
    nonfinite, the SplitStep's smashed/quant taps) come back
    (local_rounds, clients) in the loss layout; one-update-per-step
    channels are (local_rounds,) — ``update_norm_server`` always, and
    ``update_norm_client`` too under the shared tier (EPSL takes one
    client update per step). Taps report the RAW per-slot computation:
    masked stragglers still execute, their rows are excluded from state
    but visible on the bus (``mask`` tallies let consumers filter). Empty
    taps lowers the exact tap-free program.
    """
    if server_reduce not in ("mean", "sum"):
        raise ValueError(server_reduce)
    if client_tier not in ("stacked", "shared"):
        raise ValueError(f"client_tier must be 'stacked' or 'shared', "
                         f"got {client_tier!r}")
    _check_client_axis(client_axis)
    if client_axis == "shard_map":
        mesh = _resolve_shard_map_mesh(mesh)
        axis = CLIENT_AXIS_NAME
        # the body is manual over `data`: no host-level constraints inside
        constrain_mesh = None
    else:
        axis = None
        constrain_mesh = mesh
    constrain_server = _server_constrainer(mesh, server_pspecs)

    def allreduce_sum(x):
        return jax.lax.psum(x, axis) if axis is not None else x

    def _run_round(params_c_stack, params_s, oc_stack, os_, batches, mask):
        params_c_stack = _constrain(params_c_stack, constrain_mesh)
        oc_stack = _constrain(oc_stack, constrain_mesh)
        batches = _constrain(batches, constrain_mesh)
        if constrain_server is not None:
            params_s = constrain_server(params_s)
        # (clients, local_rounds, ...) -> (local_rounds, clients, ...)
        batches_rm = jax.tree_util.tree_map(
            lambda x: jnp.swapaxes(x, 0, 1), batches)
        # round constants hoisted above the local-step scan: under shard_map
        # each is ONE psum per round, not one per step
        n_active = (None if mask is None
                    else jnp.maximum(allreduce_sum(mask.sum()), 1.0))
        any_active = None if mask is None else allreduce_sum(mask.sum()) > 0

        def per_client_grads(pc, batch, ps):
            loss, aux, g_c, g_s = step.grads(pc, ps, batch)
            if taps:
                return loss, aux.get("taps", {}), g_c, g_s
            return loss, g_c, g_s

        def masked_rows(new, old):
            """Keep masked clients' leading-axis rows at their old value."""
            def sel(n, o):
                w = mask.reshape((n.shape[0],) + (1,) * (n.ndim - 1))
                return jnp.where(w > 0, n, o)
            return jax.tree_util.tree_map(sel, new, old)

        def round_body(carry, batch_r):
            params_c_stack, oc_stack, params_s, os_ = carry
            grads_out = jax.vmap(
                per_client_grads, in_axes=(0, 0, None))(
                    params_c_stack, batch_r, params_s)
            if taps:
                losses, aux_t, g_c_stack, g_s_stack = grads_out
            else:
                losses, g_c_stack, g_s_stack = grads_out
                aux_t = {}
            up_c, oc_new = jax.vmap(opt_c.update)(
                g_c_stack, oc_stack, params_c_stack)
            pc_new = apply_updates(params_c_stack, up_c)
            if mask is not None:
                pc_new = masked_rows(pc_new, params_c_stack)
                oc_new = masked_rows(oc_new, oc_stack)
            params_c_stack, oc_stack = pc_new, oc_new
            # server: ONE update on the fleet-reduced gradient — under
            # shard_map an explicit in-map lax.pmean/psum over `data`, under
            # vmap an all-reduce GSPMD infers when the client axis is sharded
            def reduce_g(g):
                g32 = g.astype(jnp.float32)
                if mask is None:
                    if server_reduce == "mean":
                        m = jnp.mean(g32, axis=0)
                        if axis is not None:
                            m = jax.lax.pmean(m, axis)
                        return m.astype(g.dtype)
                    return allreduce_sum(jnp.sum(g32, axis=0)).astype(g.dtype)
                w = mask.reshape((g.shape[0],) + (1,) * (g.ndim - 1))
                s = allreduce_sum((g32 * w).sum(axis=0))
                if server_reduce == "mean":
                    s = s / n_active
                return s.astype(g.dtype)
            g_s = jax.tree_util.tree_map(reduce_g, g_s_stack)
            up_s, os_new = opt_s.update(g_s, os_, params_s)
            ps_new = apply_updates(params_s, up_s)
            if mask is not None:
                # zero active clients -> the server also sits the round out
                ps_new = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(any_active, n, o), ps_new, params_s)
                os_new = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(any_active, n, o), os_new, os_)
            if taps:
                t = dict(aux_t)
                if "grad_norm_client" in taps:
                    t["grad_norm_client"] = jax.vmap(tree_norm)(g_c_stack)
                if "grad_norm_server" in taps:
                    t["grad_norm_server"] = jax.vmap(tree_norm)(g_s_stack)
                if "update_norm_client" in taps:
                    t["update_norm_client"] = jax.vmap(tree_norm)(up_c)
                if "update_norm_server" in taps:
                    t["update_norm_server"] = tree_norm(up_s)
                if "nonfinite" in taps:
                    # tapped norms double as the guard (NaN/inf propagate
                    # through the L2 reduction); untapped tiers pay the
                    # elementwise pass
                    bad = (~jnp.isfinite(losses)).astype(jnp.float32)
                    for k, stk in (("grad_norm_client", g_c_stack),
                                   ("grad_norm_server", g_s_stack)):
                        bad = jnp.maximum(
                            bad,
                            (~jnp.isfinite(t[k])).astype(jnp.float32)
                            if k in t else jax.vmap(tree_nonfinite)(stk))
                    t["nonfinite"] = bad
                out = (losses, t)
            else:
                out = losses
            return (params_c_stack, oc_stack, ps_new, os_new), out

        carry = (params_c_stack, oc_stack, params_s, os_)
        carry, out = jax.lax.scan(round_body, carry, batches_rm)
        params_c_stack, oc_stack, params_s, os_ = carry
        if axis is not None:
            agg = (fedavg_pmean_stack(params_c_stack, axis) if mask is None
                   else fedavg_pmean_stack_masked(params_c_stack, mask, axis))
        else:
            agg = (fedavg_stack(params_c_stack) if mask is None
                   else fedavg_stack_masked(params_c_stack, mask))
        params_c_stack = _constrain(agg, constrain_mesh)
        if taps:
            losses, tap_stack = out
            return (params_c_stack, params_s, oc_stack, os_, losses,
                    tap_stack)
        return params_c_stack, params_s, oc_stack, os_, out

    def _run_round_shared(params_c, params_s, oc, os_, batches, mask):
        batches = _constrain(batches, constrain_mesh)
        if constrain_server is not None:
            params_s = constrain_server(params_s)
        batches_rm = jax.tree_util.tree_map(
            lambda x: jnp.swapaxes(x, 0, 1), batches)
        n_active = (None if mask is None
                    else jnp.maximum(allreduce_sum(mask.sum()), 1.0))
        any_active = None if mask is None else allreduce_sum(mask.sum()) > 0

        def per_client_grads(batch, pc, ps):
            loss, aux, g_c, g_s = step.grads(pc, ps, batch)
            if taps:
                return loss, aux.get("taps", {}), g_c, g_s
            return loss, g_c, g_s

        def reduce_g(g, reduce):
            """Cohort reduction of a per-slot gradient stack: masked mean
            (or sum), all-reduced over `data` under shard_map."""
            g32 = g.astype(jnp.float32)
            if mask is None:
                if reduce == "mean":
                    m = jnp.mean(g32, axis=0)
                    if axis is not None:
                        m = jax.lax.pmean(m, axis)
                    return m.astype(g.dtype)
                return allreduce_sum(jnp.sum(g32, axis=0)).astype(g.dtype)
            w = mask.reshape((g.shape[0],) + (1,) * (g.ndim - 1))
            s = allreduce_sum((g32 * w).sum(axis=0))
            if reduce == "mean":
                s = s / n_active
            return s.astype(g.dtype)

        def guard(new, old):
            # zero active clients -> the whole round is a no-op on state
            return jax.tree_util.tree_map(
                lambda nw, o: jnp.where(any_active, nw, o), new, old)

        def round_body(carry, batch_r):
            params_c, oc, params_s, os_ = carry
            grads_out = jax.vmap(
                per_client_grads, in_axes=(0, None, None))(
                    batch_r, params_c, params_s)
            if taps:
                losses, aux_t, g_c_stack, g_s_stack = grads_out
            else:
                losses, g_c_stack, g_s_stack = grads_out
                aux_t = {}
            # the shared client tier updates like the server: one step on
            # the masked cohort-MEAN prefix gradient (EPSL)
            g_c = jax.tree_util.tree_map(lambda g: reduce_g(g, "mean"),
                                         g_c_stack)
            up_c, oc_new = opt_c.update(g_c, oc, params_c)
            pc_new = apply_updates(params_c, up_c)
            g_s = jax.tree_util.tree_map(lambda g: reduce_g(g, server_reduce),
                                         g_s_stack)
            up_s, os_new = opt_s.update(g_s, os_, params_s)
            ps_new = apply_updates(params_s, up_s)
            if mask is not None:
                pc_new, oc_new = guard(pc_new, params_c), guard(oc_new, oc)
                ps_new, os_new = guard(ps_new, params_s), guard(os_new, os_)
            if taps:
                t = dict(aux_t)
                if "grad_norm_client" in taps:
                    t["grad_norm_client"] = jax.vmap(tree_norm)(g_c_stack)
                if "grad_norm_server" in taps:
                    t["grad_norm_server"] = jax.vmap(tree_norm)(g_s_stack)
                # EPSL: ONE shared client update per step -> scalar channel
                if "update_norm_client" in taps:
                    t["update_norm_client"] = tree_norm(up_c)
                if "update_norm_server" in taps:
                    t["update_norm_server"] = tree_norm(up_s)
                if "nonfinite" in taps:
                    # tapped norms double as the guard, as above
                    bad = (~jnp.isfinite(losses)).astype(jnp.float32)
                    for k, stk in (("grad_norm_client", g_c_stack),
                                   ("grad_norm_server", g_s_stack)):
                        bad = jnp.maximum(
                            bad,
                            (~jnp.isfinite(t[k])).astype(jnp.float32)
                            if k in t else jax.vmap(tree_nonfinite)(stk))
                    t["nonfinite"] = bad
                out = (losses, t)
            else:
                out = losses
            return (pc_new, oc_new, ps_new, os_new), out

        carry = (params_c, oc, params_s, os_)
        carry, out = jax.lax.scan(round_body, carry, batches_rm)
        params_c, oc, params_s, os_ = carry
        if taps:
            losses, tap_stack = out
            return params_c, params_s, oc, os_, losses, tap_stack
        return params_c, params_s, oc, os_, out

    run_body = _run_round_shared if client_tier == "shared" else _run_round

    if client_axis == "shard_map":
        spec_c = P(CLIENT_AXIS_NAME)
        # shared client state is replicated (its update is all-reduced);
        # stacked client state shards over `data`
        state_c = P() if client_tier == "shared" else spec_c
        # losses carry the client axis SECOND: (local_rounds, clients)
        out_specs = (state_c, P(), state_c, P(), P(None, CLIENT_AXIS_NAME))
        if taps:
            # per-slot tap channels share the loss layout; one-update-per-
            # step channels are replicated (the update is all-reduced
            # identically on every shard)
            scalar = {"update_norm_server"}
            if client_tier == "shared":
                scalar.add("update_norm_client")
            out_specs = out_specs + ({
                name: (P(None) if name in scalar
                       else P(None, CLIENT_AXIS_NAME))
                for name in taps},)

        if client_dropout:
            def body_masked(params_c_stack, params_s, oc_stack, os_, batches,
                            client_mask):
                mask = jnp.asarray(client_mask, jnp.float32)
                return run_body(params_c_stack, params_s, oc_stack, os_,
                                batches, mask)
            return _client_shard_map(
                body_masked, mesh,
                in_specs=(state_c, P(), state_c, P(), spec_c, spec_c),
                out_specs=out_specs)

        def body(params_c_stack, params_s, oc_stack, os_, batches):
            return run_body(params_c_stack, params_s, oc_stack, os_,
                            batches, None)
        return _client_shard_map(
            body, mesh, in_specs=(state_c, P(), state_c, P(), spec_c),
            out_specs=out_specs)

    if client_dropout:
        def global_round_masked(params_c_stack, params_s, oc_stack, os_,
                                batches, client_mask):
            mask = jnp.asarray(client_mask, jnp.float32)
            return run_body(params_c_stack, params_s, oc_stack, os_,
                            batches, mask)
        return global_round_masked

    def global_round(params_c_stack, params_s, oc_stack, os_, batches):
        return run_body(params_c_stack, params_s, oc_stack, os_, batches,
                        None)

    return global_round
