"""Process-level XLA runtime knobs (set BEFORE jax initializes a backend).

jax 0.4.3x defaults XLA:CPU to the new thunk runtime, whose fused
gradient kernels (depthwise convs in particular) run single-threaded
inside ``while``/``scan`` bodies — a 10-50x slowdown for the scanned
multi-client engine on CPU containers. The legacy runtime parallelizes
those bodies; on accelerators these flags are no-ops.

Entry points that train on CPU (tests via conftest, benchmarks, examples)
call ``enable_fast_cpu_runtime()`` first thing. Existing user-provided
``XLA_FLAGS`` are preserved; the flag is only appended when absent so it
stays overridable.
"""
from __future__ import annotations

import os


def enable_fast_cpu_runtime() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" in flags:
        return  # user already chose; don't override
    try:
        import jax  # importing is safe pre-backend-init
        major, minor = (int(v) for v in jax.__version__.split(".")[:2])
    except Exception:
        return
    if (major, minor) >= (0, 5):
        return  # legacy runtime (and its flag) removed upstream
    os.environ["XLA_FLAGS"] = \
        (flags + " --xla_cpu_use_thunk_runtime=false").strip()
