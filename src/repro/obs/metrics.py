"""``repro.obs.metrics`` — the in-graph metrics bus every engine can carry.

PR 7's telemetry fences the *host* side of a round (phase spans, RSS,
recompiles); everything that decides accuracy-per-joule — gradient
magnitudes per tier, smashed-activation statistics at the link, int8
quantization error, per-client loss spread under dropout — happens inside
the ``lax.scan`` over steps x clients and was invisible. This module adds
an **off-by-default, fixed-shape** tap channel to the round builders:

* taps are selected at COMPILE time (``compile_experiment(spec,
  obs=ObsConfig(metrics=MetricsConfig(taps=...)))``); a plan compiled
  without a ``MetricsConfig`` lowers to the bit-identical metrics-free
  program (pinned by ``tests/test_metrics.py`` + the jaxpr audit);
* enabled taps ride the round's existing scan outputs next to the loss
  stack — ONE extra pytree in the same per-round device->host pull, zero
  extra host syncs;
* tap arrays are fixed-shape per round (leading step/client axes match the
  loss layout: SL ``(local_rounds, clients)``, FL ``(clients, steps)``),
  so they vmap through ``run_monte_carlo`` unchanged.

The host side (``summarize_round_metrics``) reduces the raw tap arrays to
the flat JSON-able scalar dict surfaced as ``RoundRecord.metrics`` and
streamed as the sink's ``metrics`` event; the same reduction runs on a
Monte-Carlo sweep's per-seed stacks, so seed 0 of a sweep reproduces the
plan's own metric stream.

Tap selection (``MetricsConfig.taps``) and what each lowers to:

=============  =============================================================
user tap       in-graph channel(s)
=============  =============================================================
grad_norms     ``grad_norm_client`` (+ ``grad_norm_server`` for SL): L2
               norm of each tier's gradient, per (step, client slot)
update_norms   ``update_norm_client`` / ``update_norm_server``: L2 norm of
               the applied optimizer update (server / EPSL-shared client
               updates are one-per-step scalars)
smashed        ``smashed_mean`` / ``smashed_std`` / ``smashed_absmax``: the
               raw smashed activation entering the link boundary (SL only)
quant_error    ``quant_error``: RMS of (dequantized - raw) at the boundary
               — only lowered when the plan has an int8 link
loss_spread    host-side only: std of per-client losses per step, averaged
               over the round's steps (from the loss stack already pulled)
mask           host-side only: active-slot tally + fraction of the round's
               client mask
=============  =============================================================

plus the training-health monitor (``nan_guard=True``): a per-(step, client)
``nonfinite`` flag — loss or either tier's gradient went NaN/inf — that the
host localizes to the FIRST bad (round, step, client slot).
``on_nonfinite="record"`` books it into ``RoundRecord.metrics`` under
``health/*``; ``"raise"`` raises :class:`NonfiniteError` carrying the
coordinate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MetricsConfig", "NonfiniteError", "TAPS", "engine_tap_names",
           "split_step_tap_names", "step_taps", "tree_norm", "tree_nonfinite",
           "smashed_tap_values", "summarize_round_metrics",
           "first_nonfinite_coord"]

# the user-facing tap vocabulary (MetricsConfig.taps)
TAPS = ("grad_norms", "update_norms", "smashed", "quant_error",
        "loss_spread", "mask")


@dataclasses.dataclass(frozen=True)
class MetricsConfig:
    """Compile-time tap selection for the in-graph metrics bus.

    ``taps`` picks from :data:`TAPS`; inapplicable taps are skipped per
    engine (FL has no link boundary; ``quant_error`` needs an int8 link),
    never errors. ``nan_guard`` lowers the per-(step, client) nonfinite
    flag; ``on_nonfinite`` picks the host policy when it fires.
    """
    taps: Tuple[str, ...] = TAPS
    nan_guard: bool = True
    on_nonfinite: str = "record"     # "record" | "raise"

    def __post_init__(self):
        unknown = [t for t in self.taps if t not in TAPS]
        if unknown:
            raise ValueError(f"unknown metrics taps {unknown}; pick from "
                             f"{TAPS}")
        if self.on_nonfinite not in ("record", "raise"):
            raise ValueError(f"on_nonfinite must be 'record' or 'raise', "
                             f"got {self.on_nonfinite!r}")


class NonfiniteError(RuntimeError):
    """The health monitor found a NaN/inf and the plan was compiled with
    ``on_nonfinite="raise"``. Carries the first bad coordinate."""

    def __init__(self, *, round_index: int, step: int, client: int,
                 count: int):
        self.round_index = round_index
        self.step = step
        self.client = client
        self.count = count
        super().__init__(
            f"nonfinite loss/gradient first at round={round_index} "
            f"step={step} client_slot={client} ({count} flagged slot-steps "
            f"this round)")


def engine_tap_names(cfg: Optional[MetricsConfig], *, kind: str,
                     has_link: bool) -> Tuple[str, ...]:
    """The in-graph tap channels ``cfg`` lowers to for one engine.

    ``kind`` is the engine family ('fl' | 'sl'); ``has_link`` whether the
    plan's link boundary transforms the smashed tensor (int8). Empty tuple
    (metrics off, or nothing applicable) means the round builders emit the
    bit-identical tap-free program.
    """
    if cfg is None:
        return ()
    names = []
    if "grad_norms" in cfg.taps:
        names.append("grad_norm_client")
        if kind == "sl":
            names.append("grad_norm_server")
    if "update_norms" in cfg.taps:
        names.append("update_norm_client")
        if kind == "sl":
            names.append("update_norm_server")
    if kind == "sl" and "smashed" in cfg.taps:
        names += ["smashed_mean", "smashed_std", "smashed_absmax"]
    if kind == "sl" and has_link and "quant_error" in cfg.taps:
        names.append("quant_error")
    if cfg.nan_guard:
        names.append("nonfinite")
    return tuple(names)


def split_step_tap_names(names: Tuple[str, ...]) -> Tuple[str, ...]:
    """The subset of engine tap channels computed INSIDE ``SplitStep.
    loss_fn`` (they need the smashed tensor, which only exists there) —
    carried out through the step's aux dict."""
    return tuple(n for n in names
                 if n.startswith("smashed_") or n == "quant_error")


# ---------------------------------------------------------------------------
# in-graph tap helpers (pure jax; every value is a float32 scalar per call)
# ---------------------------------------------------------------------------

def tree_norm(tree) -> jax.Array:
    """Global L2 norm of a pytree, accumulated in float32."""
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_nonfinite(tree) -> jax.Array:
    """1.0 when any leaf element of ``tree`` is NaN/inf, else 0.0."""
    leaves = jax.tree_util.tree_leaves(tree)
    bad = sum(jnp.sum(~jnp.isfinite(x.astype(jnp.float32)))
              for x in leaves)
    return (bad > 0).astype(jnp.float32)


def smashed_tap_values(names, smashed, boundary_out) -> dict:
    """The ``SplitStep.loss_fn`` taps: statistics of the raw smashed
    activation entering the link, and the RMS quantization error the
    boundary introduced (``boundary_out`` is the post-boundary tensor —
    identical object when the link is transparent)."""
    out = {}
    flat = jnp.concatenate(
        [x.astype(jnp.float32).ravel()
         for x in jax.tree_util.tree_leaves(smashed)])
    if "smashed_mean" in names:
        out["smashed_mean"] = jnp.mean(flat)
    if "smashed_std" in names:
        out["smashed_std"] = jnp.std(flat)
    if "smashed_absmax" in names:
        out["smashed_absmax"] = jnp.max(jnp.abs(flat))
    if "quant_error" in names:
        err = jax.tree_util.tree_map(
            lambda q, s: q.astype(jnp.float32) - s.astype(jnp.float32),
            boundary_out, smashed)
        flat_err = jnp.concatenate(
            [x.ravel() for x in jax.tree_util.tree_leaves(err)])
        out["quant_error"] = jnp.sqrt(jnp.mean(jnp.square(flat_err)))
    return out


def step_taps(names, *, loss=None, aux_taps=None, g_c=None, g_s=None,
              up_c=None, up_s=None) -> dict:
    """One (step, client)'s tap dict from whatever the round body has in
    hand. Channels not in ``names`` cost nothing; channels whose source
    argument is None are skipped (e.g. no server tier in FL)."""
    out = {}
    if "grad_norm_client" in names and g_c is not None:
        out["grad_norm_client"] = tree_norm(g_c)
    if "grad_norm_server" in names and g_s is not None:
        out["grad_norm_server"] = tree_norm(g_s)
    if "update_norm_client" in names and up_c is not None:
        out["update_norm_client"] = tree_norm(up_c)
    if "update_norm_server" in names and up_s is not None:
        out["update_norm_server"] = tree_norm(up_s)
    if "nonfinite" in names:
        # an L2 norm is NaN/inf exactly when its source tree holds a
        # NaN/inf element (or its square-sum overflowed float32 — itself
        # a training-health event), so already-tapped norms double as the
        # guard; only trees WITHOUT a tapped norm pay the elementwise pass
        bad = jnp.zeros((), jnp.float32)
        if loss is not None:
            bad = (~jnp.isfinite(loss)).astype(jnp.float32)
        for k, tree in (("grad_norm_client", g_c),
                        ("grad_norm_server", g_s)):
            if k in out:
                bad = jnp.maximum(
                    bad, (~jnp.isfinite(out[k])).astype(jnp.float32))
            elif tree is not None:
                bad = jnp.maximum(bad, tree_nonfinite(tree))
        out["nonfinite"] = bad
    if aux_taps:
        for k in ("smashed_mean", "smashed_std", "smashed_absmax",
                  "quant_error"):
            if k in names and k in aux_taps:
                out[k] = aux_taps[k]
    return out


# ---------------------------------------------------------------------------
# host-side summarization (numpy only: runs on pulled arrays, also inside
# MonteCarloResult.records_for_seed on the per-seed stacks)
# ---------------------------------------------------------------------------

def _time_major(arr, kind: str):
    """Tap/loss arrays in (step, client) order: SL rounds already emit
    (local_rounds, clients); FL rounds emit (clients, steps)."""
    import numpy as np
    a = np.asarray(arr)
    if kind == "fl" and a.ndim == 2:
        return a.T
    return a


def first_nonfinite_coord(flags, kind: str):
    """``(step, client, count)`` of the FIRST flagged (time-major) slot in
    one round's nonfinite tap, or ``None`` when the round is clean."""
    import numpy as np
    a = _time_major(flags, kind)
    bad = np.argwhere(np.asarray(a) > 0)
    if bad.size == 0:
        return None
    step = int(bad[0][0])
    client = int(bad[0][1]) if a.ndim == 2 else -1
    return step, client, int((np.asarray(a) > 0).sum())


def summarize_round_metrics(cfg: MetricsConfig, taps: Optional[dict], *,
                            losses, kind: str, n: int,
                            active: int) -> dict:
    """Reduce one round's raw tap arrays to the flat JSON-able scalar dict
    carried by ``RoundRecord.metrics``.

    ``taps`` is the engine's tap pytree for the round (possibly ``None``
    when nothing lowered in-graph); ``losses`` the round's raw loss stack
    in engine layout; ``active``/``n`` the surviving/total client slots.
    Purely numpy — byte-for-byte reproducible on a Monte-Carlo sweep's
    per-seed stacks (``MonteCarloResult.records_for_seed``).
    """
    import numpy as np
    out = {}
    for name in sorted(taps or ()):
        if name == "nonfinite":
            continue
        v = np.asarray(taps[name])
        out[f"{name}/mean"] = float(v.mean())
        out[f"{name}/max"] = float(v.max())
    if "loss_spread" in cfg.taps:
        lm = _time_major(losses, kind)
        if lm.ndim == 2 and lm.shape[1] > 0:
            out["loss/spread"] = float(np.std(lm, axis=1).mean())
    if "mask" in cfg.taps:
        out["mask/active"] = int(active)
        out["mask/fraction"] = float(active / n) if n else 0.0
    if taps and "nonfinite" in taps:
        coord = first_nonfinite_coord(taps["nonfinite"], kind)
        if coord is None:
            out["health/nonfinite"] = 0
            out["health/first_step"] = -1
            out["health/first_client"] = -1
        else:
            step, client, count = coord
            out["health/nonfinite"] = count
            out["health/first_step"] = step
            out["health/first_client"] = client
    return out
