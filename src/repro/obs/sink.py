"""Buffered JSONL event sink + run manifest.

One run = one directory under ``results/runs/<run_id>/`` holding

* ``manifest.json`` — run-level metadata (spec ``describe()``, jax/backend
  versions, mesh shape, git commit, argv), merged across writes so the
  compile seam and the entry point can both contribute;
* ``events.jsonl`` — one JSON object per line: spans, gauges, records,
  mission spans, notes (see ``tools/obs_report.py`` for the schema table).

``JsonlSink`` buffers events in memory and appends to disk every
``buffer`` events (and on flush/close), so the per-event hot-path cost is
one ``list.append``. ``NullSink`` is the disabled path: every method is a
no-op, nothing touches the filesystem.
"""
from __future__ import annotations

import json
import os
import time


def json_default(o):
    """Coerce numpy scalars/arrays (and anything with ``item()``/
    ``tolist()``) for ``json.dumps``."""
    if hasattr(o, "tolist"):
        return o.tolist()
    if hasattr(o, "item"):
        return o.item()
    return str(o)


def new_run_id() -> str:
    """Sortable, collision-resistant: UTC timestamp + pid."""
    return time.strftime("%Y%m%d-%H%M%S", time.gmtime()) + f"-{os.getpid()}"


class NullSink:
    """The disabled sink: emit/flush/close are no-ops, no run dir exists."""
    run_dir = None

    def emit(self, event: dict) -> None:
        pass

    def write_manifest(self, fields: dict) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """Buffered append-only event stream + merged manifest for one run."""

    def __init__(self, run_dir: str, buffer: int = 256):
        self.run_dir = run_dir
        self._events_path = os.path.join(run_dir, "events.jsonl")
        self._manifest_path = os.path.join(run_dir, "manifest.json")
        self._buffer = max(int(buffer), 1)
        self._pending: list[dict] = []
        self._manifest: dict = {}
        os.makedirs(run_dir, exist_ok=True)

    def emit(self, event: dict) -> None:
        self._pending.append(event)
        if len(self._pending) >= self._buffer:
            self.flush()

    def write_manifest(self, fields: dict) -> None:
        """Merge ``fields`` into the manifest and rewrite it. The special
        keys ``plan`` and ``sweep`` APPEND to ``plans`` / ``sweeps`` lists —
        one run may compile several plans (the perf bench does) and launch
        several Monte-Carlo sweeps."""
        for key in ("plan", "sweep"):
            item = fields.pop(key, None)
            if item is not None:
                self._manifest.setdefault(key + "s", []).append(item)
        self._manifest.update(fields)
        with open(self._manifest_path, "w") as f:
            json.dump(self._manifest, f, indent=1, default=json_default)

    def flush(self) -> None:
        if not self._pending:
            return
        with open(self._events_path, "a") as f:
            for ev in self._pending:
                f.write(json.dumps(ev, default=json_default) + "\n")
        self._pending = []

    def close(self) -> None:
        self.flush()
