"""``repro.obs`` — run-wide telemetry behind every compiled Plan.

Zero-dependency, **off by default**: a plan compiled without
``obs=ObsConfig(...)`` carries the shared disabled instance whose every
hot-path touch is a branch plus a no-op call. Enabled, one run writes

    results/runs/<run_id>/
      manifest.json     # spec describe(), jax/backend, mesh, git commit
      events.jsonl      # spans, gauges, records, mission spans, notes
      profile/          # optional jax.profiler trace (profile_rounds=)

through four pieces (each its own module):

* ``timeline``  — nestable phase timers with explicit device fencing
  (``span.fence`` separates device-sync wait from host cost);
* ``gauges``    — recompile counter (jax monitoring events), engine-state
  pytree bytes (the PR-6 O(cohort) pin), host RSS;
* ``sink``      — buffered JSONL event stream + merged run manifest;
* ``profiler``  — opt-in ``jax.profiler`` capture scoped to rounds N..M.

Render a run with ``tools/obs_report.py <run_dir>``; cross-link run dirs
with the perf trend log via ``benchmarks/report.py --runs``.

Usage::

    from repro.obs import ObsConfig
    plan = compile_experiment(spec, obs=ObsConfig())
    state, records = plan.run()          # spans/gauges/records stream out
    plan.obs.close()                     # flush the sink
    print(plan.obs.run_dir)
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Optional, Tuple

from .gauges import global_counter, host_rss_bytes, pytree_bytes
from .metrics import MetricsConfig, NonfiniteError  # noqa: F401 (re-export)
from .profiler import ProfilerCapture
from .sink import JsonlSink, NullSink, json_default, new_run_id
from .timeline import (NULL_SPAN, Timeline, fenced,  # noqa: F401 (re-export)
                       time_fenced)

__all__ = ["Obs", "ObsConfig", "NULL_OBS", "MetricsConfig", "NonfiniteError",
           "pytree_bytes", "host_rss_bytes", "fenced", "time_fenced",
           "json_default"]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs handed to ``compile_experiment(..., obs=)``."""
    enabled: bool = True
    run_root: str = "results/runs"   # run dirs are created under here
    run_id: Optional[str] = None     # default: UTC timestamp + pid
    gauge_every: int = 1             # rounds between gauge stamps (0 = off)
    # (start, stop) inclusive round window for jax.profiler capture; None
    # keeps the profiler off (it is never free)
    profile_rounds: Optional[Tuple[int, int]] = None
    buffer_events: int = 256         # sink flush granularity
    # in-graph metrics bus (see ``repro.obs.metrics``): None keeps every
    # round's lowering bit-identical to the metrics-free program.
    # Orthogonal to ``enabled`` — ObsConfig(enabled=False,
    # metrics=MetricsConfig()) computes RoundRecord.metrics with no sink.
    metrics: Optional[MetricsConfig] = None


def _git_commit() -> str:
    import subprocess
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


class Obs:
    """One run's telemetry facade: timeline + gauges + sink + profiler.

    Truthiness is the enabled flag — hot paths guard with ``if obs:``.
    Every method on a disabled instance is safe and does nothing.
    """

    def __init__(self, config: Optional[ObsConfig] = None):
        self.config = config = config if config is not None else ObsConfig()
        self.enabled = config.enabled
        if not self.enabled:
            self.sink = NullSink()
            self.timeline = Timeline(self.sink, enabled=False)
            self.profiler = ProfilerCapture(None, "")
            self._counter = None
            return
        import os
        run_id = config.run_id or new_run_id()
        run_dir = os.path.join(config.run_root, run_id)
        self.sink = JsonlSink(run_dir, buffer=config.buffer_events)
        self.timeline = Timeline(self.sink, enabled=True)
        self.profiler = ProfilerCapture(config.profile_rounds,
                                        os.path.join(run_dir, "profile"))
        self._counter = global_counter()
        self._compiles0, self._compile_s0 = self._counter.snapshot()
        self._gauge_mark = self._compiles0, self._compile_s0
        import jax
        self.manifest(
            run_id=run_id,
            created_utc=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            jax_version=jax.__version__,
            backend=jax.default_backend(),
            device_count=jax.device_count(),
            git_commit=_git_commit(),
            argv=list(sys.argv),
            recompile_counter=("available" if self._counter.available
                               else "unavailable"),
        )

    # ---- construction helpers --------------------------------------------

    @classmethod
    def ensure(cls, obs) -> "Obs":
        """Normalize the ``obs=`` argument: None -> the shared disabled
        instance, an ObsConfig -> a fresh Obs, an Obs -> itself."""
        if obs is None:
            return NULL_OBS
        if isinstance(obs, ObsConfig):
            return cls(obs)
        return obs

    @classmethod
    def disabled(cls) -> "Obs":
        return cls(ObsConfig(enabled=False))

    def __bool__(self) -> bool:
        return self.enabled

    @property
    def run_dir(self) -> Optional[str]:
        return self.sink.run_dir

    # ---- event stream -----------------------------------------------------

    def span(self, name: str, **fields):
        """Nestable phase timer (see ``obs.timeline``)."""
        return self.timeline.span(name, **fields)

    def event(self, ev: str, **fields) -> None:
        """Emit one free-form event line (``ev`` names its type)."""
        if not self.enabled:
            return
        self.sink.emit({
            "ev": ev,
            "t": round(time.perf_counter() - self.timeline.t0, 6),  # repro: ignore[raw-timer] -- event timestamp on the run clock, not a duration window
            **fields})

    def record(self, round_record) -> None:
        """Emit a RoundRecord as a ``record`` event (JSON-safe to_dict)."""
        if not self.enabled:
            return
        self.event("record", **round_record.to_dict())

    def gauge(self, round_index: int, engine_state=None, **fields) -> None:
        """Stamp the per-round gauges: recompiles since the last stamp,
        engine-state bytes, host RSS, plus any caller tallies (cohort
        size, dropped clients, link bytes, ...)."""
        if not self.enabled:
            return
        every = self.config.gauge_every
        if every <= 0 or round_index % every:
            return
        ev = {"round": round_index,
              "rss_bytes": host_rss_bytes(), **fields}
        if engine_state is not None:
            ev["state_bytes"] = pytree_bytes(engine_state)
        if self._counter is not None and self._counter.available:
            c, s = self._counter.snapshot()
            c0, s0 = self._gauge_mark
            ev["compiles"] = c - c0
            ev["compile_s"] = round(s - s0, 6)
            self._gauge_mark = (c, s)
        self.event("gauge", **ev)

    def compiles_total(self) -> int:
        """Backend compiles since this Obs was created (0 if the counter
        hook is unavailable)."""
        if self._counter is None or not self._counter.available:
            return 0
        return self._counter.snapshot()[0] - self._compiles0

    def manifest(self, **fields) -> None:
        """Merge fields into ``manifest.json`` (``plan=`` appends to the
        manifest's ``plans`` list — one run may compile several)."""
        self.sink.write_manifest(fields)

    # ---- profiler + lifecycle --------------------------------------------

    def round_started(self, round_index: int) -> None:
        if self.enabled:
            self.profiler.round_started(round_index)

    def round_finished(self, round_index: int) -> None:
        if self.enabled:
            self.profiler.round_finished(round_index)

    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        """Stop a live profiler capture, record its status, flush."""
        if self.enabled:
            self.profiler.close()
            if self.profiler.status != "off":
                self.manifest(profiler=self.profiler.status)
        self.sink.close()


NULL_OBS = Obs(ObsConfig(enabled=False))
