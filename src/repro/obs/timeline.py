"""Nestable phase timers with correct device fencing.

jax dispatch is asynchronous: ``fn(x)`` returns as soon as the work is
*queued*, so ``time.perf_counter()`` around a call measures dispatch, not
execution — the exact bug the deployment bench shipped with and the
"65 ms noise windows" of the PR-4 log. Every timer here is explicit about
where the fence sits:

* ``Timeline.span("round/execute")`` — a nestable phase timer on the
  monotonic clock. Inside a span, ``sp.fence(value)`` blocks until
  ``value``'s device buffers are ready and books the wait into the span's
  ``sync_s``; the emitted event carries ``dur_s`` (wall) and ``sync_s``
  (device wait) separately, so host cost = ``dur_s - sync_s``.
* ``time_fenced(fn, repeats=N)`` — the bench primitive: dispatch ``fn``
  ``N`` times back-to-back, block ONCE on the last result, return wall
  seconds. This is the async-dispatch methodology every engine bench uses
  (a per-call fence would serialize dispatch against compute).
* ``fenced(fn)`` — call once, block on the result, return
  ``(out, wall_s)``. For host-side work (numpy) the fence is a no-op.

Spans nest lexically: the timeline keeps a stack, and every event records
its full ``path`` ("run/round/execute") plus ``depth``, so a reader can
rebuild the tree without matching ids. Disabled timelines hand out one
shared null span — entering it is a branch and two no-op calls.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional


def _block(value: Any) -> Any:
    """Block until every jax buffer in ``value`` is ready. Non-jax leaves
    (numpy arrays, floats, configs) pass through untouched."""
    import jax
    try:
        return jax.block_until_ready(value)
    except Exception:
        # jax.block_until_ready tree-maps; exotic leaves that object are
        # host values and already "ready"
        return value


def fenced(fn: Callable[[], Any]) -> tuple[Any, float]:
    """``(out, wall_s)`` of one fenced call: dispatch + device execute,
    never dispatch alone."""
    t0 = time.perf_counter()
    out = fn()
    _block(out)
    return out, time.perf_counter() - t0


def time_fenced(fn: Callable[[], Any], repeats: int = 1) -> float:
    """Wall seconds of ``repeats`` back-to-back dispatches of ``fn`` with
    ONE fence on the final result — the throughput-bench clock (queue the
    whole window, block at the end)."""
    out = None
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    _block(out)
    return time.perf_counter() - t0


class _NullSpan:
    """Shared do-nothing span for disabled timelines."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, value):
        return value

    def note(self, **fields):
        return None


NULL_SPAN = _NullSpan()


class Span:
    """One live phase. Use as a context manager via ``Timeline.span``.

    Names may be hierarchical ("round/execute"); the emitted ``path``
    splices them into the enclosing stack without duplicating shared
    segments, so ``span("round")`` containing ``span("round/execute")``
    yields the path ``.../round/execute``, not ``.../round/round/execute``.
    """
    __slots__ = ("_tl", "name", "fields", "t_start", "sync_s", "_extra",
                 "_pushed", "_depth")

    def __init__(self, tl: "Timeline", name: str, fields: dict):
        self._tl = tl
        self.name = name
        self.fields = fields
        self.sync_s = 0.0
        self._extra: Optional[dict] = None

    def __enter__(self):
        tl = self._tl
        stack = tl._stack
        segs = self.name.split("/")
        # drop the longest prefix of this name that repeats the stack tail
        k = 0
        for i in range(min(len(segs), len(stack)), 0, -1):
            if stack[len(stack) - i:] == segs[:i]:
                k = i
                break
        if k == len(segs):        # name identical to the stack tail: still
            k = len(segs) - 1     # push the leaf so pop stays balanced
        self._pushed = len(segs) - k
        stack.extend(segs[k:])
        self._depth = tl._open
        tl._open += 1
        self.t_start = time.perf_counter()
        return self

    def fence(self, value):
        """Block until ``value`` is device-ready; the wait books into this
        span's ``sync_s`` (device time the host spent waiting)."""
        t0 = time.perf_counter()
        _block(value)
        self.sync_s += time.perf_counter() - t0
        return value

    def note(self, **fields):
        """Attach extra fields to the span's emitted event."""
        if self._extra is None:
            self._extra = {}
        self._extra.update(fields)

    def __exit__(self, *exc):
        t_end = time.perf_counter()
        tl = self._tl
        stack = tl._stack
        path = "/".join(stack)
        del stack[len(stack) - self._pushed:]
        tl._open -= 1
        event = {
            "ev": "span",
            "name": self.name,
            "path": path,
            "depth": self._depth,
            "t": round(self.t_start - tl.t0, 6),
            "dur_s": round(t_end - self.t_start, 6),
            "sync_s": round(self.sync_s, 6),
        }
        if self.fields:
            event.update(self.fields)
        if self._extra:
            event.update(self._extra)
        tl._sink.emit(event)
        return False


class Timeline:
    """Nestable span timers writing one event per closed span to a sink."""

    def __init__(self, sink, enabled: bool = True):
        self._sink = sink
        self.enabled = enabled
        self._stack: list[str] = []   # path segments of the open spans
        self._open = 0                # count of open spans (event depth)
        self.t0 = time.perf_counter()

    def span(self, name: str, **fields) -> Any:
        """``with tl.span("round/execute"): ...`` — disabled timelines
        return the shared null span (branch-only cost)."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, fields)
