"""Run-wide gauges: recompiles, pytree/engine-state bytes, host RSS.

Everything here is zero-dependency and survives being unavailable: the
recompile counter hooks jax's internal monitoring events (present on the
pinned jax 0.4/0.5 line) but degrades to ``available=False`` if the
private module moves; RSS reads ``/proc`` and falls back to ``resource``.

The recompile counter answers the question ``RoundRecord`` can't: did XLA
silently recompile a round mid-run (a shape change, a new donation
pattern, a cache miss)? ``jax._src.monitoring`` fires one
``BACKEND_COMPILE_EVENT`` duration event per backend compile; counting
them between two snapshots counts compiles in that window — steady-state
rounds must show a delta of 0.
"""
from __future__ import annotations

import os
from typing import Optional


def pytree_bytes(tree) -> int:
    """Total array bytes across a pytree's leaves (device or numpy) — the
    PR-6 O(cohort) engine-state pin, hoisted so benches, gauges and tests
    share one definition. Non-array leaves (ints, configs) count 0."""
    import jax
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree)
                   if hasattr(x, "dtype") and hasattr(x, "size")))


def host_rss_bytes() -> int:
    """Current resident set size of this process, in bytes (0 if neither
    /proc nor the resource module can say)."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE")
    except Exception:
        pass
    try:
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    except Exception:
        return 0


class RecompileCounter:
    """Counts XLA backend compiles (and seconds spent in them) via jax's
    monitoring events. ``install()`` registers the listener; snapshot with
    ``.count`` / ``.duration_s``; window deltas via ``snapshot()``.

    One module-level counter (``global_counter()``) is shared by every Obs
    instance so repeated runs never stack listeners; unit tests may build
    their own and ``uninstall()`` it.
    """

    def __init__(self):
        self.count = 0
        self.duration_s = 0.0
        self.available = False
        self._installed = False
        self._event: Optional[str] = None

    def install(self) -> "RecompileCounter":
        if self._installed:
            return self
        try:
            from jax._src import monitoring
            from jax._src.dispatch import BACKEND_COMPILE_EVENT
        except Exception:          # toolchain moved the private hook
            self.available = False
            return self
        self._event = BACKEND_COMPILE_EVENT
        monitoring.register_event_duration_secs_listener(self._listen)
        self.available = True
        self._installed = True
        return self

    def _listen(self, event: str, duration: float, **kwargs) -> None:
        if event == self._event:
            self.count += 1
            self.duration_s += duration

    def uninstall(self) -> None:
        if not self._installed:
            return
        try:
            from jax._src import monitoring
            monitoring._unregister_event_duration_listener_by_callback(
                self._listen)
        except Exception:
            pass
        self._installed = False
        self.available = False

    def snapshot(self) -> tuple[int, float]:
        """(count, duration_s) so far — subtract two snapshots for a
        window delta."""
        return self.count, self.duration_s


_GLOBAL: Optional[RecompileCounter] = None


def global_counter() -> RecompileCounter:
    """The process-wide recompile counter, installed on first use."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = RecompileCounter().install()
    return _GLOBAL
