"""Opt-in ``jax.profiler`` trace capture scoped to rounds N..M of a run.

``ObsConfig(profile_rounds=(2, 4))`` arms a capture that starts when round
2 begins and stops after round 4 ends; the trace lands in
``<run_dir>/profile/`` (open with TensorBoard's profile plugin or
Perfetto). Capture failures never fail the run — the status lands in the
manifest instead (``"unavailable: ..."``), because the profiler's native
hooks are the one piece of this subsystem the pinned toolchain could drop.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple


class ProfilerCapture:
    """Start/stop ``jax.profiler`` around a contiguous round window."""

    def __init__(self, rounds: Optional[Tuple[int, int]], out_dir: str):
        self.rounds = tuple(rounds) if rounds is not None else None
        if self.rounds is not None and self.rounds[0] > self.rounds[1]:
            raise ValueError(f"profile_rounds=(start, stop) needs start <= "
                             f"stop, got {self.rounds}")
        self.out_dir = out_dir
        self.active = False
        self.status = "off" if self.rounds is None else "armed"

    def round_started(self, round_index: int) -> None:
        if (self.rounds is None or self.active
                or round_index != self.rounds[0]):
            return
        try:
            import jax.profiler
            os.makedirs(self.out_dir, exist_ok=True)
            jax.profiler.start_trace(self.out_dir)
            self.active = True
            self.status = f"tracing rounds {self.rounds[0]}..{self.rounds[1]}"
        except Exception as e:                      # never fail the run
            self.status = f"unavailable: {type(e).__name__}: {e}"

    def round_finished(self, round_index: int) -> None:
        if self.active and round_index >= self.rounds[1]:
            self._stop()

    def close(self) -> None:
        """Stop a still-open capture (a run shorter than the window)."""
        if self.active:
            self._stop()

    def _stop(self) -> None:
        try:
            import jax.profiler
            jax.profiler.stop_trace()
            self.status = f"captured -> {self.out_dir}"
        except Exception as e:
            self.status = f"stop failed: {type(e).__name__}: {e}"
        self.active = False
