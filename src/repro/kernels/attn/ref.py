"""Pure-jnp oracle for the flash attention kernel (O(S^2), f32)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q (B,H,S,D); k,v (B,H,Sk,D)."""
    b, h, s, d = q.shape
    sk = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
