"""jit'd wrapper choosing Pallas (TPU) or the jnp fallback, in model layout.

Models use (B,S,H,D); the kernel uses (B,H,S,D). GQA KV heads are repeated
here. On CPU containers the Pallas path runs in interpret mode (tests); the
default model path uses the chunked-jnp implementation in
``repro.models.attention`` which XLA fuses natively.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...models.attention import gqa_repeat
from .flash import flash_attention
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas", "interpret"))
def attention(q, k, v, *, causal=True, window=None, use_pallas=False,
              interpret=True):
    """q (B,S,H,D); k,v (B,S,Kh,D) -> (B,S,H,D)."""
    h = q.shape[2]
    k = gqa_repeat(k, h // k.shape[2]).transpose(0, 2, 1, 3)
    v = gqa_repeat(v, h // v.shape[2]).transpose(0, 2, 1, 3)
    qt = q.transpose(0, 2, 1, 3)
    if use_pallas:
        out = flash_attention(qt, k, v, causal=causal, window=window,
                              interpret=interpret)
    else:
        out = flash_attention_ref(qt, k, v, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
