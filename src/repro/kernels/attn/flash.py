"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA-ready).

Canonical TPU tiling: grid (B, H, nq, nk) with ``dimension_semantics``
("parallel","parallel","parallel","arbitrary") — the innermost kv axis runs
sequentially per q block, carrying the online-softmax state (m, l, acc) in
VMEM scratch. Block shapes are explicit BlockSpecs; q/kv block defaults
(256, 512) keep the working set (q + k + v + acc tiles) well under VMEM
while the (bq x bk) score tile feeds the MXU with 128-aligned dims.

Causal + window masking is done per-tile; fully-masked tiles are skipped
with @pl.when so SWA costs O(S * window). Non-block-aligned sequence
lengths are zero-padded up to the block multiple (never shrunk toward
bq=1): padded key positions are masked with ``kv_len`` inside the kernel,
padded query rows are sliced off the output.

``flash_attention`` is differentiable: Pallas interpret mode has no
transpose rule on this toolchain, so the backward pass is the closed-form
flash-attention gradient (recomputed scores, dS = P∘(dP − rowsum(dO∘O)))
registered via ``jax.custom_vjp``. It is O(S²) memory — fine for the
training shapes this repo runs; a tiled backward kernel is future work.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, bq: int, bk: int, nk: int, causal: bool,
                  window, kv_len):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * bq
    k_lo = ik * bk
    # tile-level skip: no query in this block can see any key in that block
    live = True
    if causal:
        live = k_lo <= q_lo + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)
    if kv_len is not None:    # kv was padded: trailing tiles may be all-pad
        live = jnp.logical_and(live, k_lo < kv_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        if kv_len is not None:
            mask &= kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _pad_axis2(x: jax.Array, n_pad: int) -> jax.Array:
    if n_pad == x.shape[2]:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, n_pad - x.shape[2]), (0, 0)))


def _flash_forward(q, k, v, causal, window, block_q, block_k, interpret):
    b, h, s, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, s)
    bk = min(block_k, sk)
    # pad to the block multiple instead of shrinking the block (the old
    # ``while s % bq: bq //= 2`` fallback degrades toward bq=1 on prime S)
    s_pad = -(-s // bq) * bq
    sk_pad = -(-sk // bk) * bk
    q = _pad_axis2(q, s_pad)
    k = _pad_axis2(k, sk_pad)
    v = _pad_axis2(v, sk_pad)
    kv_len = sk if sk_pad != sk else None
    nq, nk = s_pad // bq, sk_pad // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, causal=causal, window=window,
                               kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s] if s_pad != s else out


def _masked_probs(q, k, d, causal, window):
    """Recomputed (B,H,S,Sk) float32 softmax probabilities, masked exactly
    like the forward kernel."""
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(d)
    qpos = jnp.arange(q.shape[2])[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((q.shape[2], k.shape[2]), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s_ = jnp.where(mask, s_, NEG_INF)
    return jax.nn.softmax(s_, axis=-1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_k, interpret):
    return _flash_forward(q, k, v, causal, window, block_q, block_k,
                          interpret)


def _flash_vjp_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    o = _flash_forward(q, k, v, causal, window, block_q, block_k, interpret)
    return o, (q, k, v, o)


def _flash_vjp_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v, o = res
    d = q.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    gf, of = g.astype(jnp.float32), o.astype(jnp.float32)
    p = _masked_probs(qf, kf, d, causal, window)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, gf)
    dp = jnp.einsum("bhqd,bhkd->bhqk", gf, vf)
    delta = jnp.sum(gf * of, axis=-1, keepdims=True)       # (B,H,S,1)
    ds = p * (dp - delta)
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) / math.sqrt(d)
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) / math.sqrt(d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None,
                    block_q: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B,H,S,D); k,v (B,H,Sk,D) — GQA callers repeat KV heads first.
    Returns (B,H,S,D)."""
    return _flash(q, k, v, causal, window, block_q, block_k, interpret)
