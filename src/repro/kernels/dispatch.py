"""Backend-aware kernel dispatch: which impl of each hot-path kernel runs.

The spec layer names *intents* (``ModelSpec.attn_impl``,
``EngineSpec.link_kernel``); this module resolves them to concrete kernel
paths at lowering time. Resolution is the ONLY place backend sniffing
happens — everything below takes explicit ``use_pallas``/``interpret``
flags:

- ``"auto"``   -> Pallas on an accelerator backend (TPU/GPU), the XLA
  reference path on CPU (where Pallas only runs in interpret mode and is
  a correctness oracle, not a win).
- ``"pallas"`` / ``"fused"`` -> force the Pallas kernel; off-accelerator
  it runs in interpret mode (slow, bit-level oracle for parity tests and
  the jaxpr audit of kernel-enabled lowerings).
- ``"xla"``    -> the plain jnp/XLA path (today's default, bit-identical
  to the pre-kernel lowerings).
- ``"ref"``    (attention only) -> the O(S²) ``kernels/attn/ref.py``
  oracle via the same dispatch seam the Pallas path uses.
"""
from __future__ import annotations

import jax

ATTN_IMPLS = ("auto", "xla", "pallas", "ref")
LINK_KERNELS = ("auto", "xla", "fused")


def accelerator_backend() -> bool:
    """True when the default JAX backend compiles Pallas natively."""
    return jax.default_backend() in ("tpu", "gpu")


def resolve_attn_impl(impl: str) -> str:
    """'auto'|'xla'|'pallas'|'ref' -> concrete impl for this backend."""
    if impl not in ATTN_IMPLS:
        raise ValueError(f"attn_impl must be one of {ATTN_IMPLS}, got {impl!r}")
    if impl == "auto":
        return "pallas" if accelerator_backend() else "xla"
    return impl


def resolve_link_kernel(kind: str) -> tuple[bool, bool]:
    """'auto'|'xla'|'fused' -> ``(use_pallas, interpret)`` for FleetLink."""
    if kind not in LINK_KERNELS:
        raise ValueError(
            f"link_kernel must be one of {LINK_KERNELS}, got {kind!r}")
    if kind == "auto":
        kind = "fused" if accelerator_backend() else "xla"
    return kind == "fused", not accelerator_backend()
