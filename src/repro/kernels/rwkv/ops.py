"""jit'd wrapper for the RWKV-6 scan: Pallas on TPU, lax.scan oracle on CPU."""
from __future__ import annotations

from functools import partial

import jax

from .ref import rwkv6_scan_ref
from .scan import rwkv6_scan


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def wkv(r, k, v, w, u, *, use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return rwkv6_scan(r, k, v, w, u, interpret=interpret)
    return rwkv6_scan_ref(r, k, v, w, u)
