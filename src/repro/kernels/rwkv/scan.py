"""Pallas TPU kernel: RWKV-6 ("Finch") linear-recurrence scan.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (per head, S: hd x hd)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

This is the compute hot-spot of rwkv6-7b: a sequential recurrence whose
state (hd x hd = 64x64 f32 = 16KB/head) lives in VMEM scratch across the
sequential time-block grid axis, while r/k/v/w stream through VMEM in
(block_t, hd) tiles. Grid: (B, H, nt) with
dimension_semantics ("parallel","parallel","arbitrary") — the time axis is
sequential and carries the state.

Inside a time block the recurrence is an unrolled fori_loop of rank-1
updates — on TPU these map to VPU ops over the (hd, hd) tile; the matmul
y_t = r_t S is a (1,hd)x(hd,hd) MXU op. hd=64 keeps every operand
128-lane-aligned after the natural (8,128) retiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                 block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                       # (hd,)

    def step(t, S):
        r_t = r_ref[0, 0, t].astype(jnp.float32)           # (hd,)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                   # (hd, hd)
        y_t = r_t @ (S + u[:, None] * kv)                  # (hd,)
        y_ref[0, 0, t] = y_t.astype(y_ref.dtype)
        return w_t[:, None] * S + kv

    s_scr[...] = jax.lax.fori_loop(0, block_t, step, s_scr[...])


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, block_t: int = 128,
               interpret: bool = False) -> jax.Array:
    """r/k/v/w: (B, H, T, hd) — w is the per-step decay in (0,1);
    u: (H, hd) bonus. Returns y (B, H, T, hd) f32."""
    b, h, t, hd = r.shape
    bt = min(block_t, t)
    while t % bt:
        bt //= 2
    nt = t // bt
    kernel = functools.partial(_rwkv_kernel, block_t=bt)
    spec = pl.BlockSpec((1, 1, bt, hd), lambda ib, ih, it: (ib, ih, it, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda ib, ih, it: (ih, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u)
