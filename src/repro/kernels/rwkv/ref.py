"""Pure-jnp oracle for the RWKV-6 scan kernel (lax.scan over T)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rwkv6_scan_ref(r, k, v, w, u):
    """r/k/v/w (B,H,T,hd) ; u (H,hd) -> y (B,H,T,hd) f32."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    b, h, t, hd = rf.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + uf[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (rf, kf, vf, wf))
    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    _, ys = lax.scan(step, S0, xs)
    return ys.transpose(1, 2, 0, 3)
