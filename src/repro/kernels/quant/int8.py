"""Pallas TPU kernel: row-blockwise int8 quantization of smashed activations.

This is the SL link compressor (the paper's stated future work — activation
compression — promoted here to a first-class feature): the client quantizes
the smashed tensor before the UAV hop, the server dequantizes. Wire volume
L drops ~4x vs f32 (Eq. 8: T_SL = L/R shrinks proportionally).

Tiling: grid over row blocks; each program sees an (block_rows, d) VMEM
tile, computes a per-row absmax scale, and emits int8 codes + f32 scales.
``d`` is expected to be a multiple of 128 (lane width); row blocks of 256
keep tiles ~64KB-1MB for typical d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (bm, d)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (bm, 1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(x_ref.dtype)


def quantize_int8(x: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False):
    """x (M, D) -> (codes int8 (M, D), scales f32 (M, 1))."""
    m, d = x.shape
    bm = min(block_rows, m)
    while m % bm:
        bm //= 2
    grid = (m // bm,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m, d), jnp.int8),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_int8(codes: jax.Array, scales: jax.Array, *,
                    out_dtype=jnp.float32, block_rows: int = 256,
                    interpret: bool = False) -> jax.Array:
    m, d = codes.shape
    bm = min(block_rows, m)
    while m % bm:
        bm //= 2
    grid = (m // bm,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), out_dtype),
        interpret=interpret,
    )(codes, scales)
