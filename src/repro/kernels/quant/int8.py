"""Pallas TPU kernel: row-blockwise int8 quantization of smashed activations.

This is the SL link compressor (the paper's stated future work — activation
compression — promoted here to a first-class feature): the client quantizes
the smashed tensor before the UAV hop, the server dequantizes. Wire volume
L drops ~4x vs f32 (Eq. 8: T_SL = L/R shrinks proportionally).

Tiling: grid over row blocks; each program sees an (block_rows, d) VMEM
tile, computes a per-row absmax scale, and emits int8 codes + f32 scales.
``d`` is expected to be a multiple of 128 (lane width); row blocks of 256
keep tiles ~64KB-1MB for typical d. Row counts that do not divide the
block are zero-padded up to the block multiple (padded rows quantize to
code 0 at the 1e-8 scale floor and are sliced off) — never shrunk toward
bm=1.

``quant_dequant_int8`` is the fused link-boundary kernel: ONE pallas_call
does quant + per-row scale + dequant (no int8/scale HBM round-trip), with
an optional fused residual-stream epilogue for the server side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # (bm, d)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (bm, 1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(x_ref.dtype)


def _quant_dequant_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def _quant_dequant_residual_kernel(x_ref, r_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    o_ref[...] = (q * scale + r_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _pad_rows(x: jax.Array, m_pad: int) -> jax.Array:
    if m_pad == x.shape[0]:
        return x
    return jnp.pad(x, ((0, m_pad - x.shape[0]), (0, 0)))


def _row_blocks(m: int, block_rows: int) -> tuple[int, int]:
    """(block size, padded row count): pad M up to the block multiple
    instead of shrinking the block toward 1 on awkward (e.g. prime) M."""
    bm = min(block_rows, m)
    return bm, -(-m // bm) * bm


def quantize_int8(x: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False):
    """x (M, D) -> (codes int8 (M, D), scales f32 (M, 1))."""
    m, d = x.shape
    bm, m_pad = _row_blocks(m, block_rows)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(m_pad // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((m_pad, d), jnp.int8),
                   jax.ShapeDtypeStruct((m_pad, 1), jnp.float32)],
        interpret=interpret,
    )(_pad_rows(x, m_pad))
    return (q[:m], s[:m]) if m_pad != m else (q, s)


def dequantize_int8(codes: jax.Array, scales: jax.Array, *,
                    out_dtype=jnp.float32, block_rows: int = 256,
                    interpret: bool = False) -> jax.Array:
    m, d = codes.shape
    bm, m_pad = _row_blocks(m, block_rows)
    y = pl.pallas_call(
        _dequant_kernel,
        grid=(m_pad // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), out_dtype),
        interpret=interpret,
    )(_pad_rows(codes, m_pad), _pad_rows(scales, m_pad))
    return y[:m] if m_pad != m else y


def quant_dequant_int8(x: jax.Array, *, residual: jax.Array | None = None,
                       out_dtype=None, block_rows: int = 256,
                       interpret: bool = False) -> jax.Array:
    """Fused int8 link boundary: quant + per-row scale + dequant in ONE
    kernel (the int8 codes and scales never leave VMEM). With ``residual``
    the server-side epilogue ``dequant(x) + residual`` fuses in too."""
    m, d = x.shape
    out_dtype = out_dtype or x.dtype
    bm, m_pad = _row_blocks(m, block_rows)
    spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    if residual is None:
        kernel, in_specs = _quant_dequant_kernel, [spec]
        operands = (_pad_rows(x, m_pad),)
    else:
        kernel, in_specs = _quant_dequant_residual_kernel, [spec, spec]
        operands = (_pad_rows(x, m_pad), _pad_rows(residual, m_pad))
    y = pl.pallas_call(
        kernel,
        grid=(m_pad // bm,),
        in_specs=in_specs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m_pad, d), out_dtype),
        interpret=interpret,
    )(*operands)
    return y[:m] if m_pad != m else y
