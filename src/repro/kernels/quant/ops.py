"""jit'd wrappers: straight-through int8 link compressor for split learning.

``link_compress`` is differentiable (straight-through estimator): forward
quantize→dequantize, backward identity — so the split train step can keep
the compressed link inside one autodiff program (Algorithm 3 with the
compression future-work enabled).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .int8 import quant_dequant_int8
from .ref import dequantize_int8_ref, quantize_int8_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_dequant(x: jax.Array, *, use_pallas: bool = False,
                  interpret: bool = True) -> jax.Array:
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_pallas:
        # ONE fused kernel: quant + per-row scale + dequant, codes/scales
        # never round-trip through HBM (vs the two-op XLA reference)
        y = quant_dequant_int8(x2, out_dtype=x.dtype, interpret=interpret)
    else:
        q, s = quantize_int8_ref(x2)
        y = dequantize_int8_ref(q, s, out_dtype=x.dtype)
    return y.reshape(shape)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_dequant_residual(x: jax.Array, residual: jax.Array, *,
                           use_pallas: bool = False,
                           interpret: bool = True) -> jax.Array:
    """Server-side fused epilogue: ``dequant(quant(x)) + residual`` in one
    kernel — the serve tier adds the incoming smashed activations onto the
    server residual stream without materializing the dequantized tensor."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = residual.reshape(-1, shape[-1])
    if use_pallas:
        y = quant_dequant_int8(x2, residual=r2, out_dtype=x.dtype,
                               interpret=interpret)
    else:
        q, s = quantize_int8_ref(x2)
        y = (dequantize_int8_ref(q, s, out_dtype=jnp.float32)
             + r2.astype(jnp.float32)).astype(x.dtype)
    return y.reshape(shape)


def make_link_compress(*, use_pallas: bool = False, interpret: bool = True):
    """Build a straight-through int8 link compressor bound to one kernel path.

    The fleet link layer (``repro.fleet.link``) uses this to wire the Pallas
    kernel (or its jnp oracle on CPU containers) into ``SplitStep`` as an
    opt-in compressed boundary; the returned callable is vmap-able, so the
    sharded fleet engine can batch it over the client axis.
    """

    @jax.custom_vjp
    def compress(x: jax.Array) -> jax.Array:
        return quant_dequant(x, use_pallas=use_pallas, interpret=interpret)

    def _fwd(x):
        return compress(x), None

    def _bwd(_, g):
        return (g,)   # straight-through

    compress.defvjp(_fwd, _bwd)
    return compress


# default compressor: jnp oracle path (runs everywhere, incl. CPU containers)
link_compress = make_link_compress()
