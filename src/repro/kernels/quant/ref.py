"""Pure-jnp oracle for the int8 quant/dequant kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8_ref(x: jax.Array):
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(codes: jax.Array, scales: jax.Array, *,
                        out_dtype=jnp.float32) -> jax.Array:
    return (codes.astype(jnp.float32) * scales).astype(out_dtype)


def roundtrip_error_bound(x: jax.Array) -> jax.Array:
    """|x - dq(q(x))| <= scale/2 per element."""
    _, scale = quantize_int8_ref(x)
    return scale / 2.0
