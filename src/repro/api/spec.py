"""Declarative experiment specs — one dataclass tree for every round shape.

An ``ExperimentSpec`` names *what* to run (model, data, clients, cut, link,
engine, optional UAV mission); ``api.plan.compile_experiment`` lowers it to
the matching compiled engine. The paper's whole sweep space — FL baseline
vs sequential SL (Alg. 3) vs parallel fleet SL, homogeneous vs per-client
adaptive cuts, fp32 vs int8 links, with or without the UAV mission budget —
is spanned by field edits on one spec, never by switching entry points.

Engine selection (``EngineSpec``):

  kind  client_axis  lowers to
  ----  -----------  -------------------------------------------------------
  fl    scan         ``core.split.make_fl_round(client_axis='scan')``
  fl    vmap         ``fleet.engine.make_fleet_fl_round`` (shardable)
  fl    shard_map    same engine, explicit-collective variant: the local
                     step runs inside ``jax.shard_map`` over ``data`` and
                     FedAvg is ``core.fedavg.fedavg_pmean``
  sl    scan         ``core.split.make_multi_client_round`` (sequential Alg. 3)
  sl    vmap         ``fleet.engine.make_fleet_sl_round`` (parallel SL);
                     heterogeneous (adaptive) cuts dispatch through
                     ``fleet.hetero.HeteroFleet`` — one compiled round per
                     cut bucket
  sl    shard_map    parallel SL with the pinned collective schedule
                     (in-map ``lax.pmean`` server gradient,
                     ``fedavg_pmean_stack`` prefixes); hetero cuts bucket
                     the same way

``EngineSpec.server_mesh=(fsdp, tp)`` grows the fleet mesh to the 2D
(clients x server-model) layout: ``compile_experiment`` builds a
``('data','fsdp','tp')`` mesh (``launch.mesh.make_fleet_mesh``) and wires
``launch.steps.fleet_server_pspecs`` tier specs into the SL round so the
server suffix shards fsdp x tp while the client axis shards over ``data``.

Policies, not code paths:

  * ``CutPolicy``  — fixed layer fraction, or P3SL-style per-client adaptive
    cuts from each client's (hardware, link) profile; when a mission is
    present and no explicit ``max_link_s`` is given, the UAV hover window
    bounds the per-step link time (``runtime.mission_max_link_s``).
  * ``LinkPolicy`` — fp32 or int8 straight-through boundary + wire-byte
    accounting (``fleet.link.FleetLink``).
  * ``ClientSpec.dropout_rate`` — EPSL/P3SL-style straggler masking: each
    round a Bernoulli mask drops clients from training, aggregation and
    energy billing (fleet engines only).
  * ``ExperimentSpec.scenario`` (``repro.sim.ScenarioSpec``) — the
    stochastic environment: A2G channel draws re-bill the link per round,
    availability traces drive the dropout masks, multi-UAV dispatch and
    serve geometry reshape the mission. The degenerate scenario reproduces
    the idealized records exactly (see ``repro.sim``).

``ModelSpec(family="transformer", arch=ArchConfig)`` swaps the CNN stage
lists for a split LM over real stacked attention blocks
(``fleet.hetero.lm_split_program``) trained on ``DataSpec(kind="tokens")``
streams; ``DataSpec.partition`` picks the client skew (classes /
dirichlet / iid).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..configs.base import ArchConfig
from ..core.energy import HardwareProfile, JETSON_AGX_ORIN
from ..core.link import LinkConfig
from ..core.uav_energy import DEFAULT_UAV, UAVParams
from ..sim.scenario import ScenarioSpec  # noqa: F401  (re-exported field type)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    family: str = "cnn"          # "cnn" (Stage lists) | "transformer"
    name: str = "tinycnn"        # cnn: key into models.cnn.CNN_BUILDERS
    num_classes: int = 12        # cnn label space (transformers use arch.vocab)
    # transformer family: the ArchConfig whose stacked attention blocks are
    # split at the CutPolicy fraction (fleet.hetero.lm_split_program — embed
    # + prefix blocks on the client, suffix blocks + LM head on the server)
    arch: Optional[ArchConfig] = None
    # attention kernel for the transformer blocks (kernels.dispatch):
    # "xla" (chunked jnp path, bit-identical default) | "pallas" (flash
    # kernel; interpret mode off-accelerator) | "ref" (O(S²) oracle) |
    # "auto" (pallas on TPU/GPU, xla on CPU)
    attn_impl: str = "xla"


@dataclasses.dataclass(frozen=True)
class DataSpec:
    kind: str = "synthetic"      # "synthetic" | "arrays" (pass data= at
    #                              compile) | "tokens" (synthetic LM stream)
    image_size: int = 32
    classes_per_client: int = 3  # non-IID shards (paper §IV-C)
    # client partition: "classes" (paper §IV-C fixed classes-per-client) |
    # "dirichlet" (label-skew, Dirichlet(alpha) per class) | "iid"
    partition: str = "classes"
    dirichlet_alpha: float = 0.5
    seq_len: int = 32            # tokens kind: sequence length per sample
    n_train: int = 0             # 0 -> heuristic from fleet size/classes
    n_test: int = 0
    shrink_batches: bool = False  # cap batch at smallest partition (legacy
    #                               paper_train behaviour; campaigns keep
    #                               exact batch_size so hoisted constants hold)


@dataclasses.dataclass(frozen=True)
class ClientSpec:
    num_clients: int = 4
    # heterogeneity source: profiles cycled across clients (Eq. 9 scaling
    # and, under an adaptive CutPolicy, per-client cut selection). With a
    # population, profiles cycle over POPULATION ids and are gathered to
    # the sampled cohort each round.
    edge_profiles: Tuple[HardwareProfile, ...] = (JETSON_AGX_ORIN,)
    # P3SL-style straggler masking: per-round probability a client drops
    # out of training/aggregation (fleet engines only; >=1 client kept)
    dropout_rate: float = 0.0
    # cross-device scale: the total client population M the per-round
    # cohort of K = num_clients participants is sampled from (uniform, or
    # availability-weighted under a scenario trace — sim.sample_cohort).
    # None == today's fully-materialized fleet (no sampling); population
    # == num_clients is the degenerate corner that reproduces the
    # materialized records exactly; population > num_clients keeps engine
    # state O(K): FL cohorts are stateless, parallel-SL cohorts share one
    # client tier (EPSL).
    population: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class CutPolicy:
    mode: str = "fraction"       # "fraction" | "adaptive"
    fraction: float = 0.25       # SL_{a,b}: client holds a% of layers
    min_client_layers: int = 1   # privacy floor (raw data stays on device)
    # per-step link deadline for adaptive selection; None + mission ->
    # derived from the UAV hover window (runtime.mission_max_link_s)
    max_link_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class LinkPolicy:
    rate_bps: float = 100e6
    compress: str = "none"       # "none" | "int8"
    radio_power_w: float = 2.0

    def config(self) -> LinkConfig:
        return LinkConfig(rate_bps=self.rate_bps, compress=self.compress,
                          radio_power_w=self.radio_power_w)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    kind: str = "sl"             # "fl" | "sl"
    # "scan" (sequential) | "vmap" (fleet, GSPMD-inferred collectives) |
    # "shard_map" (fleet, explicit fedavg_pmean / in-map lax.pmean)
    client_axis: str = "scan"
    server_reduce: str = "mean"  # fleet SL server gradient reduction
    # (fsdp, tp) sizes of the server suffix's 2D sub-mesh; None -> (1, 1).
    # compile_experiment grows the fleet mesh to ('data','fsdp','tp') and
    # shards the SL server params/optimizer state with the
    # launch.steps.fleet_server_pspecs tier specs.
    server_mesh: Optional[Tuple[int, int]] = None
    # int8 link-boundary kernel (only bites with LinkPolicy.compress="int8"):
    # "xla" (two-op jnp quant/dequant reference, default) | "fused" (ONE
    # Pallas kernel: quant + per-row scale + dequant; interpret mode
    # off-accelerator) | "auto" (fused on TPU/GPU, xla on CPU)
    link_kernel: str = "xla"

    @property
    def is_fleet(self) -> bool:
        return self.client_axis in ("vmap", "shard_map")


@dataclasses.dataclass(frozen=True)
class MissionSpec:
    farm_acres: float = 100.0
    uav: UAVParams = DEFAULT_UAV
    hover_s_per_stop: float = 30.0
    comm_s_per_stop: float = 10.0


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    model: ModelSpec = ModelSpec()
    data: DataSpec = DataSpec()
    clients: ClientSpec = ClientSpec()
    cut_policy: CutPolicy = CutPolicy()
    link_policy: LinkPolicy = LinkPolicy()
    engine: EngineSpec = EngineSpec()
    mission: Optional[MissionSpec] = None   # None -> no tour/budget/UAV terms
    # stochastic environment (repro.sim): A2G channel draws, availability
    # traces, multi-UAV dispatch. None keeps the idealized constants; the
    # degenerate scenario reproduces them exactly (sim.degenerate_scenario)
    scenario: Optional[ScenarioSpec] = None
    global_rounds: int = 4       # cap; a mission's UAV budget may cut it short
    local_steps: int = 2
    batch_size: int = 8
    lr: float = 1e-3
    seed: int = 0

    def describe(self) -> str:
        """One-line engine label for records/logs."""
        cut = (self.cut_policy.mode if self.engine.kind == "sl" else "-")
        pop = self.clients.population
        cohort = ("" if pop is None
                  else f",cohort={self.clients.num_clients}/{pop}")
        return (f"{self.engine.kind}/{self.engine.client_axis}"
                f"[cut={cut},link={self.link_policy.compress},"
                f"mission={'yes' if self.mission else 'no'}{cohort}]")
