"""The uniform per-round record every compiled plan emits.

One ``RoundRecord`` per executed global round, regardless of which engine
ran it — FL or SL, scanned or fleet-vmapped, homogeneous or hetero-cut,
with or without a UAV mission. Fields an engine has nothing to say about
are zero (e.g. ``link_*`` for FL, ``uav_energy_j`` without a mission), so
downstream consumers (campaign totals, benches, reports) read one schema.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    round: int
    loss: float                  # mean training loss over ACTIVE clients
    accuracy: float              # held-out accuracy after the round (nan if
                                 # the round ran without evaluation)
    link_bytes: float            # wire bytes this round (all active clients)
    link_time_s: float
    link_energy_j: float         # edge radio transmit energy (L/R * P_radio)
    client_energy_j: float       # edge compute, Eq. (9)-scaled
    server_energy_j: float
    uav_energy_j: float          # tour energy for this round (Alg. 2)
    client_time_s: float = 0.0   # edge compute seconds behind client_energy_j
    server_time_s: float = 0.0
    active_clients: int = -1     # clients that survived dropout this round
    engine: str = ""             # "fl/scan" | "fl/vmap" | "sl/scan" | "sl/vmap"
    # population ids behind this round's cohort slots (ClientSpec.population
    # sampling; empty when the fleet is fully materialized). Slot i of every
    # per-client quantity this round belonged to population client
    # cohort_pids[i].
    cohort_pids: tuple = ()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)
