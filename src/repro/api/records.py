"""The uniform per-round record every compiled plan emits.

One ``RoundRecord`` per executed global round, regardless of which engine
ran it — FL or SL, scanned or fleet-vmapped, homogeneous or hetero-cut,
with or without a UAV mission. Fields an engine has nothing to say about
are zero (e.g. ``link_*`` for FL, ``uav_energy_j`` without a mission), so
downstream consumers (campaign totals, benches, reports) read one schema.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    round: int
    loss: float                  # mean training loss over ACTIVE clients
    accuracy: float              # held-out accuracy after the round (nan if
                                 # the round ran without evaluation)
    link_bytes: float            # wire bytes this round (all active clients)
    link_time_s: float
    link_energy_j: float         # edge radio transmit energy (L/R * P_radio)
    client_energy_j: float       # edge compute, Eq. (9)-scaled
    server_energy_j: float
    uav_energy_j: float          # tour energy for this round (Alg. 2)
    client_time_s: float = 0.0   # edge compute seconds behind client_energy_j
    server_time_s: float = 0.0
    active_clients: int = -1     # clients that survived dropout this round
    engine: str = ""             # "fl/scan" | "fl/vmap" | "sl/scan" | "sl/vmap"
    # population ids behind this round's cohort slots (ClientSpec.population
    # sampling; empty when the fleet is fully materialized). Slot i of every
    # per-client quantity this round belonged to population client
    # cohort_pids[i].
    cohort_pids: tuple = ()
    # metrics-bus summary of the round (repro.obs.metrics): a flat
    # JSON-able scalar dict keyed "<channel>/<stat>" ("grad_norm_client/
    # mean", "health/nonfinite", ...). Empty unless the plan was compiled
    # with ObsConfig(metrics=MetricsConfig(...)).
    metrics: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        """JSON-serializable dict of the record. Field values can arrive as
        numpy scalars (``cohort_pids`` gathered from a device cohort,
        metrics pulled out of jitted evals) and ``json.dumps`` refuses
        those — every scalar is coerced to its Python equivalent here, so
        any sink/report can dump the result verbatim."""
        return {k: _jsonable(v)
                for k, v in dataclasses.asdict(self).items()}


def _jsonable(v):
    """Python-native scalar(s) for one record field: numpy/jax scalars via
    ``item()``, tuples element-wise (``cohort_pids``), dicts value-wise
    (``metrics``)."""
    if isinstance(v, tuple):
        return tuple(_jsonable(x) for x in v)
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if hasattr(v, "item"):
        return v.item()
    return v
