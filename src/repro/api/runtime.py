"""Shared experiment runtime helpers — one home for logic the bespoke
entry points used to duplicate.

Everything here is deliberately *neutral*: it imports only ``core``,
``models`` and ``data`` modules (never ``core.paper_train`` or
``fleet.campaign``), so both the legacy shims and the compiled-plan layer
can depend on it without import cycles.

Hoisted from ``core.paper_train`` / ``fleet.campaign`` (which previously
carried private near-copies):

  * ``round_batches``        — one global round of pre-gathered minibatch
                               stacks with a leading client axis
  * ``client_step_time_s``   — A5000-roofline seconds scaled to an edge
                               profile via paper Eq. (9)
  * ``count_fl_step_flops`` / ``count_sl_step_flops`` — the symmetric
                               per-step FLOP accounting both pipelines share
  * ``classification_metrics`` — the paper's Fig. 3 radar metrics
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.energy import (HardwareProfile, JETSON_AGX_ORIN, RTX_A5000,
                           scale_time)
from ..core.flops import flops_of
from ..core.split import apply_stages
from ..models.cnn import cross_entropy_loss


# ---------------------------------------------------------------------------
# batch gathering (leading client axis)
# ---------------------------------------------------------------------------

def round_batches(x, y, parts, batch_size, steps, rng, *,
                  shrink: bool = False):
    """One global round of minibatches, pre-gathered and stacked on a
    leading client axis: ``((clients, steps, b, ...), (clients, steps, b))``.

    Sampling is with replacement so small partitions still fill batches
    (hoisted per-step link/energy constants stay exact). With ``shrink``
    the batch dimension is capped at the smallest partition size (the
    legacy ``paper_train`` behaviour); otherwise empty partitions are an
    error and every batch is exactly ``batch_size``.
    """
    empty = [ci for ci, idx in enumerate(parts) if len(idx) == 0]
    if empty:
        raise ValueError(f"clients {empty} drew no data; increase the "
                         f"training set or classes_per_client")
    bs = min(batch_size, min(len(idx) for idx in parts)) if shrink \
        else batch_size
    sel = np.stack([rng.choice(idx, size=(steps, bs), replace=True)
                    for idx in parts])
    return jnp.asarray(x[sel]), jnp.asarray(y[sel])


def client_coords(acres: float, n: int, *, seed: int = 0) -> np.ndarray:
    """``n`` edge-device positions on a square farm: a jittered uniform grid
    over the next square count, truncated to ``n`` (deterministic)."""
    import math

    from ..core.deployment import field_side_meters
    side = field_side_meters(acres)
    g = int(math.ceil(math.sqrt(n)))
    xs = (np.arange(g) + 0.5) * side / g
    pts = np.stack(np.meshgrid(xs, xs, indexing="ij"), axis=-1).reshape(-1, 2)
    rng = np.random.RandomState(seed)
    pts = pts + rng.uniform(-0.05, 0.05, size=pts.shape) * side / g
    return pts[:n]


def stack_replicas(tree, n: int):
    """Broadcast one pytree to ``n`` identical replicas on a leading axis."""
    return jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), tree)


# ---------------------------------------------------------------------------
# analytic per-step time constants (paper Eq. 9 methodology)
# ---------------------------------------------------------------------------

def roofline_s(flops: float, hw: HardwareProfile) -> float:
    return flops / (hw.fp32_tflops * 1e12)


def client_step_time_s(flops: float,
                       edge: HardwareProfile = JETSON_AGX_ORIN) -> float:
    """Edge-device seconds per step: A5000 roofline scaled via Eq. (9)."""
    return scale_time(roofline_s(flops, RTX_A5000), RTX_A5000, edge)


def mission_max_link_s(hover_s_per_stop: float, comm_s_per_stop: float,
                       local_steps: int) -> float:
    """Per-step link deadline implied by the UAV's dwell at one stop.

    Algorithm 2 parks the UAV ``hover + comm`` seconds per edge device per
    round; a round runs ``local_steps`` split steps, each needing one
    smashed-data roundtrip, so each step's link time must fit an equal
    share of the dwell window. ``adaptive_cut.select_cut(max_link_s=...)``
    takes this directly.
    """
    return (hover_s_per_stop + comm_s_per_stop) / max(local_steps, 1)


# ---------------------------------------------------------------------------
# symmetric per-step FLOP counting (shared by FL and SL accounting)
# ---------------------------------------------------------------------------

def count_fl_step_flops(stages, params, bx, by) -> float:
    """XLA-counted (analytic fallback) fwd+bwd FLOPs of one full-model
    training step on one minibatch."""
    return flops_of(
        lambda p, xx, yy: jax.grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, xx), yy))(p),
        params, bx, by)


def count_sl_step_flops(cs, cp, ss, sp, bx, by):
    """Per-tier fwd+bwd FLOPs of one split step, counted symmetrically with
    ``count_fl_step_flops``.

    client: prefix forward + the VJP that turns the returned cut gradient
    into client-param gradients (the full client-side backward).
    server: suffix forward + backward w.r.t. server params AND the smashed
    input (the cut gradient it sends back).
    Returns (client_flops, server_flops, smashed_shape_dtype_struct).
    """
    smashed_sd = jax.eval_shape(lambda p, xx: apply_stages(cs, p, xx), cp, bx)
    cut_grad = jnp.zeros(smashed_sd.shape, smashed_sd.dtype)

    def client_step(p, xx, ct):
        smashed, vjp = jax.vjp(lambda q: apply_stages(cs, q, xx), p)
        return smashed, vjp(ct)

    def server_step(p, sm, yy):
        return jax.grad(
            lambda q, s: cross_entropy_loss(apply_stages(ss, q, s), yy),
            argnums=(0, 1))(p, sm)

    client_fl = flops_of(client_step, cp, bx, cut_grad)
    server_fl = flops_of(server_step, sp, cut_grad, by)
    return client_fl, server_fl, smashed_sd


def count_split_step_flops(step, cp, sp, bx, by):
    """``count_sl_step_flops`` generalized to any ``SplitStep`` (transformer
    stacks included): same symmetric accounting, driven through the step's
    own ``client_fwd`` / ``server_loss`` instead of CNN stage lists. The
    link boundary is excluded on both sides (byte accounting prices it).
    Returns (client_flops, server_flops, smashed_shape_dtype_struct)."""
    smashed_sd = jax.eval_shape(step.client_fwd, cp, bx)
    cut_grad = jnp.zeros(smashed_sd.shape, smashed_sd.dtype)

    def client_step(p, xx, ct):
        smashed, vjp = jax.vjp(lambda q: step.client_fwd(q, xx), p)
        return smashed, vjp(ct)

    def server_step(p, sm, yy):
        return jax.grad(
            lambda q, s: step.server_loss(q, s, yy)[0], argnums=(0, 1))(p, sm)

    client_fl = flops_of(client_step, cp, bx, cut_grad)
    server_fl = flops_of(server_step, sp, cut_grad, by)
    return client_fl, server_fl, smashed_sd


def accuracy_from_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Scalar held-out accuracy as a pure device computation — the jittable
    core of ``classification_metrics`` the Monte-Carlo rollouts can run
    INSIDE a vmapped sweep (the full radar metrics are host numpy)."""
    return jnp.mean((jnp.argmax(logits, axis=-1)
                     == jnp.asarray(labels)).astype(jnp.float32))


# ---------------------------------------------------------------------------
# metrics (paper Fig. 3 radar: Acc / Precision / Recall / F1 / MCC)
# ---------------------------------------------------------------------------

def classification_metrics(logits: jax.Array, labels: jax.Array,
                           num_classes: int) -> dict:
    pred = np.asarray(logits.argmax(-1))
    y = np.asarray(labels)
    acc = float((pred == y).mean())
    precs, recs, f1s = [], [], []
    for c in range(num_classes):
        tp = float(((pred == c) & (y == c)).sum())
        fp = float(((pred == c) & (y != c)).sum())
        fn = float(((pred != c) & (y == c)).sum())
        p = tp / (tp + fp) if tp + fp else 0.0
        r = tp / (tp + fn) if tp + fn else 0.0
        precs.append(p)
        recs.append(r)
        f1s.append(2 * p * r / (p + r) if p + r else 0.0)
    # multiclass MCC
    n = len(y)
    t_k = np.bincount(y, minlength=num_classes).astype(float)
    p_k = np.bincount(pred, minlength=num_classes).astype(float)
    c = float((pred == y).sum())
    s2 = n * n
    num = c * n - float(t_k @ p_k)
    den = np.sqrt(max(s2 - float(p_k @ p_k), 0.0)) * \
        np.sqrt(max(s2 - float(t_k @ t_k), 0.0))
    mcc = num / den if den else 0.0
    return {"accuracy": acc, "precision": float(np.mean(precs)),
            "recall": float(np.mean(recs)), "f1": float(np.mean(f1s)),
            "mcc": float(mcc)}
