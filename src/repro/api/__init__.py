"""Unified experiment layer: one declarative spec compiles every round.

``ExperimentSpec`` (a dataclass tree: model + data + clients + cut_policy +
link_policy + engine + optional mission) names an experiment;
``compile_experiment`` lowers it to a ``Plan`` with a uniform
``init() / run_round() / evaluate()`` surface and a ``RoundRecord`` stream,
dispatching internally to the scan/vmap/shard_map/hetero engines. The
legacy entry points are gone; ``core.paper_train.paper_spec`` and
``fleet.campaign.campaign_spec`` map the historical configs onto specs.

See ``src/repro/api/README.md`` for the old-call-site -> spec table and
``docs/ARCHITECTURE.md`` for the layer map.
"""
from .records import RoundRecord
from .runtime import (classification_metrics, client_coords,
                      client_step_time_s, count_fl_step_flops,
                      count_sl_step_flops, mission_max_link_s, round_batches,
                      stack_replicas)
from .spec import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                   ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec,
                   ScenarioSpec)
from .plan import Plan, PlanState, compile_experiment

__all__ = [n for n in dir() if not n.startswith("_")]
