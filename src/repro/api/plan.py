"""``compile_experiment``: lower one declarative spec to one compiled plan.

A ``Plan`` is the uniform run surface every entry point now shares:

    plan = compile_experiment(spec, mesh=..., data=...)
    state = plan.init()
    state, rec = plan.run_round(state)          # one RoundRecord per round
    metrics = plan.evaluate(state)

Internally the plan dispatches on ``spec.engine`` to the existing compiled
engines (see ``api.spec`` for the lowering table), wires the policies —
FedAvg, adaptive cuts, the int8 link boundary, client dropout, UAV mission
budgeting — into that engine, and hoists every energy/FLOP/link constant
out of the hot loop at compile time (the paper's analytic Eq. 8/9
accounting). Nothing is metered per step; ``run_round`` multiplies
pre-computed per-client constants by the step counts of the round that
actually ran (dropout masks excluded clients from both training and
billing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import keys
from ..core.energy import RTX_A5000
from ..core.link import LinkConfig
from ..core.split import (SplitStep, apply_stages, cut_index_for_fraction,
                          init_stages, make_fl_round,
                          make_multi_client_round, stack_cut_index)
from ..core.trajectory import TourPlan, plan_tour
from ..data.partition import (partition_dirichlet, partition_iid,
                              partition_non_iid, population_partition_count)
from ..data.synthetic import SyntheticPestImages, synthetic_tokens
from ..fleet.engine import (make_fleet_fl_round, make_fleet_sl_round,
                            server_mesh_sizes, shard_server_state,
                            validate_fleet_mesh)
from ..launch.mesh import make_fleet_mesh, single_device_fleet_mesh
from ..fleet.hetero import (HeteroFleet, assign_cuts_cnn, cnn_split_program,
                            lm_split_program)
from ..fleet.link import FleetLink
from ..kernels.dispatch import (ATTN_IMPLS, LINK_KERNELS, resolve_attn_impl,
                                resolve_link_kernel)
from ..models.cnn import CNN_BUILDERS, cross_entropy_loss
from ..obs import NULL_OBS, Obs
from ..obs.metrics import (NonfiniteError, engine_tap_names,
                           split_step_tap_names, summarize_round_metrics)
from ..optim import adamw, init_stacked
from ..sim.channel import deterministic_rate_bps, sample_rates_bps
from ..sim.mission import MissionTimeline, rollout_mission
from ..sim.scenario import (COHORT_DOWN_WEIGHT, availability_init,
                            availability_step, sample_cohort)
from .records import RoundRecord
from .runtime import (accuracy_from_logits, classification_metrics,
                      client_coords, client_step_time_s, count_fl_step_flops,
                      count_sl_step_flops, count_split_step_flops,
                      mission_max_link_s, roofline_s, round_batches,
                      stack_replicas)
from .spec import ExperimentSpec

# time billed to the FL server per round: aggregation only (negligible
# FLOPs; the historical constant from the faithful reproduction trainer)
FL_SERVER_AGG_S = 1e-3


@dataclasses.dataclass
class PlanState:
    """Mutable run state threaded through ``run_round``."""
    round: int
    engine_state: Any               # pytree tuple, or the HeteroFleet
    rng: np.random.RandomState      # minibatch sampling stream
    dropout_rng: np.random.RandomState
    last_metrics: Optional[dict] = None   # full metric dict of the last eval
    avail_up: Optional[np.ndarray] = None  # scenario availability (clients,)
    #                                        up/down state carried per round


class Plan:
    """A compiled experiment. Built by ``compile_experiment`` — the
    attributes below are its public read surface; the engine closures are
    private."""

    def __init__(self, spec: ExperimentSpec, *, mesh, arrays, parts, stages,
                 params0, tour: Optional[TourPlan], cut_of_client,
                 flops: dict, edges, consts, engine_fns,
                 timeline: Optional[MissionTimeline] = None,
                 serve_dist_m=None, rate_nominal=None, prof_consts=None,
                 obs: Optional[Obs] = None, metrics=None,
                 graph_taps: tuple = ()):
        self.spec = spec
        self.mesh = mesh
        # metrics bus (repro.obs.metrics): the MetricsConfig the plan was
        # compiled with (None = off) and the in-graph tap channels its
        # engine rounds emit — with any graph taps the round closures
        # return (state, losses, taps) instead of (state, losses)
        self.metrics_config = metrics
        self.graph_taps = tuple(graph_taps)
        # telemetry facade (repro.obs): the shared disabled instance unless
        # compile_experiment was handed an ObsConfig — disabled, every
        # hot-path touch is a branch + no-op call
        self.obs = obs if obs is not None else NULL_OBS
        self.engine_label = f"{spec.engine.kind}/{spec.engine.client_axis}"
        self.x_train, self.y_train, self.x_test, self.y_test = arrays
        self.parts = parts
        self.stages = stages
        self.params0 = params0
        self.tour = tour
        self.timeline = timeline      # scenario missions (sim.rollout_mission)
        budget = (timeline.rounds if timeline is not None
                  else tour.rounds if tour is not None else None)
        self.rounds_budget = budget
        self.num_rounds = (min(spec.global_rounds, budget)
                           if budget is not None else spec.global_rounds)
        self.cut_of_client = list(cut_of_client)
        self.flops = flops            # {"full": f} | {cut: (client, server, sd)}
        self.edges = edges
        n = spec.clients.num_clients
        # scenario runtime: serving distances + the nominal (deterministic)
        # per-client rates the link constants were hoisted at
        self.serve_dist_m = (np.zeros(n) if serve_dist_m is None
                             else np.asarray(serve_dist_m))
        self.rate_nominal = (np.full(n, spec.link_policy.rate_bps)
                             if rate_nominal is None
                             else np.asarray(rate_nominal))
        scn = spec.scenario
        self._channel = scn.channel if scn is not None else None
        self._scn_key = (jax.random.PRNGKey(scn.seed)
                         if scn is not None else None)
        self._mask_in_engine = _needs_mask(spec)
        # cohort sampling (ClientSpec.population): the environment key the
        # per-round cohort draw folds from — the scenario's stream when one
        # is attached (so Monte-Carlo sweep seed i replays realization
        # scn.seed + i, cohorts included), the seed-0 environment otherwise
        # (matching run_monte_carlo's default ScenarioSpec())
        self._population = spec.clients.population
        self._env_key = (self._scn_key if self._scn_key is not None
                         else jax.random.PRNGKey(0))
        # per-PROFILE per-step constants for cohort billing (edge_profiles
        # cycle over population ids, gathered to the sampled cohort); None
        # when the fleet is fully materialized (per-slot consts suffice)
        self._t_client_prof, self._p_edge_prof = (
            prof_consts if prof_consts is not None else (None, None))
        # hoisted per-client constants (np arrays over the client axis)
        (self._t_client, self._t_server, self._link_bytes, self._link_time,
         self._link_energy, self._server_base_s) = consts
        # engine closures: (init_state, run, eval, raw unjitted run, raw
        # jittable held-out accuracy — the raw pair is None for hetero
        # plans, which have no single jittable round)
        (self._init_state, self._run, self._eval, self._run_raw,
         self._eval_acc_raw) = engine_fns

    # ---- lifecycle --------------------------------------------------------

    def init(self) -> PlanState:
        """Fresh run state (per-client model/optimizer stacks, RNG streams).
        The batch stream matches the legacy trainers' (one RandomState
        seeded with ``spec.seed``, one ``choice`` per client per round)."""
        scn = self.spec.scenario
        # availability runs over the POPULATION when one is declared (the
        # trace both masks the sampled cohort and weights the next draw);
        # O(population) scalars, never O(population) model state
        n_avail = (self._population if self._population is not None
                   else self.spec.clients.num_clients)
        avail_up = (np.asarray(availability_init(n_avail))
                    if scn is not None and scn.needs_mask else None)
        return PlanState(
            round=0, engine_state=self._init_state(),
            rng=np.random.RandomState(self.spec.seed),
            dropout_rng=np.random.RandomState(self.spec.seed + 1),
            avail_up=avail_up)

    def round_batches(self, state: PlanState, cohort=None):
        """Pre-gathered (clients, local_steps, ...) stacks for one round, in
        the engine's batch format (FL: ``(bx, by)``; SL: dict).

        Population plans draw the FULL partition pool (one leading row per
        distinct partition, the same RNG call sequence as a materialized
        fleet) and gather rows by ``cohort`` population ids; with
        ``cohort=None`` the raw pool is returned — the Monte-Carlo sweeps
        stack pools per round and gather inside the jitted rollout, where
        the cohort is a traced value."""
        bx, by = round_batches(self.x_train, self.y_train, self.parts,
                               self.spec.batch_size, self.spec.local_steps,
                               state.rng, shrink=self.spec.data.shrink_batches)
        if cohort is not None:
            sel = np.asarray(cohort) % len(self.parts)
            bx, by = bx[sel], by[sel]
        if self.spec.engine.kind == "fl":
            return bx, by
        return {"inputs": bx, "targets": by}

    def _round_cohort(self, state: PlanState) -> Optional[np.ndarray]:
        """The round's sorted cohort population ids (None when the fleet is
        fully materialized). Key-folded from the environment key
        (``keys.ENV_COHORT`` — mask is ``ENV_MASK``, rates ``ENV_RATES``)
        so Monte-Carlo sweeps replay the identical cohort stream; weighted
        by the availability state ENTERING the round when a scenario trace
        runs (down clients draw at ``COHORT_DOWN_WEIGHT``), uniform
        otherwise."""
        if self._population is None:
            return None
        key = keys.fold(keys.round_env_key(self._env_key, state.round),
                        keys.ENV_COHORT)
        weights = None
        scn = self.spec.scenario
        if scn is not None and scn.needs_mask:
            up = jnp.asarray(state.avail_up)
            weights = up + (1.0 - up) * COHORT_DOWN_WEIGHT
        return np.asarray(sample_cohort(key, self._population,
                                        self.spec.clients.num_clients,
                                        weights=weights))

    def _round_mask(self, state: PlanState,
                    cohort=None) -> Optional[np.ndarray]:
        scn = self.spec.scenario
        if scn is not None and scn.needs_mask:
            # scenario availability trace: jax-native + key-folded per round,
            # bit-identical to the Monte-Carlo rollout's mask stream
            key = keys.fold(keys.round_env_key(self._scn_key, state.round),
                            keys.ENV_MASK)
            mask, up = availability_step(key, jnp.asarray(state.avail_up),
                                         scn.availability)
            state.avail_up = np.asarray(up)
            mask = np.asarray(mask, np.float32)
            if cohort is not None:
                # population trace -> cohort slots. availability_step's
                # >=1-active guard holds for the population, not the slice:
                # an all-down cohort keeps slot 0 (same rule as the MC
                # rollout's jnp.where guard)
                mask = mask[cohort]
                if mask.sum() == 0:
                    mask[0] = 1.0
            return mask
        rate = self.spec.clients.dropout_rate
        if rate <= 0.0:
            return None
        n = self.spec.clients.num_clients
        mask = (state.dropout_rng.uniform(size=n) >= rate).astype(np.float32)
        if mask.sum() == 0:          # never drop the whole fleet
            mask[state.dropout_rng.randint(n)] = 1.0
        return mask

    def _round_rate_ratio(self, round_index: int) -> Optional[np.ndarray]:
        """nominal/sampled channel rate per client for one round (None when
        no channel is attached — keep the hoisted constants verbatim)."""
        if self._channel is None:
            return None
        key = keys.fold(keys.round_env_key(self._scn_key, round_index),
                        keys.ENV_RATES)
        rates = sample_rates_bps(key, self._channel,
                                 jnp.asarray(self.serve_dist_m),
                                 self.spec.link_policy.rate_bps)
        return np.asarray(self.rate_nominal / np.asarray(rates))

    def run_round(self, state: PlanState, batches=None, *,
                  with_eval: bool = True) -> tuple[PlanState, RoundRecord]:
        """Execute one global round; returns (state, RoundRecord). Batches
        default to the plan's own stream; pass them explicitly to drive the
        engine with external data (the perf benches do).

        With telemetry enabled (``compile_experiment(..., obs=)``) the
        round decomposes into spans — ``round/sample`` (cohort/mask draw +
        host batch gather), ``round/execute`` (engine dispatch, fenced so
        device wait lands in ``sync_s``), ``round/eval``, ``round/account``
        (record assembly) — plus one gauge stamp (engine-state bytes, host
        RSS, recompiles since the last stamp) and the record itself."""
        obs = self.obs
        r = state.round
        obs.round_started(r)
        with obs.span("round", round=r):
            with obs.span("round/sample", round=r):
                cohort = self._round_cohort(state)
                if batches is None:
                    batches = self.round_batches(state, cohort=cohort)
                mask = self._round_mask(state, cohort=cohort)
            with obs.span("round/execute", round=r) as sp:
                out = self._run(state.engine_state, batches, mask)
                if self.graph_taps:
                    # taps ride the SAME device->host pull as the losses:
                    # one fence for the whole round output
                    state.engine_state, losses, taps = out
                    losses, taps = sp.fence((losses, taps))
                else:
                    state.engine_state, losses = out
                    losses = sp.fence(losses)
                    taps = None
            rec = self._assemble_record(state, losses, mask, cohort,
                                        taps=taps, with_eval=with_eval)
            if obs:
                n = self.spec.clients.num_clients
                obs.gauge(r, engine_state=state.engine_state,
                          active_clients=rec.active_clients,
                          dropped=n - rec.active_clients,
                          cohort=len(rec.cohort_pids),
                          link_bytes=rec.link_bytes)
                obs.record(rec)
                if rec.metrics:
                    obs.event("metrics", round=r, engine=self.engine_label,
                              **rec.metrics)
        obs.round_finished(r)
        state.round += 1
        return state, rec

    def _assemble_record(self, state: PlanState, losses, mask, cohort, *,
                         with_eval: bool, taps=None) -> RoundRecord:
        """Host-side accounting of one executed round: loss extraction,
        optional held-out eval, the analytic energy/link bill, and — when
        the plan carries a MetricsConfig — the metrics-bus summary (with
        the ``on_nonfinite='raise'`` health policy applied)."""
        obs = self.obs
        n = self.spec.clients.num_clients
        steps = self.spec.local_steps
        with obs.span("round/account", round=state.round):
            active = (np.arange(n) if mask is None
                      else np.flatnonzero(mask > 0))
            # losses: FL (clients, steps); SL (steps, clients)
            loss_c = np.asarray(losses)
            loss = float((loss_c[active, :] if self.spec.engine.kind == "fl"
                          else loss_c[:, active]).mean())
            uav = 0.0
            if self.timeline is not None:
                uav = self.timeline.uav_energy_j(state.round)
            elif self.tour is not None:
                uav = float(self.tour.e_first if state.round == 0
                            else self.tour.e_per_round)
            # compute time/energy price the SAMPLED clients' hardware: under
            # a population, per-profile constants are gathered to the
            # cohort's pids (profiles cycle over pids); materialized fleets
            # keep the per-slot arrays (identical values when cohort ==
            # identity)
            if cohort is not None and self._t_client_prof is not None:
                prof = cohort % len(self._t_client_prof)
                t_client, p_edge = (self._t_client_prof[prof],
                                    self._p_edge_prof[prof])
            else:
                t_client = self._t_client
                p_edge = np.asarray([e.power_w for e in self.edges])
            t_cli = float(t_client[active].sum() * steps)
            e_cli = float(sum(t_client[c] * steps * p_edge[c]
                              for c in active))
            t_srv = float(self._t_server[active].sum() * steps
                          + self._server_base_s)
            # channel-attached scenarios re-bill link time/energy per round
            # at the sampled rates (constants x nominal/sampled ratio);
            # otherwise the hoisted constants stand verbatim
            ratio = self._round_rate_ratio(state.round)
            l_time, l_energy = self._link_time, self._link_energy
            if ratio is not None:
                l_time, l_energy = l_time * ratio, l_energy * ratio
            metrics = {}
            if self.metrics_config is not None:
                tm = ({} if taps is None
                      else {k: np.asarray(v) for k, v in taps.items()})
                metrics = summarize_round_metrics(
                    self.metrics_config, tm, losses=loss_c,
                    kind=self.spec.engine.kind, n=n, active=len(active))
                if (self.metrics_config.on_nonfinite == "raise"
                        and metrics.get("health/nonfinite", 0)):
                    raise NonfiniteError(
                        round_index=state.round,
                        step=metrics["health/first_step"],
                        client=metrics["health/first_client"],
                        count=metrics["health/nonfinite"])
        if with_eval:
            with obs.span("round/eval", round=state.round):
                state.last_metrics = self.evaluate(state)
            accuracy = state.last_metrics["accuracy"]
        else:
            accuracy = float("nan")
        return RoundRecord(
            round=state.round, loss=loss, accuracy=accuracy,
            link_bytes=float(self._link_bytes[active].sum() * steps),
            link_time_s=float(l_time[active].sum() * steps),
            link_energy_j=float(l_energy[active].sum() * steps),
            client_time_s=t_cli, client_energy_j=e_cli,
            server_time_s=t_srv,
            server_energy_j=t_srv * RTX_A5000.power_w,
            uav_energy_j=uav, active_clients=len(active),
            engine=self.engine_label,
            cohort_pids=(() if cohort is None
                         else tuple(int(p) for p in cohort)),
            metrics=metrics)

    def raw_round(self, engine_state, batches, mask=None):
        """One engine round with NO record assembly or host synchronization:
        ``(engine_state, losses_device_array)`` — plus the device tap dict
        as a third element when the plan carries in-graph metrics taps
        (``graph_taps``). The throughput benches use this to queue rounds
        back-to-back (jax async dispatch) and block once at the end —
        ``run_round``'s per-round loss extraction would otherwise serialize
        dispatch against compute."""
        return self._run(engine_state, batches, mask)

    def evaluate(self, state: PlanState) -> dict:
        """Held-out classification metrics of the current global model."""
        return self._eval(state.engine_state)

    def run(self, rounds: Optional[int] = None, *, with_eval: bool = True
            ) -> tuple[PlanState, list[RoundRecord]]:
        """Init + run ``rounds`` (default: the mission-budgeted round count)
        and collect the record stream. With telemetry enabled the whole run
        is one ``run`` span over per-round spans; mission plans additionally
        emit the tour-leg decomposition (travel/hover/comm on the simulated
        mission clock — ``fleet.campaign.mission_obs_events``) and the sink
        is flushed before returning."""
        obs = self.obs
        num = self.num_rounds if rounds is None else rounds
        records = []
        with obs.span("run", rounds=num):
            with obs.span("init"):
                state = self.init()
            for _ in range(num):
                state, rec = self.run_round(state, with_eval=with_eval)
                records.append(rec)
        if obs:
            if self.tour is not None or self.timeline is not None:
                # deferred: fleet.campaign imports api.records at module
                # level; importing it here avoids the package cycle
                from ..fleet.campaign import mission_obs_events
                for ev in mission_obs_events(self, records):
                    obs.event(**ev)
            obs.flush()
        return state, records


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _resolve_data(spec: ExperimentSpec, data):
    if data is not None or spec.data.kind == "arrays":
        if data is None:
            raise ValueError("DataSpec(kind='arrays') needs data=(x_train, "
                             "y_train, x_test, y_test) at compile time")
        return tuple(np.asarray(a) for a in data)
    key = jax.random.PRNGKey(spec.seed)
    if spec.data.kind == "tokens":
        # synthetic LM stream: inputs are tokens[:, :-1], targets the next
        # token — the transformer family's data pipeline
        vocab = spec.model.arch.vocab
        n_train = spec.data.n_train or max(24 * spec.clients.num_clients, 96)
        n_test = spec.data.n_test or max(n_train // 4, 32)
        seq = spec.data.seq_len
        toks_tr = synthetic_tokens(keys.fold(key, keys.DATA_TRAIN), n_train,
                                   seq + 1, vocab)
        toks_te = synthetic_tokens(keys.fold(key, keys.DATA_TEST), n_test,
                                   seq + 1, vocab)
        return (np.asarray(toks_tr[:, :-1]), np.asarray(toks_tr[:, 1:]),
                np.asarray(toks_te[:, :-1]), np.asarray(toks_te[:, 1:]))
    gen = SyntheticPestImages(num_classes=spec.model.num_classes,
                              image_size=spec.data.image_size, seed=spec.seed)
    n_train = spec.data.n_train or max(24 * spec.clients.num_clients,
                                       12 * spec.model.num_classes)
    n_test = spec.data.n_test or max(n_train // 4, 48)
    x_train, y_train = gen.sample(keys.fold(key, keys.DATA_TRAIN), n_train)
    x_test, y_test = gen.sample(keys.fold(key, keys.DATA_TEST), n_test)
    return (np.asarray(x_train), np.asarray(y_train),
            np.asarray(x_test), np.asarray(y_test))


def _resolve_parts(spec: ExperimentSpec, y_train: np.ndarray) -> list:
    """Client data partition per ``DataSpec.partition``. With a population,
    partitioning is by population id: ``population_partition_count`` distinct
    shards cycled over pids (``pid % count``), gathered to the sampled
    cohort per round — the materialized corner (population == num_clients)
    builds exactly today's per-client partitions."""
    n = spec.clients.num_clients
    if spec.clients.population is not None:
        n = population_partition_count(spec.clients.population, len(y_train))
    if spec.data.partition == "dirichlet":
        return partition_dirichlet(y_train, n, alpha=spec.data.dirichlet_alpha,
                                   seed=spec.seed, min_size=1)
    if spec.data.partition == "iid":
        return partition_iid(len(y_train), n, seed=spec.seed)
    return partition_non_iid(y_train, n, spec.data.classes_per_client,
                             num_classes=spec.model.num_classes,
                             seed=spec.seed)


def _profile_consts(spec: ExperimentSpec, client_flops):
    """Per-PROFILE ``(t_client_s, power_w)`` arrays for cohort billing.
    Only materialized under a population with one homogeneous per-step
    client cost (``client_flops``): device profiles cycle over population
    ids exactly as they cycle over materialized slots, so the per-round
    gather ``cohort % n_profiles`` reproduces per-slot constants bit-for-bit
    in the degenerate corner."""
    if spec.clients.population is None or client_flops is None:
        return None
    profs = spec.clients.edge_profiles
    return (np.asarray([client_step_time_s(client_flops, p) for p in profs]),
            np.asarray([p.power_w for p in profs]))


def _needs_mask(spec: ExperimentSpec) -> bool:
    """Whether the compiled engine must accept a per-round client mask
    (i.i.d. dropout policy, or a stochastic scenario availability trace)."""
    if spec.clients.dropout_rate > 0:
        return True
    scn = spec.scenario
    return scn is not None and scn.needs_mask


def _validate(spec: ExperimentSpec):
    eng = spec.engine
    cli = spec.clients
    if cli.num_clients < 1:
        raise ValueError(f"ClientSpec.num_clients must be >= 1, got "
                         f"{cli.num_clients}")
    if not 0.0 <= cli.dropout_rate < 1.0:
        raise ValueError(f"ClientSpec.dropout_rate must be in [0, 1), got "
                         f"{cli.dropout_rate} (1.0 would drop every client "
                         f"every round)")
    if cli.population is not None:
        if cli.population < cli.num_clients:
            raise ValueError(
                f"ClientSpec.population={cli.population} is smaller than the "
                f"cohort num_clients={cli.num_clients}; a round samples "
                f"num_clients participants FROM the population (use "
                f"population=None for a fully-materialized fleet)")
        if cli.population > cli.num_clients:
            if eng.kind == "sl" and not eng.is_fleet:
                raise ValueError(
                    "population sampling with sl/scan is unsupported: the "
                    "sequential Algorithm 3 engine keeps per-slot client "
                    "params + Adam moments across rounds, which would leak "
                    "state between the different population clients a slot "
                    "maps to; use sl/vmap or sl/shard_map (the EPSL shared "
                    "client tier) or fl/* (stateless rounds)")
            if spec.cut_policy.mode == "adaptive":
                raise ValueError(
                    "adaptive per-client cuts re-bucket (and so recompile) "
                    "per sampled cohort; population sampling supports "
                    "fraction cuts only")
    if eng.kind not in ("fl", "sl"):
        raise ValueError(f"engine.kind must be 'fl' or 'sl', got {eng.kind!r}")
    if eng.client_axis not in ("scan", "vmap", "shard_map"):
        raise ValueError(f"engine.client_axis must be 'scan', 'vmap' or "
                         f"'shard_map', got {eng.client_axis!r}")
    if spec.model.family not in ("cnn", "transformer"):
        raise ValueError(f"unknown model family {spec.model.family!r}")
    if spec.model.family == "transformer":
        if spec.model.arch is None:
            raise ValueError("ModelSpec(family='transformer') needs arch="
                             "ArchConfig (the stacked attention blocks to "
                             "split)")
        if spec.model.arch.n_experts:
            raise ValueError("MoE stacks can't split through the stacked-"
                             "block interface (see transformer_block_apply)")
        if eng.kind != "sl":
            raise ValueError("the transformer family trains split (sl); the "
                             "full-model FL baseline is a CNN-family path")
        if spec.cut_policy.mode != "fraction":
            raise ValueError("transformer cuts are fraction-placed "
                             "(stack_cut_index); adaptive per-client cuts "
                             "are a CNN-stage path for now")
        if spec.data.kind not in ("tokens",):
            raise ValueError("transformer specs train on DataSpec("
                             "kind='tokens')")
        if spec.data.partition != "iid":
            raise ValueError("token streams carry no label classes to skew; "
                             "use DataSpec(partition='iid')")
        if eng.server_mesh is not None:
            raise ValueError("server_mesh tier specs are wired for the CNN "
                             "stage path only; the transformer family would "
                             "silently replicate the server suffix (plumb "
                             "fleet_server_pspecs through _compile_sl_stack "
                             "to lift this)")
    elif spec.model.name not in CNN_BUILDERS:
        raise ValueError(f"unknown CNN {spec.model.name!r}")
    if spec.model.attn_impl not in ATTN_IMPLS:
        raise ValueError(f"ModelSpec.attn_impl must be one of {ATTN_IMPLS}, "
                         f"got {spec.model.attn_impl!r}")
    if spec.model.attn_impl != "xla" and spec.model.family != "transformer":
        raise ValueError("ModelSpec.attn_impl selects the transformer "
                         "attention kernel; CNN stage lists have no "
                         "attention to dispatch")
    if eng.link_kernel not in LINK_KERNELS:
        raise ValueError(f"EngineSpec.link_kernel must be one of "
                         f"{LINK_KERNELS}, got {eng.link_kernel!r}")
    if eng.link_kernel != "xla" and spec.link_policy.compress != "int8":
        raise ValueError("EngineSpec.link_kernel fuses the int8 boundary; "
                         "it needs LinkPolicy(compress='int8')")
    if spec.data.kind not in ("synthetic", "arrays", "tokens"):
        raise ValueError(f"DataSpec.kind must be 'synthetic', 'arrays' or "
                         f"'tokens', got {spec.data.kind!r}")
    if spec.data.kind == "tokens" and spec.model.family != "transformer":
        raise ValueError("DataSpec(kind='tokens') is the transformer "
                         "family's pipeline; CNN specs train on 'synthetic' "
                         "or 'arrays'")
    if spec.data.partition not in ("classes", "dirichlet", "iid"):
        raise ValueError(f"DataSpec.partition must be 'classes', 'dirichlet' "
                         f"or 'iid', got {spec.data.partition!r}")
    if spec.cut_policy.mode not in ("fraction", "adaptive"):
        raise ValueError(spec.cut_policy.mode)
    if spec.cut_policy.mode == "adaptive" and not (
            eng.kind == "sl" and eng.is_fleet):
        raise ValueError("adaptive cuts produce per-client programs; they "
                         "need the bucketed fleet engine (sl/vmap or "
                         "sl/shard_map)")
    if spec.clients.dropout_rate > 0 and not eng.is_fleet:
        raise ValueError("client dropout is a fleet policy; use a vmap or "
                         "shard_map client axis")
    if spec.scenario is not None:
        spec.scenario.validate(has_mission=spec.mission is not None)
        if spec.scenario.needs_mask and not eng.is_fleet:
            raise ValueError("availability traces mask clients per round; "
                             "they need a fleet engine (vmap or shard_map "
                             "client axis)")
        if spec.scenario.needs_mask and spec.clients.dropout_rate > 0:
            raise ValueError("pick ONE straggler process: ClientSpec."
                             "dropout_rate (i.i.d.) or the scenario's "
                             "availability trace")
        if spec.scenario.num_uavs > spec.clients.num_clients:
            raise ValueError(f"{spec.scenario.num_uavs} UAVs for "
                             f"{spec.clients.num_clients} clients")
    if eng.server_mesh is not None:
        if eng.kind != "sl" or not eng.is_fleet:
            raise ValueError("server_mesh shards the SL server suffix; it "
                             "needs a fleet SL engine (sl/vmap or "
                             "sl/shard_map)")
        f, t = eng.server_mesh
        if f < 1 or t < 1:
            raise ValueError(f"server_mesh sizes must be >= 1, got "
                             f"{eng.server_mesh}")


def _resolve_mesh(spec: ExperimentSpec, mesh):
    """Pick/validate the fleet mesh for a fleet-axis engine. ``server_mesh``
    grows a ('data','fsdp','tp') layout; shard_map always gets a concrete
    mesh (single-device fallback) so the explicit-collective program
    compiles anywhere."""
    eng = spec.engine
    if not eng.is_fleet:
        return mesh
    n = spec.clients.num_clients
    if mesh is None and eng.server_mesh is not None:
        f, t = eng.server_mesh
        mesh = make_fleet_mesh(n, fsdp=f, tp=t)
        if mesh is None and f * t > 1:
            raise ValueError(
                f"server_mesh={eng.server_mesh} needs at least {f * t} "
                f"devices ({len(jax.devices())} available)")
    elif mesh is not None and eng.server_mesh is not None:
        # an explicit mesh must deliver the server sub-mesh the spec asked
        # for — never silently fall back to a replicated server suffix
        if server_mesh_sizes(mesh) != tuple(eng.server_mesh):
            raise ValueError(
                f"server_mesh={eng.server_mesh} but the supplied mesh has "
                f"(fsdp, tp)={server_mesh_sizes(mesh)}; build it with "
                f"launch.mesh.make_fleet_mesh(num_clients, fsdp=, tp=) or "
                f"drop one of the two")
    if mesh is None and eng.client_axis == "shard_map":
        mesh = make_fleet_mesh(n) or single_device_fleet_mesh()
    validate_fleet_mesh(mesh, n)
    f, t = server_mesh_sizes(mesh)
    if (eng.client_axis == "shard_map" and f * t > 1
            and jax.default_backend() == "cpu"):
        # this repo's pinned XLA:CPU partitioner aborts (hard, not an
        # exception) on fsdp/tp-sharded operands entering the manual
        # body's scan — see fleet.engine and ROADMAP; the vmap engine
        # runs the full 2D layout on every backend
        raise ValueError(
            "client_axis='shard_map' with a >1 server_mesh is gated off "
            "the CPU backend (XLA:CPU partitioner abort in the pinned "
            "toolchain); use client_axis='vmap' for the 2D layout on CPU")
    return mesh


def compile_experiment(spec: ExperimentSpec, *, mesh=None, data=None,
                       obs=None) -> Plan:
    """Lower ``spec`` to a ``Plan``. ``data`` is an optional
    ``(x_train, y_train, x_test, y_test)`` tuple (required for
    ``DataSpec(kind='arrays')``); ``mesh`` an optional fleet mesh
    (``launch.mesh.make_fleet_mesh`` — built automatically for
    ``client_axis='shard_map'`` or a ``server_mesh``): the stacked client
    axis of fleet engines shards over ``data``, the SL server suffix over
    ``fsdp`` x ``tp``.

    ``obs`` opts into telemetry: an ``repro.obs.ObsConfig`` (or a live
    ``Obs`` to share one run dir across several plans). Lowering phases
    emit ``compile/*`` spans, the plan stamps its row into the run
    manifest, and every ``run_round`` streams spans/gauges/records to
    ``results/runs/<run_id>/`` (see ``repro.obs``). ``None`` (default)
    attaches the shared disabled instance — hot paths pay one branch."""
    obs = Obs.ensure(obs)
    with obs.span("compile", spec=spec.describe()):
        plan = _compile_plan(spec, mesh=mesh, data=data, obs=obs)
    if obs:
        mesh_shape = (None if plan.mesh is None
                      else {k: int(v) for k, v in plan.mesh.shape.items()})
        obs.manifest(plan={
            "spec": spec.describe(), "engine": plan.engine_label,
            "model": (spec.model.name if spec.model.family == "cnn"
                      else spec.model.family),
            "num_clients": spec.clients.num_clients,
            "population": spec.clients.population,
            "rounds": plan.num_rounds, "local_steps": spec.local_steps,
            "batch_size": spec.batch_size, "mesh": mesh_shape})
        obs.flush()
    return plan


def _compile_plan(spec: ExperimentSpec, *, mesh, data, obs: Obs) -> Plan:
    _validate(spec)
    n = spec.clients.num_clients
    mesh = _resolve_mesh(spec, mesh)
    # metrics bus: resolve the in-graph tap channels at compile time. No
    # MetricsConfig (the default) -> empty taps -> every round builder
    # lowers its exact tap-free program (the bit-identity the jaxpr audit
    # pins). ObsConfig(enabled=False, metrics=...) is honored: taps work
    # without a sink.
    metrics = obs.config.metrics
    graph_taps = engine_tap_names(
        metrics, kind=spec.engine.kind,
        has_link=spec.link_policy.compress == "int8")
    step_tap_names = split_step_tap_names(graph_taps)
    with obs.span("compile/data"):
        arrays = _resolve_data(spec, data)
        x_train, y_train, x_test, y_test = arrays
        parts = _resolve_parts(spec, y_train)
    edges = [spec.clients.edge_profiles[i % len(spec.clients.edge_profiles)]
             for i in range(n)]
    use_pallas_link, interpret_link = resolve_link_kernel(
        spec.engine.link_kernel)
    link = FleetLink(config=spec.link_policy.config(),
                     use_pallas=use_pallas_link, interpret=interpret_link)
    scn = spec.scenario

    # ---- mission: placement, tour/timeline, round budget -----------------
    tour = None
    timeline = None
    if spec.mission is not None:
        with obs.span("compile/mission"):
            coords = client_coords(spec.mission.farm_acres, n, seed=spec.seed)
            if scn is not None:
                # scenario missions roll out in time (multi-UAV dispatch,
                # serve geometry); single-UAV hover is the verbatim
                # plan_tour plan
                timeline = rollout_mission(
                    coords, np.zeros(2), params=spec.mission.uav,
                    hover_s_per_stop=spec.mission.hover_s_per_stop,
                    comm_s_per_stop=spec.mission.comm_s_per_stop,
                    num_uavs=scn.num_uavs, serve_mode=scn.serve_mode)
                if scn.num_uavs == 1:
                    tour = timeline.routes[0].tour
            else:
                tour = plan_tour(
                    coords, np.zeros(2), params=spec.mission.uav,
                    hover_s_per_stop=spec.mission.hover_s_per_stop,
                    comm_s_per_stop=spec.mission.comm_s_per_stop)

    # ---- channel: nominal per-client rates -------------------------------
    # link constants are hoisted at the channel's *deterministic* rate; the
    # per-round stochastic draw scales them by nominal/sampled
    serve_dist = (timeline.serve_dist_m if timeline is not None
                  else np.zeros(n))
    rate_nominal = np.full(n, spec.link_policy.rate_bps)
    if scn is not None and scn.channel is not None:
        rate_nominal = np.asarray(deterministic_rate_bps(
            scn.channel, jnp.asarray(serve_dist),
            spec.link_policy.rate_bps), dtype=np.float64)

    def client_link(cid: int) -> FleetLink:
        lp = spec.link_policy
        return FleetLink(config=LinkConfig(rate_bps=float(rate_nominal[cid]),
                                           compress=lp.compress,
                                           radio_power_w=lp.radio_power_w))

    # ---- per-client constants (filled per engine below) ------------------
    t_client = np.zeros(n)
    t_server = np.zeros(n)
    link_bytes = np.zeros(n)
    link_time = np.zeros(n)
    link_energy = np.zeros(n)
    server_base_s = 0.0
    flops: dict = {}

    if spec.model.family == "transformer":
        cfg = spec.model.arch
        k = stack_cut_index(cfg.n_layers, spec.cut_policy.fraction)
        cut_of_client = [k] * n
        with obs.span("compile/params"):
            prog = lm_split_program(cfg, jax.random.PRNGKey(spec.seed), k,
                                    link_boundary=link.boundary(),
                                    attn_impl=resolve_attn_impl(
                                        spec.model.attn_impl),
                                    taps=step_tap_names)
            sample_bx = jnp.asarray(x_train[:spec.batch_size])
            sample_by = jnp.asarray(y_train[:spec.batch_size])
        with obs.span("compile/flops"):
            # FLOPs are counted on the tap-free step twin so the hoisted
            # energy/link constants — and every non-metrics record field —
            # stay bitwise identical with the metrics bus on
            fl_client, fl_server, smashed_sd = count_split_step_flops(
                dataclasses.replace(prog.step, taps=()), prog.params_c0,
                prog.params_s0, sample_bx, sample_by)
        flops[k] = (fl_client, fl_server, smashed_sd)
        for cid in range(n):
            lc = client_link(cid)
            t_client[cid] = client_step_time_s(fl_client, edges[cid])
            t_server[cid] = roofline_s(fl_server, RTX_A5000)
            link_bytes[cid] = lc.step_wire_bytes(smashed_sd)
            link_time[cid] = lc.step_time_s(smashed_sd)
            link_energy[cid] = lc.step_energy_j(smashed_sd)
        with obs.span("compile/lower"):
            engine_fns = _compile_sl_stack(spec, mesh, prog,
                                           jnp.asarray(x_test), y_test,
                                           taps=graph_taps)
        consts = (t_client, t_server, link_bytes, link_time, link_energy,
                  server_base_s)
        return Plan(spec, mesh=mesh, arrays=arrays, parts=parts, stages=None,
                    params0=(prog.params_c0, prog.params_s0), tour=tour,
                    cut_of_client=cut_of_client, flops=flops, edges=edges,
                    consts=consts, engine_fns=engine_fns, timeline=timeline,
                    serve_dist_m=serve_dist, rate_nominal=rate_nominal,
                    prof_consts=_profile_consts(spec, fl_client), obs=obs,
                    metrics=metrics, graph_taps=graph_taps)

    # ---- model + params ---------------------------------------------------
    with obs.span("compile/params"):
        stages = CNN_BUILDERS[spec.model.name](spec.model.num_classes)
        params0 = init_stages(jax.random.PRNGKey(spec.seed), stages)
        sample_x = jnp.asarray(x_train[:spec.batch_size])
        sample_y = jnp.asarray(y_train[:spec.batch_size])
        x_test_j = jnp.asarray(x_test)

    if spec.engine.kind == "fl":
        cut_of_client: list[int] = []
        with obs.span("compile/flops"):
            step_flops = count_fl_step_flops(stages, params0, sample_x,
                                             sample_y)
        flops["full"] = step_flops
        for c in range(n):
            t_client[c] = client_step_time_s(step_flops, edges[c])
        server_base_s = FL_SERVER_AGG_S
        with obs.span("compile/lower"):
            engine_fns = _compile_fl(spec, mesh, stages, params0, x_test_j,
                                     y_test, taps=graph_taps)
    else:
        # cut assignment: one fraction-derived cut, or per-client adaptive
        # cuts under the (optionally mission-derived) link deadline checked
        # against each client's nominal channel rate
        with obs.span("compile/cuts"):
            max_link_s = spec.cut_policy.max_link_s
            if max_link_s is None and spec.mission is not None:
                max_link_s = mission_max_link_s(
                    spec.mission.hover_s_per_stop,
                    spec.mission.comm_s_per_stop, spec.local_steps)
            if spec.cut_policy.mode == "adaptive":
                cut_of_client = assign_cuts_cnn(
                    stages, params0, sample_x, edges=edges,
                    links=[client_link(c).config for c in range(n)],
                    min_client_layers=spec.cut_policy.min_client_layers,
                    max_link_s=max_link_s)
            else:
                cut_of_client = [cut_index_for_fraction(
                    stages, spec.cut_policy.fraction)] * n
        # hoisted per-step constants, per distinct cut
        by_cut: dict[int, list[int]] = {}
        for cid, k in enumerate(cut_of_client):
            by_cut.setdefault(int(k), []).append(cid)
        with obs.span("compile/flops"):
            for k, ids in by_cut.items():
                cs, cp = list(stages[:k]), list(params0[:k])
                ss, sp = list(stages[k:]), list(params0[k:])
                fl_client, fl_server, smashed_sd = count_sl_step_flops(
                    cs, cp, ss, sp, sample_x, sample_y)
                flops[k] = (fl_client, fl_server, smashed_sd)
                for cid in ids:
                    lc = client_link(cid)
                    t_client[cid] = client_step_time_s(fl_client, edges[cid])
                    t_server[cid] = roofline_s(fl_server, RTX_A5000)
                    link_bytes[cid] = lc.step_wire_bytes(smashed_sd)
                    link_time[cid] = lc.step_time_s(smashed_sd)
                    link_energy[cid] = lc.step_energy_j(smashed_sd)
        with obs.span("compile/lower"):
            if spec.engine.client_axis == "scan":
                engine_fns = _compile_sl_scan(spec, stages, params0,
                                              cut_of_client[0], link,
                                              x_test_j, y_test,
                                              taps=graph_taps)
            else:
                engine_fns = _compile_sl_fleet(spec, mesh, stages, params0,
                                               cut_of_client, link, x_test_j,
                                               y_test, taps=graph_taps)

    consts = (t_client, t_server, link_bytes, link_time, link_energy,
              server_base_s)
    # one homogeneous per-step client cost exists for FL (full model) and
    # single-cut SL; heterogeneous adaptive cuts fall back to the per-slot
    # constants (only reachable with population == num_clients, where the
    # cohort is the identity and per-slot billing is exact)
    if spec.engine.kind == "fl":
        cli_fl = flops["full"]
    elif len(set(cut_of_client)) == 1:
        cli_fl = flops[cut_of_client[0]][0]
    else:
        cli_fl = None
    return Plan(spec, mesh=mesh, arrays=arrays, parts=parts, stages=stages,
                params0=params0, tour=tour, cut_of_client=cut_of_client,
                flops=flops, edges=edges, consts=consts,
                engine_fns=engine_fns, timeline=timeline,
                serve_dist_m=serve_dist, rate_nominal=rate_nominal,
                prof_consts=_profile_consts(spec, cli_fl), obs=obs,
                metrics=metrics, graph_taps=graph_taps)


# ---------------------------------------------------------------------------
# per-engine lowering: (init_state, run(state, batches, mask), eval(state),
#                       run_raw, eval_acc_raw) — the raw pair is unjitted /
#                       jittable closures the Monte-Carlo sweeps lower into
#                       one vmapped rollout (None, None for hetero fleets)
# ---------------------------------------------------------------------------

def _sl_audit(round_fn, masked: bool) -> dict:
    """The jaxpr auditor's handle onto an SL engine round: the jitted
    callable plus how the uniform run surface maps to its positional
    signature (``repro.analyze.jaxpr_audit`` consumes this)."""
    return {"jit_fn": round_fn, "donate_argnums": (0, 1, 2, 3),
            "unpack_state": True, "masked": masked}


def _mask_runner(round_fn, masked: bool, n: int, audit: dict = None,
                 with_taps: bool = False):
    """Uniform ``run(state, batches, mask)`` closure over a round builder
    that takes a trailing mask only when built mask-aware. With
    ``with_taps`` the round emits the metrics-bus tap dict after the
    losses and ``run`` returns ``(state, losses, taps)``."""
    full_mask = jnp.ones(n, jnp.float32)   # hoisted: one buffer, not per round

    def run(engine_state, batches, mask):
        if masked:
            m = full_mask if mask is None else jnp.asarray(mask)
            out = round_fn(*engine_state, batches, m)
        else:
            assert mask is None, \
                "mask fed to a mask-free engine (validated at compile)"
            out = round_fn(*engine_state, batches)
        if with_taps:
            *state, losses, taps = out
            return tuple(state), losses, taps
        *state, losses = out
        return tuple(state), losses
    if audit is not None:
        run._audit = audit
    return run


def _compile_fl(spec, mesh, stages, params0, x_test_j, y_test, taps=()):
    opt = adamw(spec.lr)

    def grad_fn(params, batch):
        bx, by = batch
        return jax.value_and_grad(
            lambda p: cross_entropy_loss(apply_stages(stages, p, bx), by))(
                params)

    masked = _needs_mask(spec)
    if spec.engine.is_fleet:
        raw_fn = make_fleet_fl_round(grad_fn, opt, mesh=mesh,
                                     client_dropout=masked,
                                     client_axis=spec.engine.client_axis,
                                     taps=taps)
    else:
        raw_fn = make_fl_round(grad_fn, opt, client_axis="scan", taps=taps)
    round_fn = jax.jit(raw_fn, donate_argnums=(0,))

    def init_state():
        return jax.tree_util.tree_map(jnp.copy, params0)

    full_mask = jnp.ones(spec.clients.num_clients, jnp.float32)

    def make_run(fn, audit=None):
        def run(engine_state, batches, mask):
            if masked:
                m = full_mask if mask is None else jnp.asarray(mask)
                return fn(engine_state, batches, m)
            assert mask is None, \
                "mask fed to a mask-free engine (validated at compile)"
            return fn(engine_state, batches)
        if audit is not None:
            run._audit = audit
        return run

    eval_logits = jax.jit(lambda p: apply_stages(stages, p, x_test_j))

    def evaluate(engine_state):
        return classification_metrics(eval_logits(engine_state), y_test,
                                      spec.model.num_classes)

    y_test_j = jnp.asarray(y_test)

    def eval_acc_raw(engine_state):
        return accuracy_from_logits(
            apply_stages(stages, engine_state, x_test_j), y_test_j)

    audit = {"jit_fn": round_fn, "donate_argnums": (0,),
             "unpack_state": False, "masked": masked}
    return (init_state, make_run(round_fn, audit=audit), evaluate,
            make_run(raw_fn), eval_acc_raw)


def _eval_prefix(client_stack, dropout: bool):
    """The global client prefix to evaluate with. Rows are identical after
    FedAvg (row 0 suffices); under dropout they may hold stale straggler
    prefixes, so the row mean stands in for the active average."""
    if dropout:
        return jax.tree_util.tree_map(
            lambda v: jnp.mean(v.astype(jnp.float32), axis=0).astype(v.dtype),
            client_stack)
    return jax.tree_util.tree_map(lambda v: v[0], client_stack)


def _split_step(stages, params0, k, link, step_taps=()):
    cs, cp = list(stages[:k]), list(params0[:k])
    ss, sp = list(stages[k:]), list(params0[k:])
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
        link_constraint=link.boundary(),
        taps=step_taps,
    )
    return cs, cp, ss, sp, step


def _compile_sl_scan(spec, stages, params0, k, link, x_test_j, y_test,
                     taps=()):
    """Sequential Algorithm 3: one shared server model updated per client
    visit (``make_multi_client_round``), homogeneous cut."""
    cs, cp0, ss, sp, step = _split_step(stages, params0, k, link,
                                        step_taps=split_step_tap_names(taps))
    opt_c, opt_s = adamw(spec.lr), adamw(spec.lr)
    n = spec.clients.num_clients
    raw_fn = make_multi_client_round(step, opt_c, opt_s,
                                     local_rounds=spec.local_steps,
                                     taps=taps)
    round_fn = jax.jit(raw_fn, donate_argnums=(0, 1, 2, 3))

    def init_state():
        state = (stack_replicas(cp0, n), sp, init_stacked(opt_c, cp0, n),
                 opt_s.init(sp))
        return jax.tree_util.tree_map(jnp.copy, state)

    eval_logits = jax.jit(
        lambda cp, sp_: apply_stages(ss, sp_, apply_stages(cs, cp, x_test_j)))

    def evaluate(engine_state):
        client_stack, sp_, _, _ = engine_state
        prefix = _eval_prefix(client_stack, dropout=False)
        return classification_metrics(eval_logits(prefix, sp_), y_test,
                                      spec.model.num_classes)

    y_test_j = jnp.asarray(y_test)

    def eval_acc_raw(engine_state):
        client_stack, sp_, _, _ = engine_state
        prefix = _eval_prefix(client_stack, dropout=False)
        return accuracy_from_logits(
            apply_stages(ss, sp_, apply_stages(cs, prefix, x_test_j)),
            y_test_j)

    return (init_state,
            _mask_runner(round_fn, False, n, audit=_sl_audit(round_fn, False),
                         with_taps=bool(taps)),
            evaluate, _mask_runner(raw_fn, False, n, with_taps=bool(taps)),
            eval_acc_raw)


def _compile_sl_fleet(spec, mesh, stages, params0, cut_of_client, link,
                      x_test_j, y_test, taps=()):
    """Parallel fleet SL (``make_fleet_sl_round``, vmap or shard_map client
    axis). Homogeneous cuts run the engine directly — one compiled round,
    no host-side bucket reassembly; heterogeneous cuts dispatch through
    ``HeteroFleet`` (one compiled round + server suffix per cut bucket).
    With a >1 ``server_mesh`` the ``launch.steps.fleet_server_pspecs`` tier
    specs shard the server suffix (params + optimizer moments) fsdp x tp
    while the client axis shards over ``data``."""
    opt_c, opt_s = adamw(spec.lr), adamw(spec.lr)
    dropout = _needs_mask(spec)
    n = spec.clients.num_clients
    pop = spec.clients.population
    # EPSL shared client tier: a sampled cohort (population > cohort) can't
    # keep per-slot client params/Adam moments — slot i maps to a different
    # population client every round — so the fleet trains ONE client model
    # broadcast across the cohort axis (state O(1) in both population and
    # cohort). The materialized corner (population in (None, num_clients))
    # keeps the stacked tier and its exact record stream.
    shared = pop is not None and pop > n
    client_axis = spec.engine.client_axis
    fsdp, tp = server_mesh_sizes(mesh)
    server_pspecs_fn = None
    if mesh is not None and fsdp * tp > 1:
        from ..launch.steps import fleet_server_pspecs
        server_pspecs_fn = fleet_server_pspecs

    if len(set(cut_of_client)) == 1:
        k = cut_of_client[0]
        cs, cp0, ss, sp, step = _split_step(
            stages, params0, k, link,
            step_taps=split_step_tap_names(taps))
        sps_specs = (server_pspecs_fn(sp, mesh)
                     if server_pspecs_fn is not None else None)
        raw_fn = make_fleet_sl_round(step, opt_c, opt_s,
                                     local_rounds=spec.local_steps, mesh=mesh,
                                     server_reduce=spec.engine.server_reduce,
                                     client_dropout=dropout,
                                     client_axis=client_axis,
                                     client_tier="shared" if shared
                                     else "stacked",
                                     server_pspecs=sps_specs, taps=taps)
        round_fn = jax.jit(raw_fn, donate_argnums=(0, 1, 2, 3))

        def init_state():
            if shared:
                state = (cp0, sp, opt_c.init(cp0), opt_s.init(sp))
            else:
                state = (stack_replicas(cp0, n), sp,
                         init_stacked(opt_c, cp0, n), opt_s.init(sp))
            state = jax.tree_util.tree_map(jnp.copy, state)
            if sps_specs is not None:
                from jax.sharding import PartitionSpec as P
                from ..optim.optimizers import OptState
                pc, ps, oc, os_ = state
                ps = shard_server_state(ps, mesh, sps_specs)
                os_ = shard_server_state(
                    os_, mesh, OptState(step=P(), mu=sps_specs,
                                        nu=sps_specs))
                state = (pc, ps, oc, os_)
            return state

        def global_prefix(client_stack):
            return (client_stack if shared
                    else _eval_prefix(client_stack, dropout))

        eval_logits = jax.jit(
            lambda cp, sp_: apply_stages(ss, sp_,
                                         apply_stages(cs, cp, x_test_j)))

        def evaluate(engine_state):
            client_stack, sp_, _, _ = engine_state
            return classification_metrics(
                eval_logits(global_prefix(client_stack), sp_), y_test,
                spec.model.num_classes)

        y_test_j = jnp.asarray(y_test)

        def eval_acc_raw(engine_state):
            client_stack, sp_, _, _ = engine_state
            prefix = global_prefix(client_stack)
            return accuracy_from_logits(
                apply_stages(ss, sp_, apply_stages(cs, prefix, x_test_j)),
                y_test_j)

        return (init_state,
                _mask_runner(round_fn, dropout, n,
                             audit=_sl_audit(round_fn, dropout),
                             with_taps=bool(taps)),
                evaluate, _mask_runner(raw_fn, dropout, n,
                                       with_taps=bool(taps)),
                eval_acc_raw)

    def build_program(k):
        return cnn_split_program(stages, params0, k,
                                 loss_fn=cross_entropy_loss,
                                 link_boundary=link.boundary(),
                                 taps=split_step_tap_names(taps))

    fleet = HeteroFleet(build_program, cut_of_client, opt_c, opt_s,
                        local_rounds=spec.local_steps, mesh=mesh,
                        client_dropout=dropout,
                        server_reduce=spec.engine.server_reduce,
                        client_axis=client_axis,
                        server_pspecs_fn=server_pspecs_fn, taps=taps)

    bucket_eval = []
    for bucket in fleet.buckets:
        k = bucket.cut_index
        cs, ss = list(stages[:k]), list(stages[k:])
        bucket_eval.append(jax.jit(
            lambda cp, sp_, cs=cs, ss=ss: apply_stages(
                ss, sp_, apply_stages(cs, cp, x_test_j))))

    def init_state():
        # per-bucket state tuples threaded EXTERNALLY through run_round_on,
        # so every PlanState owns independent fresh state (the fleet object
        # only holds the compiled engines)
        return fleet.init_states()

    def run(engine_state, batches, mask):
        return fleet.run_round_on(engine_state, batches, client_mask=mask)

    def evaluate(engine_state):
        # every bucket's model votes on the held-out set, weighted by its
        # client count
        logits = jnp.zeros((len(y_test), spec.model.num_classes), jnp.float32)
        for i, bucket in enumerate(fleet.buckets):
            client_stack, params_s, _, _ = engine_state[i]
            prefix = _eval_prefix(client_stack, dropout)
            out = bucket_eval[i](prefix, params_s)
            logits = logits + out.astype(jnp.float32) * len(bucket.client_ids)
        return classification_metrics(logits / n, y_test,
                                      spec.model.num_classes)

    # hetero rounds dispatch per bucket on the host: no single jittable
    # round exists, so Monte-Carlo vectorization is unsupported (raw=None)
    return init_state, run, evaluate, None, None


def _compile_sl_stack(spec, mesh, prog, x_test_j, y_test, taps=()):
    """Transformer-family lowering: the ``lm_split_program`` step through
    the sequential (scan) or fleet (vmap/shard_map) SL engines — same
    wiring as the CNN paths, token logits evaluated over all positions."""
    opt_c, opt_s = adamw(spec.lr), adamw(spec.lr)
    masked = _needs_mask(spec)
    n = spec.clients.num_clients
    pop = spec.clients.population
    shared = pop is not None and pop > n   # EPSL shared client tier (see
    #                                        _compile_sl_fleet)
    vocab = spec.model.arch.vocab
    if spec.engine.client_axis == "scan":
        raw_fn = make_multi_client_round(prog.step, opt_c, opt_s,
                                         local_rounds=spec.local_steps,
                                         taps=taps)
    else:
        raw_fn = make_fleet_sl_round(prog.step, opt_c, opt_s,
                                     local_rounds=spec.local_steps, mesh=mesh,
                                     server_reduce=spec.engine.server_reduce,
                                     client_dropout=masked,
                                     client_axis=spec.engine.client_axis,
                                     client_tier="shared" if shared
                                     else "stacked", taps=taps)
    round_fn = jax.jit(raw_fn, donate_argnums=(0, 1, 2, 3))

    def init_state():
        if shared:
            state = (prog.params_c0, prog.params_s0,
                     opt_c.init(prog.params_c0), opt_s.init(prog.params_s0))
        else:
            state = (stack_replicas(prog.params_c0, n), prog.params_s0,
                     init_stacked(opt_c, prog.params_c0, n),
                     opt_s.init(prog.params_s0))
        return jax.tree_util.tree_map(jnp.copy, state)

    def global_prefix(client_stack):
        return client_stack if shared else _eval_prefix(client_stack, masked)

    eval_logits = jax.jit(
        lambda cp, sp_: prog.server_logits(
            sp_, prog.step.client_fwd(cp, x_test_j)))

    def evaluate(engine_state):
        client_stack, sp_, _, _ = engine_state
        logits = eval_logits(global_prefix(client_stack), sp_)
        return classification_metrics(logits.reshape(-1, vocab),
                                      np.asarray(y_test).reshape(-1), vocab)

    y_test_flat = jnp.asarray(np.asarray(y_test).reshape(-1))

    def eval_acc_raw(engine_state):
        client_stack, sp_, _, _ = engine_state
        logits = prog.server_logits(
            sp_, prog.step.client_fwd(global_prefix(client_stack), x_test_j))
        return accuracy_from_logits(logits.reshape(-1, vocab), y_test_flat)

    return (init_state,
            _mask_runner(round_fn, masked, n,
                         audit=_sl_audit(round_fn, masked),
                         with_taps=bool(taps)),
            evaluate, _mask_runner(raw_fn, masked, n, with_taps=bool(taps)),
            eval_acc_raw)
