from .ckpt import save_checkpoint, restore_checkpoint, tree_flatten_with_paths

__all__ = ["save_checkpoint", "restore_checkpoint", "tree_flatten_with_paths"]
