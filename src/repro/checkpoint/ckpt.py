"""msgpack-based checkpointing with sharding-aware restore.

Format: a single .msgpack file containing
  {"meta": {...}, "leaves": {path: {"dtype","shape","data"}}}
bf16 is serialized via a uint16 view (msgpack has no bf16).

On restore, pass ``shardings`` (a pytree of NamedSharding or None) to
device_put each leaf directly to its target sharding — the multi-host-safe
pattern (each process would read its slice; on one host we put the whole
array with the right layout).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def tree_flatten_with_paths(tree: Any) -> dict[str, jax.Array]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _encode_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        data = arr.view(np.uint16).tobytes()
        dtype = "bfloat16"
    else:
        data = arr.tobytes()
        dtype = arr.dtype.str
    return {"dtype": dtype, "shape": list(arr.shape), "data": data}


def _decode_leaf(d: dict) -> np.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], dtype=np.uint16).reshape(shape)
        return arr.view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(shape)


def save_checkpoint(path: str, tree: Any, *, meta: Optional[dict] = None) -> None:
    flat = tree_flatten_with_paths(tree)
    payload = {"meta": meta or {}, "leaves": {k: _encode_leaf(v) for k, v in flat.items()}}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic


def restore_checkpoint(path: str, like: Any, *, shardings: Any = None) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_by_path = payload["leaves"]
    flat_like = tree_flatten_with_paths(like)
    flat_shard = tree_flatten_with_paths(shardings) if shardings is not None else {}
    out = {}
    for key, ref in flat_like.items():
        if key not in leaves_by_path:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _decode_leaf(leaves_by_path[key])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs model {ref.shape}")
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
    # rebuild the tree in `like`'s structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = [out["/".join(_path_str(p) for p in path)] for path, _ in paths]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), ordered)


def checkpoint_meta(path: str) -> dict:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    return payload["meta"]
