"""Sharding policy: logical→physical mapping + name-rule param specs.

Mesh axes: ('data','model') single-pod, ('pod','data','model') multi-pod.
Logical axes used by the models and the spec rules:

  dp    batch axis — ('pod','data') product when present
  tp    'model' — tensor/expert parallel
  fsdp  'data'  — weight sharding across the data axis (ZeRO-style)

The split-learning tier rule (DESIGN.md §3): client-tier parameters use
**no tensor parallelism** ('tp'→replicated) — the architectural signature
of split learning is that edge devices cannot shard a model; they remain
'fsdp'-sharded across the client-fleet axis in the SPMD program (the SPMD
dual of each client holding its own copy + FedAvg). Server-tier parameters
are fully 2D-sharded (fsdp × tp).

Every spec is divisibility-guarded against the actual leaf shape: a dim
that doesn't divide by its mesh axis size falls back to replicated — no
silent padding; the roofline/hillclimb log records where this costs us.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP_AXIS = "model"
FSDP_AXIS = "data"
DP_AXES = ("pod", "data")

_ACTIVE: list["ShardingPolicy"] = []


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh

    def resolve(self, logical: Sequence) -> P:
        out = []
        for ax in logical:
            if ax is None:
                out.append(None)
            elif ax == "dp":
                axes = tuple(a for a in DP_AXES if a in self.mesh.axis_names)
                out.append(axes if len(axes) > 1 else axes[0])
            elif ax == "tp":
                out.append(TP_AXIS)
            elif ax == "fsdp":
                out.append(FSDP_AXIS)
            else:
                out.append(ax)
        return P(*out)

    def constrain(self, x: jax.Array, logical: Sequence) -> jax.Array:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.resolve(logical)))


@contextlib.contextmanager
def set_policy(policy: Optional[ShardingPolicy]):
    if policy is None:
        yield
        return
    _ACTIVE.append(policy)
    try:
        yield
    finally:
        _ACTIVE.pop()


def get_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE[-1] if _ACTIVE else None


def shard_act(x: jax.Array, logical: Sequence) -> jax.Array:
    pol = get_policy()
    if pol is None:
        return x
    return pol.constrain(x, logical)


# ---------------------------------------------------------------------------
# parameter partition specs by path rules (2D: fsdp x tp)
# ---------------------------------------------------------------------------

# (regex on the /-joined path, logical spec for the *trailing* dims).
# Leading dims beyond the rule's length (layer-stack axes) get None.
_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$", ("tp", "fsdp")),
    (r"head/w$", ("fsdp", "tp")),
    # column-parallel projections (output-feature sharded)
    (r"(wq|wk|wv|wg|gate|up|in_proj)/w$", ("fsdp", "tp")),
    (r"(wq|wk|wv|wg|gate|up|in_proj)/b$", ("tp",)),
    # row-parallel projections (input-feature sharded)
    (r"(wo|down|out_proj)/w$", ("tp", "fsdp")),
    (r"(wo|down|out_proj)/b$", (None,)),
    # MoE: expert-parallel on the leading expert axis, fsdp on d_model/d_ff
    (r"w_gate$|w_up$", ("tp", "fsdp", None)),
    (r"w_down$", ("tp", "fsdp", None)),
    (r"router/w$", (None, None)),
    # mamba
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"w_dt_a$", ("tp", None)),
    (r"w_dt_b$", (None, "tp")),
    (r"dt_bias$", ("tp",)),
    (r"(w_B|w_C)/w$", ("tp", None)),
    (r"A_log$", ("tp", None)),
    (r"/D$", ("tp",)),
    # rwkv
    (r"/u$", ("tp", None)),
    (r"w_lora_a$", ("fsdp", None)),
    (r"w_lora_b$", (None, None)),
    (r"mix/(wr|wk|wv|wg)/w$", ("fsdp", "tp")),
    (r"mix/wo/w$", ("tp", "fsdp")),
    (r"ffn/wk/w$", ("fsdp", "tp")),
    (r"ffn/wv/w$", ("tp", "fsdp")),
    (r"ffn/wr/w$", ("fsdp", "tp")),
]


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def _axis_size(mesh_shape: dict, logical: str) -> int:
    if logical == "tp":
        return mesh_shape.get(TP_AXIS, 1)
    if logical == "fsdp":
        return mesh_shape.get(FSDP_AXIS, 1)
    return 1


_EXPERT_PAT = re.compile(r"w_gate$|w_up$|w_down$")


def _spec_for(path: str, shape: tuple, mesh_shape: dict, tier: str) -> P:
    for pat, rule in _RULES:
        if re.search(pat, path):
            # client_edp: expert-parallel client tier — experts sharded over
            # the client-fleet ('data') axis, one expert group per edge
            # cluster; tokens all-to-all instead of 77GB weight gathers
            # (beyond-paper §Perf lever).
            if tier == "client_edp" and _EXPERT_PAT.search(path):
                e = shape[0] if len(shape) == 3 else None
                size = mesh_shape.get(FSDP_AXIS, 1)
                if e and size > 1 and e % size == 0:
                    return P(FSDP_AXIS, None, None)
            pad = (None,) * (len(shape) - len(rule))
            full = pad + tuple(rule)
            out = []
            for dim, ax in zip(shape, full):
                if ax is None:
                    out.append(None)
                    continue
                if tier in ("client", "client_edp") and ax == "tp":
                    out.append(None)        # client tier: no tensor parallelism
                    continue
                size = _axis_size(mesh_shape, ax)
                if size > 1 and dim % size == 0:
                    out.append(TP_AXIS if ax == "tp" else FSDP_AXIS)
                else:
                    out.append(None)        # divisibility guard
            return P(*out)
    return P()


def mesh_axis_sizes(mesh) -> dict:
    """axis_name -> size; works for Mesh and AbstractMesh."""
    if hasattr(mesh, "shape"):
        try:
            return dict(mesh.shape)
        except Exception:
            pass
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def param_pspecs(params: Any, mesh, *, tier: str = "server",
                 tier_fn=None, prefix: str = "") -> Any:
    """PartitionSpec pytree for a param tree via name rules.

    ``tier_fn(path:str)->str`` overrides the uniform tier (used by the split
    model where groups/<i> have different tiers).
    """
    mesh_shape = mesh_axis_sizes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = prefix + "/".join(_path_str(p) for p in path)
        t = tier_fn(name) if tier_fn is not None else tier
        specs.append(_spec_for(name, tuple(leaf.shape), mesh_shape, t))
    return jax.tree_util.tree_unflatten(treedef, specs)
