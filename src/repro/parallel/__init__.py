from .sharding import (ShardingPolicy, set_policy, get_policy, shard_act,
                       param_pspecs, DP_AXES, TP_AXIS, FSDP_AXIS)

__all__ = ["ShardingPolicy", "set_policy", "get_policy", "shard_act",
           "param_pspecs", "DP_AXES", "TP_AXIS", "FSDP_AXIS"]
