"""``repro.sim`` — stochastic mission & channel scenarios over the engines.

``ScenarioSpec`` (channel + availability + mission shape) rides on
``repro.api.ExperimentSpec``; ``compile_experiment`` lowers it so channel
draws drive the per-round link bill and availability traces drive the
fleet dropout masks. ``run_monte_carlo`` sweeps N scenario seeds in one
jitted vmapped rollout. The deterministic corner (``degenerate_scenario``)
reproduces the idealized campaign records exactly.
"""
from .channel import (ChannelParams, deterministic_rate_bps, path_loss_db,
                      sample_rates_bps, slant_distance_m)
from .scenario import (AvailabilityParams, COHORT_DOWN_WEIGHT, ScenarioSpec,
                       availability_init, availability_step,
                       degenerate_scenario, sample_cohort)
from .mission import MissionTimeline, UavRoute, rollout_mission
from .monte_carlo import MonteCarloResult, run_monte_carlo

__all__ = [n for n in dir() if not n.startswith("_")]
