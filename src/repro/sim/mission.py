"""Time-stepped mission rollout over the Held-Karp tour.

Turns Algorithm 2's closed-form round budget into an explicit timeline:
per-round start times, per-client hover (serve) windows, per-UAV battery
state, and the return-to-base reservation — plus two generalizations the
paper's single-UAV mission idealizes away:

  * **multi-UAV dispatch** — the fleet is partitioned into ``num_uavs``
    contiguous arcs of the global exact tour; each UAV plans its own
    (exact) tour + budget over its arc, and a *fleet* round completes when
    the slowest UAV finishes (rounds = min over UAVs of their budgets).
  * **serve modes** — ``"hover"``: the UAV parks directly above each
    client (slant distance = altitude, the paper's geometry); ``"relay"``:
    the UAV parks at its partition's centroid and serves all its clients
    from there (per-client slant distances vary — the knob that makes the
    ``sim.channel`` path-loss term bite).

With ``num_uavs=1`` and ``serve_mode="hover"`` the single route is the
verbatim ``core.trajectory.plan_tour`` plan — same Held-Karp order, same
``e_first`` / ``e_per_round`` / ``rounds`` — so the degenerate scenario
bills exactly what the idealized campaign billed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.trajectory import TourPlan, budget_rounds, plan_tour, solve_tsp
from ..core.uav_energy import DEFAULT_UAV, UAVParams


@dataclasses.dataclass(frozen=True)
class UavRoute:
    """One UAV's assignment: the clients it serves and its planned tour."""
    uav: int
    client_ids: tuple[int, ...]   # global client indices, visit order
    tour: TourPlan                # over this partition (order indexes the
    #                               partition's coords, not global ids)
    hover_xy: np.ndarray          # (stops, 2) serve waypoints, visit order
    serve_dist_m: np.ndarray      # (len(client_ids),) slant distance per
    #                               client, aligned with client_ids
    round_duration_s: float       # steady-state seconds per round


@dataclasses.dataclass(frozen=True)
class MissionTimeline:
    """The rolled-out mission: fleet-synchronized rounds + battery traces."""
    routes: tuple[UavRoute, ...]
    rounds: int                   # fleet rounds (min over UAVs; Alg. 2 budget)
    e_first_j: float              # summed over UAVs: base->first + round 0
    e_per_round_j: float          # summed over UAVs
    e_return_j: float             # summed return legs (reserved, billed once)
    battery_j: np.ndarray         # (num_uavs, rounds+1) energy remaining
    round_start_s: np.ndarray     # (rounds,) fleet-synchronized start times
    round_duration_s: float       # max over UAVs (the fleet waits)
    serve_dist_m: np.ndarray      # (num_clients,) slant distances, global ids
    hover_start_s: np.ndarray     # (num_clients,) serve-window offset within
    #                               a steady-state round

    @property
    def num_uavs(self) -> int:
        return len(self.routes)

    def uav_energy_j(self, round_index: int) -> float:
        """The fleet's tour energy billed to one round (round 0 carries the
        base->first legs) — the same split the idealized campaign bills."""
        return self.e_first_j if round_index == 0 else self.e_per_round_j


def _partition_by_tour(coords: np.ndarray, num_uavs: int,
                       exact_limit: int) -> list[np.ndarray]:
    """Contiguous arcs of the global tour, one per UAV (near-equal sizes).
    Single-UAV keeps the identity order so the route's own exact solve is
    byte-identical to ``plan_tour`` over the full fleet."""
    n = len(coords)
    if num_uavs == 1:
        return [np.arange(n)]
    if num_uavs > n:
        raise ValueError(f"{num_uavs} UAVs for {n} clients")
    order, _ = solve_tsp(coords, exact_limit=exact_limit)
    return [np.asarray(chunk)
            for chunk in np.array_split(np.asarray(order), num_uavs)]


def _relay_tour(centroid: np.ndarray, base: np.ndarray, num_stops: int,
                params: UAVParams, hover_s: float, comm_s: float) -> TourPlan:
    """A degenerate one-waypoint tour: park at the centroid, dwell one
    hover+comm window per served client, return at mission end."""
    leg = float(np.linalg.norm(centroid - base))
    e_pi = num_stops * (hover_s * params.xi_h + comm_s * params.xi_c)
    e_first = (leg / params.V) * params.xi_m() + e_pi
    e_return = (leg / params.V) * params.xi_m()
    rounds, total = budget_rounds(params.beta, e_first, e_pi, e_return)
    return TourPlan(order=[0], tour_length=0.0, rounds=rounds,
                    e_per_round=e_pi, e_first=e_first, e_return=e_return,
                    total_energy=total)


def _leg_lengths(waypoints: np.ndarray, order: list[int]) -> np.ndarray:
    """Cycle leg lengths in visit order: leg[i] = dist(order[i-1], order[i])
    (leg[0] closes the cycle from the last stop)."""
    pts = waypoints[np.asarray(order)]
    return np.linalg.norm(pts - np.roll(pts, 1, axis=0), axis=-1)


def rollout_mission(coords: np.ndarray, base: np.ndarray, *,
                    params: UAVParams = DEFAULT_UAV,
                    hover_s_per_stop: float = 30.0,
                    comm_s_per_stop: float = 10.0,
                    num_uavs: int = 1, serve_mode: str = "hover",
                    exact_limit: int = 16) -> MissionTimeline:
    """Roll one mission out in time. ``coords`` are the (n, 2) client ground
    positions, ``base`` the charging station. Returns the fleet timeline."""
    if serve_mode not in ("hover", "relay"):
        raise ValueError(f"serve_mode must be 'hover' or 'relay', "
                         f"got {serve_mode!r}")
    n = len(coords)
    parts = _partition_by_tour(coords, num_uavs, exact_limit)
    alt = params.altitude
    routes: list[UavRoute] = []
    serve_dist = np.zeros(n)
    hover_start = np.zeros(n)
    for u, ids in enumerate(parts):
        sub = coords[ids]
        m = len(ids)
        if serve_mode == "hover":
            tour = plan_tour(sub, base, params=params,
                             hover_s_per_stop=hover_s_per_stop,
                             comm_s_per_stop=comm_s_per_stop,
                             exact_limit=exact_limit)
            visit = ids[np.asarray(tour.order)]
            hover_xy = sub[np.asarray(tour.order)]
            dist = np.full(m, alt)          # overhead: slant = altitude
            legs = _leg_lengths(sub, tour.order)
        else:  # relay
            centroid = sub.mean(axis=0)
            tour = _relay_tour(centroid, base, m, params,
                               hover_s_per_stop, comm_s_per_stop)
            visit = ids
            hover_xy = np.broadcast_to(centroid, (1, 2)).copy()
            ground = np.linalg.norm(sub - centroid, axis=-1)
            dist = np.sqrt(ground ** 2 + alt ** 2)
            legs = np.zeros(m)              # the UAV stays parked
        # steady-state serve-window offsets: travel leg into each stop,
        # then its hover+comm dwell
        t = 0.0
        dwell = hover_s_per_stop + comm_s_per_stop
        for j, cid in enumerate(visit):
            t += legs[j] / params.V if serve_mode == "hover" else 0.0
            hover_start[cid] = t
            t += dwell
        duration = float(tour.tour_length / params.V + m * dwell)
        serve_dist[ids] = dist
        routes.append(UavRoute(uav=u, client_ids=tuple(int(c) for c in visit),
                               tour=tour, hover_xy=hover_xy,
                               serve_dist_m=dist,
                               round_duration_s=duration))

    rounds = min(r.tour.rounds for r in routes)
    e_first = float(sum(r.tour.e_first for r in routes))
    e_per_round = float(sum(r.tour.e_per_round for r in routes))
    e_return = float(sum(r.tour.e_return for r in routes))
    duration = max(r.round_duration_s for r in routes)
    battery = np.zeros((len(routes), rounds + 1))
    for u, r in enumerate(routes):
        battery[u, 0] = params.beta
        for k in range(rounds):
            battery[u, k + 1] = params.beta - r.tour.e_first \
                - k * r.tour.e_per_round
    first_leg_s = max(
        (r.tour.e_first - r.tour.e_per_round) / params.xi_m() for r in routes)
    round_start = first_leg_s + duration * np.arange(max(rounds, 0))
    return MissionTimeline(
        routes=tuple(routes), rounds=rounds, e_first_j=e_first,
        e_per_round_j=e_per_round, e_return_j=e_return, battery_j=battery,
        round_start_s=round_start, round_duration_s=duration,
        serve_dist_m=serve_dist, hover_start_s=hover_start)
