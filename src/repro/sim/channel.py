"""Stochastic air-to-ground channel — per-client per-round achievable rate.

Replaces the constant ``LinkPolicy.rate_bps`` idealization with the standard
UAV-relay link budget (Ninkovic et al., 2024 observe A2G rates vary strongly
with UAV position and fading):

    PL(d)  = PL_0 + 10 * alpha * log10(d / 1 m)          log-distance path loss
    X_sh   ~ N(0, sigma_sh^2)  [dB]                      log-normal shadowing
    |h|^2  ~ Exp(1)                                      Rayleigh fast fading
    SNR    = P_tx * 10^(-(PL + X_sh)/10) * |h|^2 / N_0
    R      = B * log2(1 + SNR)                           Shannon rate [bit/s]

with ``d`` the 3D slant distance between the UAV's serving waypoint and the
edge device. Everything is jax-native and shape-polymorphic: rates broadcast
over a (clients,) distance vector, fold a PRNG key per round, and ``vmap``
over Monte-Carlo seeds (``repro.sim.monte_carlo``).

Two kinds:

  * ``"a2g"``      — the model above. With ``shadowing_sigma_db=0`` and
                     ``fading='none'`` it is fully deterministic (distance-
                     dependent only) — the degenerate corner the equivalence
                     tests pin.
  * ``"constant"`` — every draw returns the nominal link-policy rate. This is
                     today's idealization expressed inside the new subsystem,
                     so existing campaign numbers are a special case.

The energy accounting consumes rates as a *ratio*: per-round link time/energy
= (hoisted per-step constant at the nominal rate) x (nominal / sampled rate).
In the deterministic corner the ratio is exactly 1.0, so the legacy bill is
reproduced bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """A2G link-budget parameters (defaults: 2.4 GHz-ish rural low-altitude)."""
    kind: str = "a2g"              # "a2g" | "constant"
    ref_loss_db: float = 40.0      # PL_0 at d0 = 1 m
    path_loss_exp: float = 2.2     # alpha (LoS-dominated air-to-ground)
    shadowing_sigma_db: float = 4.0
    fading: str = "rayleigh"       # "none" | "rayleigh"
    tx_power_dbm: float = 20.0
    noise_dbm: float = -96.0       # noise floor over `bandwidth_hz`
    bandwidth_hz: float = 20e6
    min_rate_bps: float = 1e4      # floor: a deep fade stalls, never divides by 0

    @property
    def is_stochastic(self) -> bool:
        return self.kind == "a2g" and (self.shadowing_sigma_db > 0.0
                                       or self.fading != "none")

    def validate(self) -> None:
        if self.kind not in ("a2g", "constant"):
            raise ValueError(f"channel kind must be 'a2g' or 'constant', "
                             f"got {self.kind!r}")
        if self.fading not in ("none", "rayleigh"):
            raise ValueError(f"fading must be 'none' or 'rayleigh', "
                             f"got {self.fading!r}")
        if self.shadowing_sigma_db < 0:
            raise ValueError("shadowing_sigma_db must be >= 0")


def slant_distance_m(ground_m, altitude_m):
    """3D UAV<->device distance from ground offset + flight altitude."""
    return jnp.sqrt(jnp.square(ground_m) + altitude_m ** 2)


def path_loss_db(params: ChannelParams, dist_m):
    d = jnp.maximum(jnp.asarray(dist_m, jnp.float32), 1.0)
    return params.ref_loss_db + 10.0 * params.path_loss_exp * jnp.log10(d)


def _shannon_rate_bps(params: ChannelParams, snr_db, fade_power):
    snr = jnp.power(10.0, snr_db / 10.0) * fade_power
    rate = params.bandwidth_hz * jnp.log2(1.0 + snr)
    return jnp.maximum(rate, params.min_rate_bps)


def deterministic_rate_bps(params: ChannelParams, dist_m,
                           nominal_rate_bps: float):
    """The channel's deterministic component: shadowing/fading stripped.

    ``"constant"`` channels return the nominal (link-policy) rate everywhere;
    ``"a2g"`` returns the pure log-distance Shannon rate — strictly
    decreasing in distance. This is the rate the compile-time link constants
    (and adaptive-cut deadlines) are hoisted at.
    """
    dist_m = jnp.asarray(dist_m, jnp.float32)
    if params.kind == "constant":
        return jnp.full(dist_m.shape, nominal_rate_bps, jnp.float32)
    snr_db = params.tx_power_dbm - path_loss_db(params, dist_m) \
        - params.noise_dbm
    return _shannon_rate_bps(params, snr_db, 1.0)


def sample_rates_bps(key, params: ChannelParams, dist_m,
                     nominal_rate_bps: float):
    """One draw of per-client achievable rates (same shape as ``dist_m``).

    Deterministic channels (``"constant"``, or ``"a2g"`` with zero shadowing
    and no fading) bypass the RNG entirely and return the deterministic rate
    bit-for-bit — the degenerate-equivalence contract.
    """
    if not params.is_stochastic:
        return deterministic_rate_bps(params, dist_m, nominal_rate_bps)
    dist_m = jnp.asarray(dist_m, jnp.float32)
    k_sh, k_fd = jax.random.split(key)
    snr_db = params.tx_power_dbm - path_loss_db(params, dist_m) \
        - params.noise_dbm
    if params.shadowing_sigma_db > 0.0:
        snr_db = snr_db - params.shadowing_sigma_db * jax.random.normal(
            k_sh, dist_m.shape)
    fade = (jax.random.exponential(k_fd, dist_m.shape)
            if params.fading == "rayleigh" else 1.0)
    return _shannon_rate_bps(params, snr_db, fade)
