"""Scenario specs — the stochastic mission environment as data.

A ``ScenarioSpec`` names everything the idealized campaign held constant:
the air-to-ground channel (``ChannelParams``), the per-round client
availability process (``AvailabilityParams``), and the mission shape
(how many UAVs, where they serve from). It rides on ``ExperimentSpec``
as an optional field; ``api.plan.compile_experiment`` lowers it so

  * channel-derived rates drive the per-round link bill (and, under
    adaptive cuts, the per-client rates the hover-window deadline is
    checked against), and
  * availability traces drive the fleet engines' existing dropout masks.

The *degenerate* scenario — constant channel, full availability, one UAV
hovering overhead — reproduces today's ``campaign_spec`` records exactly
(``degenerate_scenario()``; pinned by ``tests/test_sim.py``), so the paper
numbers are a special case of this subsystem, not a separate code path.

Availability kinds (P3SL shows availability traces change which cuts and
schedules win — this is the knob that generates those traces):

  * ``"full"``      — every client, every round (degenerate).
  * ``"bernoulli"`` — i.i.d. per-round drop with prob ``p_drop`` (the
                      idealization ``ClientSpec.dropout_rate`` already
                      offers, expressed as a scenario).
  * ``"markov"``    — a two-state Gilbert-Elliott process per client:
                      an *up* client fails with ``p_drop``, a *down* one
                      recovers with ``p_recover`` — bursty outages, the
                      realistic farm-radio failure mode.

All trace generation is jax-native (key-folded per round) so the compiled
plan's host loop and the vmapped Monte-Carlo rollout draw bit-identical
masks from the same seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .channel import ChannelParams


@dataclasses.dataclass(frozen=True)
class AvailabilityParams:
    kind: str = "full"        # "full" | "bernoulli" | "markov"
    p_drop: float = 0.0       # bernoulli: P(drop); markov: P(up -> down)
    p_recover: float = 0.5    # markov: P(down -> up)

    @property
    def is_stochastic(self) -> bool:
        return self.kind != "full"

    def validate(self) -> None:
        if self.kind not in ("full", "bernoulli", "markov"):
            raise ValueError(f"availability kind must be 'full', 'bernoulli' "
                             f"or 'markov', got {self.kind!r}")
        if not (0.0 <= self.p_drop <= 1.0 and 0.0 <= self.p_recover <= 1.0):
            raise ValueError("availability probabilities must be in [0, 1]")


def availability_init(num_clients: int):
    """Round-0 prior state: every client up."""
    return jnp.ones((num_clients,), jnp.float32)


def availability_step(key, up_prev, params: AvailabilityParams):
    """One round of the availability process: ``(mask, new_state)``.

    ``up_prev`` is the previous round's (clients,) 0/1 state (ignored for
    memoryless kinds). At least one client is always kept up — a fleet
    round with zero active clients is a no-op the engines support but a
    campaign would never schedule (the UAV skips a dead round).
    """
    if not params.is_stochastic:
        ones = jnp.ones_like(up_prev)
        return ones, ones
    u = jax.random.uniform(key, up_prev.shape)
    if params.kind == "bernoulli":
        up = (u >= params.p_drop).astype(jnp.float32)
    else:  # markov (Gilbert-Elliott)
        up = jnp.where(up_prev > 0, u >= params.p_drop,
                       u < params.p_recover).astype(jnp.float32)
    # keep >=1 active: the client with the luckiest draw stands in
    guard = (jnp.arange(up.shape[0]) == jnp.argmax(u)).astype(jnp.float32)
    up = jnp.where(up.sum() > 0, up, guard)
    return up, up


# relative sampling weight of a client whose availability state is DOWN at
# cohort-draw time: bursty (markov) farms get sampled ~20x less while in
# their bad state, but are never excluded — they re-enter the pool as soon
# as they recover (and with a little probability before, so the estimator
# keeps coverage of the whole population)
COHORT_DOWN_WEIGHT = 0.05


def sample_cohort(key, population: int, cohort: int, weights=None):
    """Draw ``cohort`` distinct participant ids from ``population``, sorted.

    Gumbel top-k: ``argtop_k(log w + Gumbel)`` is an exact sample without
    replacement from the normalized ``weights`` (uniform when None) — one
    fused jax-native draw, no rejection loop, so the compiled plan's host
    loop and the vmapped Monte-Carlo rollout replay the identical cohort
    stream from the same folded key (PR 5 discipline; the cohort key is
    ``keys.fold(keys.round_env_key(env_key, round), keys.ENV_COHORT)`` —
    mask is ``keys.ENV_MASK``, rates ``keys.ENV_RATES``; the slot registry
    in ``repro/keys.py`` keeps the stream layout collision-free).

    Ids return SORTED, so ``cohort == population`` is the identity draw
    ``[0..M)`` regardless of key or weights — the degenerate corner's
    cohort stream is today's client ordering, bit for bit.
    """
    if not (1 <= cohort <= population):
        raise ValueError(f"cohort size {cohort} must be in [1, {population}]")
    u = jax.random.uniform(key, (population,), minval=1e-12, maxval=1.0)
    gumbel = -jnp.log(-jnp.log(u))
    if weights is not None:
        gumbel = gumbel + jnp.log(jnp.maximum(
            jnp.asarray(weights, jnp.float32), 1e-12))
    _, ids = jax.lax.top_k(gumbel, cohort)
    return jnp.sort(ids)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """The stochastic environment of one experiment.

    ``channel=None`` / ``availability=None`` mean "keep today's
    idealization" (constant link-policy rate / no availability process) —
    a bare ``ScenarioSpec()`` changes nothing but routes the mission
    through ``sim.mission.rollout_mission``.
    """
    channel: Optional[ChannelParams] = None
    availability: Optional[AvailabilityParams] = None
    num_uavs: int = 1
    serve_mode: str = "hover"   # "hover" (overhead) | "relay" (partition centroid)
    seed: int = 0               # channel + availability stream seed

    @property
    def needs_mask(self) -> bool:
        return self.availability is not None and self.availability.is_stochastic

    def validate(self, *, has_mission: bool) -> None:
        if self.num_uavs < 1:
            raise ValueError(f"num_uavs must be >= 1, got {self.num_uavs}")
        if self.serve_mode not in ("hover", "relay"):
            raise ValueError(f"serve_mode must be 'hover' or 'relay', "
                             f"got {self.serve_mode!r}")
        if self.channel is not None:
            self.channel.validate()
            if self.channel.kind == "a2g" and not has_mission:
                raise ValueError("an 'a2g' channel needs the mission geometry "
                                 "(client placements + UAV altitude); attach "
                                 "a MissionSpec or use kind='constant'")
        if self.availability is not None:
            self.availability.validate()
        if (self.num_uavs > 1 or self.serve_mode != "hover") \
                and not has_mission:
            raise ValueError("multi-UAV / relay scenarios describe a mission; "
                             "attach a MissionSpec")


def degenerate_scenario() -> ScenarioSpec:
    """The deterministic corner: constant channel, full availability, one
    UAV hovering overhead. Runs the whole sim path, reproduces the
    idealized campaign records (pinned by ``tests/test_sim.py``)."""
    return ScenarioSpec(channel=ChannelParams(kind="constant"),
                        availability=AvailabilityParams(kind="full"),
                        num_uavs=1, serve_mode="hover")
