"""Vectorized Monte-Carlo campaign sweeps: one jitted ``vmap`` over seeds.

A compiled ``Plan`` runs ONE realization of its scenario (one channel /
availability stream). Campaign questions are distributional — "what is the
spread of mission energy and final loss over fading and outage draws?" —
and answering them with a Python loop over campaigns pays per-(seed, round)
dispatch overhead exactly like the pre-fleet host loops paid per-step.

``run_monte_carlo(plan, num_seeds)`` instead lowers the whole sweep to one
XLA program: a per-seed rollout (``lax.scan`` over rounds — engine round,
availability mask, channel-rate draw, energy/link bill) ``vmap``-ed over
the seed axis and jitted once. ``mode="loop"`` keeps the per-round Python
dispatch as the measured baseline (``benchmarks/bench_engine_perf.py``
logs the ratio; the acceptance gate is >= 3x at 16 seeds on XLA:CPU).

Per-seed outputs are the numeric ``RoundRecord`` fields stacked as
(seeds, rounds) arrays, plus ONE held-out accuracy per seed: the rollout
ends with the plan's jittable accuracy kernel (``accuracy_from_logits``)
on the final engine state, vmapped with the sweep — so ``summary()``
reports the across-seed accuracy spread without paying per-round eval.
Intermediate rounds keep ``accuracy=NaN`` (a per-round eval would dominate
the rollout; evaluate the seeds you care about with the plan).

Population plans (``ClientSpec.population``) sample their per-round cohort
INSIDE the rollout with the same key-folding discipline as the plan
(``keys.ENV_COHORT`` of the per-round key; mask is ``keys.ENV_MASK``,
channel rates ``keys.ENV_RATES`` — see the ``repro.keys`` registry), so a
sweep's cohort stream is bit-identical to a plan compiled at that
realization seed; batches/masks/billing constants are gathered from the
population pools by the traced cohort ids.

Supported plans: any single-engine plan (fl/sl x scan/vmap/shard_map,
homogeneous cut). Hetero-bucketed plans dispatch per bucket on the host
and have no single jittable round — ``run_monte_carlo`` raises.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import keys
from ..obs.timeline import fenced
from .channel import sample_rates_bps
from .scenario import (COHORT_DOWN_WEIGHT, AvailabilityParams, ScenarioSpec,
                       availability_init, availability_step, sample_cohort)

_STATS = ("mean", "std", "min", "max", "p10", "p90")


def _stats(v: np.ndarray) -> dict:
    return {"mean": float(v.mean()), "std": float(v.std()),
            "min": float(v.min()), "max": float(v.max()),
            "p10": float(np.percentile(v, 10)),
            "p90": float(np.percentile(v, 90))}


@dataclasses.dataclass
class MonteCarloResult:
    """Per-seed (seeds, rounds) stacks of the RoundRecord numeric fields."""
    stacks: dict
    num_seeds: int
    rounds: int
    engine: str
    mode: str                   # "vmap" | "loop"
    wall_s: float               # rollout wall time (post-compile)
    # metrics-bus settings of the swept plan (None when the plan was
    # compiled without MetricsConfig): records_for_seed re-runs the SAME
    # numpy reduction the plan's _assemble_record ran, on the per-seed
    # stacks, so seed 0 reproduces plan.run()'s metric stream
    metrics_config: object = None
    kind: str = "sl"
    num_clients: int = 0

    def _round_metrics(self, i: int, r: int) -> dict:
        if self.metrics_config is None:
            return {}
        from ..obs.metrics import summarize_round_metrics
        s = self.stacks
        taps = {k.split("/", 1)[1]: s[k][i, r]
                for k in s if k.startswith("metrics/")}
        return summarize_round_metrics(
            self.metrics_config, taps,
            losses=s["loss_stack"][i, r] if "loss_stack" in s
            else np.zeros(0, np.float32),
            kind=self.kind, n=self.num_clients,
            active=int(s["active_clients"][i, r]))

    def records_for_seed(self, i: int) -> list:
        from ..api.records import RoundRecord
        s = self.stacks
        return [RoundRecord(
            round=r, loss=float(s["loss"][i, r]),
            # one held-out eval per seed: the final round carries it,
            # intermediate rounds stay NaN (see module docstring)
            accuracy=(float(s["final_accuracy"][i])
                      if r == self.rounds - 1 and "final_accuracy" in s
                      else float("nan")),
            link_bytes=float(s["link_bytes"][i, r]),
            link_time_s=float(s["link_time_s"][i, r]),
            link_energy_j=float(s["link_energy_j"][i, r]),
            client_time_s=float(s["client_time_s"][i, r]),
            client_energy_j=float(s["client_energy_j"][i, r]),
            server_time_s=float(s["server_time_s"][i, r]),
            server_energy_j=float(s["server_energy_j"][i, r]),
            uav_energy_j=float(s["uav_energy_j"][i, r]),
            active_clients=int(s["active_clients"][i, r]),
            engine=self.engine,
            cohort_pids=(tuple(int(p) for p in s["cohort"][i, r])
                         if "cohort" in s else ()),
            metrics=self._round_metrics(i, r)) for r in range(self.rounds)]

    def summary(self) -> dict:
        """Across-seed statistics of campaign totals + the final-round loss."""
        s = self.stacks
        total_energy = (s["client_energy_j"] + s["server_energy_j"]
                        + s["link_energy_j"] + s["uav_energy_j"]).sum(axis=1)
        return {
            "num_seeds": self.num_seeds, "rounds": self.rounds,
            "mode": self.mode, "engine": self.engine,
            "final_loss": _stats(s["loss"][:, -1]),
            "final_accuracy": (_stats(s["final_accuracy"])
                               if "final_accuracy" in s else None),
            "mean_active_clients": _stats(s["active_clients"].mean(axis=1)),
            "total_link_bytes": _stats(s["link_bytes"].sum(axis=1)),
            "total_link_time_s": _stats(s["link_time_s"].sum(axis=1)),
            "total_link_energy_j": _stats(s["link_energy_j"].sum(axis=1)),
            "total_client_energy_j": _stats(s["client_energy_j"].sum(axis=1)),
            "total_energy_j": _stats(total_energy),
            # across-seed spread of each in-graph tap channel: per-seed mean
            # over the sweep's (rounds, steps, clients) tap stack -> _stats
            "metrics": {k.split("/", 1)[1]:
                        _stats(s[k].reshape(s[k].shape[0], -1).mean(axis=1))
                        for k in sorted(s) if k.startswith("metrics/")} or None,
        }


def _mc_context(plan):
    """Hoisted per-client constants + scenario knobs, as jnp arrays."""
    if getattr(plan, "_run_raw", None) is None:
        raise ValueError("Monte-Carlo rollouts need a single compiled engine "
                         "round; hetero-bucketed plans dispatch per bucket "
                         "on the host (run those seeds with plan.run())")
    spec = plan.spec
    scn = spec.scenario or ScenarioSpec()
    n = spec.clients.num_clients
    from ..core.energy import RTX_A5000
    ctx = {
        "n": n, "steps": spec.local_steps, "kind": spec.engine.kind,
        "needs_mask": plan._mask_in_engine,
        # metrics-bus taps (repro.obs.metrics): when the plan compiled with
        # a MetricsConfig its raw round emits (state, losses, taps) and the
        # rollout stacks each tap channel as a "metrics/<name>" output
        "taps": tuple(getattr(plan, "graph_taps", ())),
        "metrics": getattr(plan, "metrics_config", None),
        # a plain ClientSpec.dropout_rate is the i.i.d. special case of an
        # availability trace — honor it per seed as one
        "avail": (scn.availability if scn.needs_mask
                  else AvailabilityParams(kind="bernoulli",
                                          p_drop=spec.clients.dropout_rate)
                  if spec.clients.dropout_rate > 0
                  else AvailabilityParams(kind="full")),
        "chan": scn.channel,
        "dist": jnp.asarray(plan.serve_dist_m, jnp.float32),
        "rate_nom": jnp.asarray(plan.rate_nominal, jnp.float32),
        "t_client": jnp.asarray(plan._t_client, jnp.float32),
        "t_server": jnp.asarray(plan._t_server, jnp.float32),
        "l_bytes": jnp.asarray(plan._link_bytes, jnp.float32),
        "l_time": jnp.asarray(plan._link_time, jnp.float32),
        "l_energy": jnp.asarray(plan._link_energy, jnp.float32),
        "p_edge": jnp.asarray([e.power_w for e in plan.edges], jnp.float32),
        "server_base_s": float(plan._server_base_s),
        "p_server": RTX_A5000.power_w,
        "rate_bps": spec.link_policy.rate_bps,
        # population cohort sampling: the availability trace runs over the
        # POPULATION (n_avail ids); each round draws a cohort of n slots
        # (fold 3) weighted by the up/down state entering the round when a
        # scenario trace is attached, gathers batch pool rows (pid %
        # n_parts) and per-profile billing constants (pid % n_profiles)
        "pop": spec.clients.population,
        "n_avail": (spec.clients.population
                    if spec.clients.population is not None else n),
        "n_parts": len(plan.parts),
        "weighted": (spec.clients.population is not None and scn.needs_mask),
        "t_client_prof": (None if plan._t_client_prof is None
                          else jnp.asarray(plan._t_client_prof, jnp.float32)),
        "p_edge_prof": (None if plan._p_edge_prof is None
                        else jnp.asarray(plan._p_edge_prof, jnp.float32)),
    }
    return ctx, scn


def _round_outputs(ctx, kr, state, up, batch, run):
    """One round: cohort draw -> availability mask -> engine round ->
    channel bill. Key folds match the plan's (the ``repro.keys`` env
    slots: ENV_MASK, ENV_RATES, ENV_COHORT)."""
    if ctx["pop"] is not None:
        # cohort weights use the availability state ENTERING the round
        # (the plan draws its cohort before stepping the trace)
        w = (up + (1.0 - up) * COHORT_DOWN_WEIGHT if ctx["weighted"]
             else None)
        cohort = sample_cohort(keys.fold(kr, keys.ENV_COHORT), ctx["pop"],
                               ctx["n"], weights=w)
    else:
        cohort = None
    mask, up = availability_step(keys.fold(kr, keys.ENV_MASK), up,
                                 ctx["avail"])
    if cohort is not None:
        # population trace -> cohort slots; availability_step's >=1-active
        # guard holds for the population, not the slice, so an all-down
        # cohort keeps slot 0 (same rule as Plan._round_mask)
        mask = mask[cohort]
        mask = jnp.where(mask.sum() > 0, mask,
                         jnp.zeros(ctx["n"], mask.dtype).at[0].set(1))
        batch = jax.tree_util.tree_map(
            lambda x: x[cohort % ctx["n_parts"]], batch)
    if ctx["taps"]:
        state, losses, taps = run(state, batch,
                                  mask if ctx["needs_mask"] else None)
    else:
        state, losses = run(state, batch, mask if ctx["needs_mask"] else None)
        taps = None
    steps = ctx["steps"]
    active = jnp.maximum(mask.sum(), 1.0)
    w = mask[:, None] if ctx["kind"] == "fl" else mask[None, :]
    loss = (losses * w).sum() / (active * steps)
    if ctx["chan"] is not None:
        rates = sample_rates_bps(keys.fold(kr, keys.ENV_RATES), ctx["chan"],
                                 ctx["dist"], ctx["rate_bps"])
        ratio = ctx["rate_nom"] / rates
    else:
        ratio = jnp.ones_like(ctx["l_time"])
    # compute billing prices the SAMPLED cohort's hardware profiles;
    # link/server constants stay per-slot (serve geometry is a slot
    # property — the UAV visits n stops regardless of who is sampled)
    if cohort is not None and ctx["t_client_prof"] is not None:
        prof = cohort % ctx["t_client_prof"].shape[0]
        t_client, p_edge = ctx["t_client_prof"][prof], ctx["p_edge_prof"][prof]
    else:
        t_client, p_edge = ctx["t_client"], ctx["p_edge"]
    t_srv = (ctx["t_server"] * mask).sum() * steps + ctx["server_base_s"]
    out = {
        "loss": loss, "active_clients": mask.sum(),
        "link_bytes": (ctx["l_bytes"] * mask).sum() * steps,
        "link_time_s": (ctx["l_time"] * ratio * mask).sum() * steps,
        "link_energy_j": (ctx["l_energy"] * ratio * mask).sum() * steps,
        "client_time_s": (t_client * mask).sum() * steps,
        "client_energy_j": (t_client * p_edge * mask).sum() * steps,
        "server_time_s": t_srv, "server_energy_j": t_srv * ctx["p_server"],
    }
    if cohort is not None:
        out["cohort"] = cohort
    if ctx["metrics"] is not None:
        # raw per-(step, client) loss stack: records_for_seed reduces it to
        # loss_spread with the same numpy path as the plan's round records
        out["loss_stack"] = losses
    if taps is not None:
        for name, v in taps.items():
            out[f"metrics/{name}"] = v
    return state, up, out


def _stacked_batches(plan, rounds: int):
    """``rounds`` draws of the plan's own batch stream, stacked on a leading
    round axis (shared across seeds: MC varies the environment, not data)."""
    st = plan.init()
    per_round = [plan.round_batches(st) for _ in range(rounds)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_round)


def _uav_rounds(plan, rounds: int) -> np.ndarray:
    if plan.timeline is not None:
        return np.asarray([plan.timeline.uav_energy_j(r)
                           for r in range(rounds)])
    if plan.tour is not None:
        return np.asarray([plan.tour.e_first if r == 0
                           else plan.tour.e_per_round for r in range(rounds)])
    return np.zeros(rounds)


def build_vmap_rollout(plan, num_seeds: int, *, rounds: Optional[int] = None,
                       seed: int = 0):
    """The sweep's vmapped rollout as a jittable closure plus its example
    arguments: ``(mc_fn, (seed_keys, state0, batches_all))``.

    ``run_monte_carlo(mode="vmap")`` jits and executes exactly this
    callable; ``repro.analyze.audit_mc`` traces it statically — one
    builder, so the audited program IS the executed program.
    """
    ctx, scn = _mc_context(plan)
    rounds = plan.num_rounds if rounds is None else rounds
    if rounds < 1:
        raise ValueError("need at least one round")
    run = plan._run_raw
    eval_acc = plan._eval_acc_raw
    batches_all = _stacked_batches(plan, rounds)
    state0 = plan.init().engine_state
    seed_keys = jnp.stack([jax.random.PRNGKey(scn.seed + seed + i)
                           for i in range(num_seeds)])
    up0 = availability_init(ctx["n_avail"])

    def rollout(key, state0, batches_all):
        def body(carry, xs):
            state, up = carry
            r, batch = xs
            state, up, out = _round_outputs(
                ctx, keys.round_env_key(key, r), state, up, batch, run)
            return (state, up), out
        (state, _), outs = jax.lax.scan(body, (state0, up0),
                                        (jnp.arange(rounds), batches_all))
        # one held-out accuracy per seed, fused into the same program
        return outs, eval_acc(state)

    mc_fn = jax.vmap(rollout, in_axes=(0, None, None))
    return mc_fn, (seed_keys, state0, batches_all)


def run_monte_carlo(plan, num_seeds: int, *, rounds: Optional[int] = None,
                    mode: str = "vmap", seed: int = 0,
                    obs=None) -> MonteCarloResult:
    """Sweep ``num_seeds`` scenario realizations of ``plan``.

    ``mode="vmap"`` (default): ONE jitted program — ``lax.scan`` over
    rounds, ``vmap`` over seeds. ``mode="loop"``: the same per-round step
    jitted once but dispatched from Python per (seed, round) — the
    idealized-campaign execution model, kept as the measured baseline.
    Both modes consume identical per-seed keys, so their per-seed outputs
    agree.

    Sweep seed ``i`` IS the scenario realization ``ScenarioSpec.seed +
    seed + i``: its per-round mask/rate streams are bit-identical to a
    plan compiled with that scenario seed — in particular, seed 0 of a
    ``seed=0`` sweep replays the plan's own ``run()`` realization
    (pinned by ``tests/test_sim.py``).

    Telemetry: the sweep inherits ``plan.obs`` (pass ``obs=`` to override);
    enabled, it emits ``mc/setup`` / ``mc/compile`` / ``mc/execute`` /
    ``mc/summarize`` spans plus a ``note`` event and a manifest ``sweep``
    entry recording the seed batch (``scn.seed + seed .. + num_seeds-1``).
    ``wall_s`` semantics are untouched — the timed region is the same
    fenced dispatch with or without telemetry.
    """
    if mode not in ("vmap", "loop"):
        raise ValueError(f"mode must be 'vmap' or 'loop', got {mode!r}")
    from ..obs import NULL_OBS, Obs
    if obs is None:
        obs = getattr(plan, "obs", NULL_OBS)
    else:
        obs = Obs.ensure(obs)
    ctx, scn = _mc_context(plan)
    rounds = plan.num_rounds if rounds is None else rounds
    if rounds < 1:
        raise ValueError("need at least one round")
    run = plan._run_raw
    eval_acc = plan._eval_acc_raw
    with obs.span("mc/setup", seeds=num_seeds, rounds=rounds, mode=mode):
        mc_fn, (seed_keys, state0, batches_all) = build_vmap_rollout(
            plan, num_seeds, rounds=rounds, seed=seed)
        up0 = availability_init(ctx["n_avail"])

    if mode == "vmap":
        mc = jax.jit(mc_fn)
        # AOT-compile so the timed wall excludes compilation WITHOUT paying
        # a full throwaway sweep
        with obs.span("mc/compile", mode=mode):
            compiled = mc.lower(seed_keys, state0, batches_all).compile()
        with obs.span("mc/execute", mode=mode):
            # fenced: dispatch + block on the result, never dispatch alone
            (outs, accs), wall = fenced(
                lambda: compiled(seed_keys, state0, batches_all))
        with obs.span("mc/summarize"):
            stacks = {k: np.asarray(v) for k, v in outs.items()}
            stacks["final_accuracy"] = np.asarray(accs)
    else:
        @jax.jit
        def round_step(key, r, state, up, batch):
            state, up, out = _round_outputs(
                ctx, keys.round_env_key(key, r), state, up, batch, run)
            return state, up, out

        eval_fn = jax.jit(eval_acc)

        def sweep():
            rows, accs = [], []
            for key in seed_keys:
                state, up = state0, up0
                per_round = []
                for r in range(rounds):
                    batch = jax.tree_util.tree_map(lambda x, r=r: x[r],
                                                   batches_all)
                    state, up, out = round_step(key, jnp.uint32(r), state,
                                                up, batch)
                    per_round.append(out)
                rows.append(per_round)
                accs.append(eval_fn(state))
            return rows, accs

        # warm the per-round jit cache with ONE round (all later calls
        # share shapes), then run the sweep once, timed
        with obs.span("mc/compile", mode=mode):
            warm = jax.tree_util.tree_map(lambda x: x[0], batches_all)
            warm_state, _, _ = round_step(seed_keys[0], jnp.uint32(0), state0,
                                          up0, warm)
            jax.block_until_ready(eval_fn(warm_state))
        with obs.span("mc/execute", mode=mode):
            # fenced: the sweep queues per-round dispatches; block on the
            # full row set before reading the wall clock
            (rows, accs), wall = fenced(sweep)
        with obs.span("mc/summarize"):
            # np.asarray (not float): population sweeps carry a (cohort,) id
            # row per round alongside the scalar bill fields
            stacks = {k: np.asarray([[np.asarray(out[k])
                                      for out in per_round]
                                     for per_round in rows])
                      for k in rows[0][0]}
            stacks["final_accuracy"] = np.asarray([float(a) for a in accs])

    uav = np.broadcast_to(_uav_rounds(plan, rounds),
                          (num_seeds, rounds)).copy()
    stacks["uav_energy_j"] = uav
    if obs:
        obs.event("note", kind="monte_carlo", num_seeds=num_seeds,
                  rounds=rounds, mode=mode, engine=plan.engine_label,
                  wall_s=round(wall, 6))
        obs.manifest(sweep={"kind": "monte_carlo", "mode": mode,
                            "num_seeds": num_seeds, "rounds": rounds,
                            "engine": plan.engine_label,
                            "seed_base": scn.seed + seed,
                            "seeds": [scn.seed + seed + i
                                      for i in range(num_seeds)],
                            "wall_s": round(wall, 6)})
        obs.flush()
    return MonteCarloResult(stacks=stacks, num_seeds=num_seeds,
                            rounds=rounds, engine=plan.engine_label,
                            mode=mode, wall_s=wall,
                            metrics_config=ctx["metrics"], kind=ctx["kind"],
                            num_clients=ctx["n"])
