"""Central PRNG fold-slot registry.

Every ``jax.random.fold_in(key, <literal>)`` in the repo must fold a slot
registered here.  Slots are scoped by *domain* so the same integer can
mean different things on unrelated key streams (the per-round env key vs
a model-init key), but within one domain both names and values are
unique — ``register`` raises on any collision, which is what makes the
stream layout auditable: ``repro.analyze`` greps every fold site and
rejects literals that are not a registered slot of some domain.

Migrating a literal to a named slot is bit-identical by construction
(the integer value is part of the registration), so the replay tests
that pin Monte-Carlo / cohort streams double as the migration gate.

Domains in use:

``env``
    The per-round environment key ``round_env_key(env_key, r)``
    (scenario stream, or seed 0 without a scenario).  Consumed by
    availability masks, channel rate draws, and cohort sampling — one
    slot each so the three streams never collide and ``run_monte_carlo``
    replays all of them from the same fold layout.
``data``
    Dataset synthesis keys (train/test split of a base data key).
``init``
    Model parameter-init keys that need a sub-stream beside a
    ``jax.random.split`` fan-out.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

__all__ = [
    "KeySlot",
    "register",
    "registered_slots",
    "slot_values",
    "fold",
    "round_env_key",
    "ENV_MASK",
    "ENV_RATES",
    "ENV_COHORT",
    "DATA_TRAIN",
    "DATA_TEST",
    "INIT_FFN_ALT",
    "INIT_MOE_SHARED",
]


@dataclass(frozen=True)
class KeySlot:
    """One registered fold constant: ``fold_in(key, slot.value)``."""

    domain: str
    name: str
    value: int

    def __index__(self) -> int:  # lets the slot be used as the fold literal
        return self.value


_REGISTRY: dict[tuple[str, str], KeySlot] = {}


def register(domain: str, name: str, value: int) -> KeySlot:
    """Register a fold slot; raise if (domain, name) or (domain, value) collide.

    Re-registering the exact same triple returns the existing slot (idempotent
    under module reloads); any mismatch is an error.
    """
    slot = KeySlot(domain, name, int(value))
    prev = _REGISTRY.get((domain, name))
    if prev is not None:
        if prev == slot:
            return prev
        raise ValueError(
            f"fold slot {domain}/{name} already registered with value "
            f"{prev.value}, refusing {slot.value}"
        )
    for other in _REGISTRY.values():
        if other.domain == domain and other.value == slot.value:
            raise ValueError(
                f"fold value {slot.value} in domain {domain!r} already taken "
                f"by slot {other.name!r}, refusing {name!r}"
            )
    _REGISTRY[(domain, name)] = slot
    return slot


def registered_slots() -> tuple[KeySlot, ...]:
    """All registered slots, in registration order."""
    return tuple(_REGISTRY.values())


def slot_values(domain: str | None = None) -> frozenset[int]:
    """The set of registered fold values (optionally for one domain)."""
    return frozenset(
        s.value for s in _REGISTRY.values() if domain is None or s.domain == domain
    )


def fold(key: jax.Array, slot: KeySlot) -> jax.Array:
    """``jax.random.fold_in`` through a registered slot."""
    return jax.random.fold_in(key, slot.value)


def round_env_key(env_key: jax.Array, round_index) -> jax.Array:
    """The per-round environment key every env-domain slot folds from."""
    return jax.random.fold_in(env_key, round_index)


# --- the repo's slot layout (values are load-bearing: replay tests pin the
# --- resulting streams bit-for-bit, so renumbering is a breaking change) ---

#: availability/dropout mask draw for the round
ENV_MASK = register("env", "mask", 1)
#: stochastic channel rate draw for the round's link bill
ENV_RATES = register("env", "rates", 2)
#: population cohort sample for the round
ENV_COHORT = register("env", "cohort", 3)

#: synthetic train split of a DataSpec seed key
DATA_TRAIN = register("data", "train", 0)
#: synthetic held-out split of a DataSpec seed key
DATA_TEST = register("data", "test", 1)

#: transformer dense-residual alternate FFN init (beside the split fan-out)
INIT_FFN_ALT = register("init", "ffn_alt", 1)
#: MoE shared-expert init stream (beside the routed-expert fan-out)
INIT_MOE_SHARED = register("init", "moe_shared", 7)
