"""``repro.analyze`` — static analysis of the compiled stack.

Two passes, one CLI (``tools/repro_lint.py``), CI-gated:

* **Pass 1 (jaxpr audit, ``jaxpr_audit``)** — structural invariants of
  every compiled engine round and the Monte-Carlo rollout: donation
  actually aliases, no host callbacks, no f64 under x32, collective axes
  exist on the mesh, traces are stable, closure constants stay under
  budget, and the PRNG fold-slot registry (``repro.keys``) is
  collision-free.
* **Pass 2 (AST lint, ``ast_lint``)** — repo-specific source hazards:
  traced-value branching, raw timers, key reuse, magic fold literals,
  unhoisted constants, bare excepts, labels crossing the link boundary.

See the "Static analysis" section of ``docs/ARCHITECTURE.md`` for the
rule table and the escape-hatch policy.
"""

from .ast_lint import RULES, lint_file, lint_paths, lint_source
from .findings import Finding, Report
from .jaxpr_audit import (audit_keys, audit_mc, audit_plan, check_callbacks,
                          check_collective_axes, check_const_budget,
                          check_donation, check_f64, check_trace_stability,
                          iter_eqns)
from .variants import audit_all, compiled_variants, mc_specs, variant_specs

__all__ = [
    "Finding", "Report", "RULES",
    "lint_file", "lint_paths", "lint_source",
    "audit_plan", "audit_mc", "audit_keys", "audit_all",
    "check_donation", "check_callbacks", "check_f64",
    "check_collective_axes", "check_const_budget", "check_trace_stability",
    "iter_eqns",
    "variant_specs", "mc_specs", "compiled_variants",
]
