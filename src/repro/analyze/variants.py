"""The engine-variant matrix the jaxpr auditor sweeps.

Mirrors the tier-1 test matrix at minimum compile cost: one tiny CNN spec
per ``EngineSpec`` variant (fl/sl x scan/vmap/shard_map), the
population-cohort corners (stateless FL cohorts + the EPSL shared client
tier), and the Monte-Carlo vmap rollout over a masked scenario plan.
``tools/repro_lint.py --jaxpr`` compiles each and runs ``audit_plan`` /
``audit_mc``; a finding on any variant fails CI.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

NUM_CLASSES = 4


def _tiny_spec(kind: str, axis: str, *, pop: Optional[int] = None,
               scenario=None, dropout: float = 0.0, mission: bool = False):
    from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec)
    return ExperimentSpec(
        model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
        data=DataSpec(kind="synthetic", image_size=12, classes_per_client=2,
                      n_train=32, n_test=16),
        clients=ClientSpec(num_clients=2, population=pop,
                           dropout_rate=dropout),
        cut_policy=CutPolicy(mode="fraction", fraction=0.4),
        link_policy=LinkPolicy(),
        engine=EngineSpec(kind=kind, client_axis=axis),
        mission=MissionSpec(farm_acres=50.0) if mission else None,
        scenario=scenario,
        global_rounds=1, local_steps=1, batch_size=4, seed=0)


def variant_specs() -> Iterator[tuple[str, object]]:
    """``(name, ExperimentSpec)`` per audited variant."""
    for kind in ("fl", "sl"):
        for axis in ("scan", "vmap", "shard_map"):
            yield f"{kind}/{axis}", _tiny_spec(kind, axis)
    # masked engines (the mask-aware lowering is a distinct program)
    yield "fl/vmap+dropout", _tiny_spec("fl", "vmap", dropout=0.25)
    yield "sl/vmap+dropout", _tiny_spec("sl", "vmap", dropout=0.25)
    # population cohorts: stateless FL rounds + the EPSL shared client tier
    yield "fl/vmap+population", _tiny_spec("fl", "vmap", pop=6)
    yield "sl/vmap+population", _tiny_spec("sl", "vmap", pop=6)


def mc_specs() -> Iterator[tuple[str, object]]:
    """Variants whose Monte-Carlo vmap rollout is audited too."""
    from ..sim import AvailabilityParams, ChannelParams, ScenarioSpec
    scn = ScenarioSpec(
        channel=ChannelParams(kind="a2g"),
        availability=AvailabilityParams(kind="bernoulli", p_drop=0.3),
        seed=1)
    yield "mc/fl/vmap+scenario", _tiny_spec("fl", "vmap", scenario=scn,
                                            mission=True)
    yield "mc/sl/vmap+population", _tiny_spec("sl", "vmap", pop=6)


def compiled_variants(*, mc: bool = True, match: Optional[str] = None
                      ) -> Iterator[tuple[str, object, bool]]:
    """Compile the matrix lazily: ``(name, plan, audit_mc_too)``.
    ``match`` filters by substring BEFORE compiling (the CLI's
    ``--variant``)."""
    from ..api import compile_experiment
    for name, spec in variant_specs():
        if match is None or match in name:
            yield name, compile_experiment(spec), False
    if mc:
        for name, spec in mc_specs():
            if match is None or match in name:
                yield name, compile_experiment(spec), True


def audit_all(*, mc: bool = True):
    """Run the full jaxpr audit sweep; returns a combined Report."""
    from .findings import Report
    from .jaxpr_audit import audit_keys, audit_mc as _audit_mc, audit_plan
    report = Report()
    report.extend(audit_keys())
    for name, plan, with_mc in compiled_variants(mc=mc):
        r = audit_plan(plan)
        r.checked = [f"{name}: {c}" for c in r.checked]
        report.extend(r)
        if with_mc:
            r = _audit_mc(plan)
            r.checked = [f"{name}: {c}" for c in r.checked]
            report.extend(r)
    return report
