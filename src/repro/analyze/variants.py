"""The engine-variant matrix the jaxpr auditor sweeps.

Mirrors the tier-1 test matrix at minimum compile cost: one tiny CNN spec
per ``EngineSpec`` variant (fl/sl x scan/vmap/shard_map), the
population-cohort corners (stateless FL cohorts + the EPSL shared client
tier), the Monte-Carlo vmap rollout over a masked scenario plan, and the
metrics-bus twins (``<name>+metrics``: the same specs compiled with
``ObsConfig(metrics=MetricsConfig())`` so the tap-carrying programs clear
the audit too).
``tools/repro_lint.py --jaxpr`` compiles each and runs ``audit_plan`` /
``audit_mc``; a finding on any variant fails CI.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

NUM_CLASSES = 4


def _tiny_spec(kind: str, axis: str, *, pop: Optional[int] = None,
               scenario=None, dropout: float = 0.0, mission: bool = False,
               link_kernel: str = "xla", compress: str = "none"):
    from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, LinkPolicy, MissionSpec, ModelSpec)
    return ExperimentSpec(
        model=ModelSpec(name="tinycnn", num_classes=NUM_CLASSES),
        data=DataSpec(kind="synthetic", image_size=12, classes_per_client=2,
                      n_train=32, n_test=16),
        clients=ClientSpec(num_clients=2, population=pop,
                           dropout_rate=dropout),
        cut_policy=CutPolicy(mode="fraction", fraction=0.4),
        link_policy=LinkPolicy(compress=compress),
        engine=EngineSpec(kind=kind, client_axis=axis,
                          link_kernel=link_kernel),
        mission=MissionSpec(farm_acres=50.0) if mission else None,
        scenario=scenario,
        global_rounds=1, local_steps=1, batch_size=4, seed=0)


def _tiny_lm_spec(axis: str, *, attn_impl: str = "xla"):
    """Minimum-cost transformer SL spec: the kernel-dispatch seam
    (``ModelSpec.attn_impl``) compiled into a real split-LM round."""
    from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                       ExperimentSpec, ModelSpec)
    from ..configs.base import ArchConfig
    arch = ArchConfig(name="tinylm", family="attn", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype="float32")
    return ExperimentSpec(
        model=ModelSpec(family="transformer", name="tinylm", arch=arch,
                        attn_impl=attn_impl),
        data=DataSpec(kind="tokens", partition="iid", seq_len=16,
                      n_train=32, n_test=16),
        clients=ClientSpec(num_clients=2),
        cut_policy=CutPolicy(mode="fraction", fraction=0.5),
        engine=EngineSpec(kind="sl", client_axis=axis),
        global_rounds=1, local_steps=1, batch_size=4, seed=0)


def variant_specs() -> Iterator[tuple[str, object]]:
    """``(name, ExperimentSpec)`` per audited variant."""
    for kind in ("fl", "sl"):
        for axis in ("scan", "vmap", "shard_map"):
            yield f"{kind}/{axis}", _tiny_spec(kind, axis)
    # masked engines (the mask-aware lowering is a distinct program)
    yield "fl/vmap+dropout", _tiny_spec("fl", "vmap", dropout=0.25)
    yield "sl/vmap+dropout", _tiny_spec("sl", "vmap", dropout=0.25)
    # population cohorts: stateless FL rounds + the EPSL shared client tier
    yield "fl/vmap+population", _tiny_spec("fl", "vmap", pop=6)
    yield "sl/vmap+population", _tiny_spec("sl", "vmap", pop=6)
    # kernel-enabled lowerings (PR-9 Pallas pass): the audited programs
    # must include what we actually execute when kernels are on — the
    # interpret-mode Pallas flash attention inside a split-LM round and
    # the fused int8 link boundary
    yield "sl/vmap+lm_pallas", _tiny_lm_spec("vmap", attn_impl="pallas")
    yield "sl/scan+lm_pallas", _tiny_lm_spec("scan", attn_impl="pallas")
    yield "sl/vmap+link_fused", _tiny_spec("sl", "vmap", compress="int8",
                                           link_kernel="fused")


# variants whose metrics-bus twin ("<name>+metrics") joins the audit: the
# tap-carrying lowerings are distinct programs and must clear the same six
# jaxpr checks; metrics-off programs staying bit-identical is pinned by
# tests/test_metrics.py, not here
METRICS_TWINS = ("fl/vmap", "sl/scan", "sl/vmap", "sl/shard_map",
                 "sl/vmap+population", "sl/vmap+link_fused",
                 "mc/sl/vmap+population")


def _metrics_obs():
    """The audit's metrics-on ObsConfig: full default tap set, no sink —
    ``enabled=False`` keeps the sweep free of run dirs."""
    from ..obs import ObsConfig
    from ..obs.metrics import MetricsConfig
    return ObsConfig(enabled=False, metrics=MetricsConfig())


def mc_specs() -> Iterator[tuple[str, object]]:
    """Variants whose Monte-Carlo vmap rollout is audited too."""
    from ..sim import AvailabilityParams, ChannelParams, ScenarioSpec
    scn = ScenarioSpec(
        channel=ChannelParams(kind="a2g"),
        availability=AvailabilityParams(kind="bernoulli", p_drop=0.3),
        seed=1)
    yield "mc/fl/vmap+scenario", _tiny_spec("fl", "vmap", scenario=scn,
                                            mission=True)
    yield "mc/sl/vmap+population", _tiny_spec("sl", "vmap", pop=6)


def compiled_variants(*, mc: bool = True, match: Optional[str] = None
                      ) -> Iterator[tuple[str, object, bool]]:
    """Compile the matrix lazily: ``(name, plan, audit_mc_too)``.
    ``match`` filters by substring BEFORE compiling (the CLI's
    ``--variant``)."""
    from ..api import compile_experiment
    for name, spec in variant_specs():
        if match is None or match in name:
            yield name, compile_experiment(spec), False
        twin = f"{name}+metrics"
        if name in METRICS_TWINS and (match is None or match in twin):
            yield twin, compile_experiment(spec, obs=_metrics_obs()), False
    if mc:
        for name, spec in mc_specs():
            if match is None or match in name:
                yield name, compile_experiment(spec), True
            twin = f"{name}+metrics"
            if name in METRICS_TWINS and (match is None or match in twin):
                yield twin, compile_experiment(spec, obs=_metrics_obs()), True


def audit_all(*, mc: bool = True):
    """Run the full jaxpr audit sweep; returns a combined Report."""
    from .findings import Report
    from .jaxpr_audit import audit_keys, audit_mc as _audit_mc, audit_plan
    report = Report()
    report.extend(audit_keys())
    for name, plan, with_mc in compiled_variants(mc=mc):
        r = audit_plan(plan)
        r.checked = [f"{name}: {c}" for c in r.checked]
        report.extend(r)
        if with_mc:
            r = _audit_mc(plan)
            r.checked = [f"{name}: {c}" for c in r.checked]
            report.extend(r)
    return report
