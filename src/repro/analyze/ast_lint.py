"""Pass 2: stdlib-``ast`` lint over ``src/repro`` — repo-specific JAX hazards.

Rules (ids are stable; the CLI and CI artifact key on them):

``traced-branch``
    ``if``/``while`` on a parameter of a jit-scoped function (a function
    decorated with / passed to ``jit``/``vmap``/``scan``/``cond``/...).
    Python control flow on a traced value raises ``TracerBoolConversion``
    at best and silently bakes a branch at worst. ``is None`` /
    ``is not None`` tests are static and exempt.
``raw-timer``
    ``time.perf_counter()`` / ``time.time()`` outside ``repro.obs``'s
    fenced primitives. jax dispatch is asynchronous — a naive timer pair
    measures queueing, not execution; use ``obs.fenced`` /
    ``obs.time_fenced`` / a span. ``obs/timeline.py`` is exempt: it IS
    the timer implementation, the one module that must read raw clocks
    (every timer there fences explicitly — see its module docstring).
``key-reuse``
    One PRNG key variable consumed by two or more ``jax.random``
    samplers without an intervening ``fold_in``/``split`` — the draws
    are perfectly correlated.
``magic-fold``
    ``jax.random.fold_in(key, <integer literal>)`` outside
    ``repro/keys.py``. Fold slots must be registered (``keys.register``)
    and folded via ``keys.fold(key, SLOT)`` so the stream layout stays
    collision-audited in one place. Non-literal folds (round/step
    indices) are fine.
``unhoisted-const``
    A ``jnp`` constant builder (``zeros``/``ones``/``full``/``eye``/
    ``arange``/``array`` of literals) inside a ``for``/``while`` body —
    rebuilt (and re-transferred) every iteration; hoist it.
``bare-except``
    ``except:`` with no exception type.
``label-link``
    The ``client_fwd`` closure of a ``SplitStep`` references a
    label-like name (``targets``/``labels``/``y*``): its output crosses
    the client->server link, so labels would leave the client — the SL
    privacy boundary (see ARCHITECTURE.md "Where the labels live").

Escape hatch: a ``repro: ignore[<rule>] -- <reason>`` comment on the
finding line. The reason is mandatory — an ignore without one is itself
a finding (``bad-suppression``), so every suppression in the repo
carries a written justification.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, Optional

from .findings import Finding, Report

RULES = (
    "traced-branch", "raw-timer", "key-reuse", "magic-fold",
    "unhoisted-const", "bare-except", "label-link", "bad-suppression",
)

# functions that introduce a traced scope for a function passed to / wrapped
# by them (matched on the last attribute segment: jax.jit, jax.lax.scan, ...)
_JIT_WRAPPERS = frozenset({
    "jit", "pjit", "vmap", "pmap", "grad", "value_and_grad", "scan",
    "while_loop", "cond", "shard_map", "checkpoint", "remat",
})
_SAMPLERS_EXEMPT = frozenset({"fold_in", "split", "key_data", "wrap_key_data",
                              "clone", "key_impl"})
_LABELISH = frozenset({"targets", "labels", "y", "yy", "by"})

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore\[([a-z-]+)\](\s*--\s*(\S.*))?")


def _func_name(node: ast.AST) -> Optional[str]:
    """Last dotted segment of a call target (``jax.lax.scan`` -> ``scan``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.AST) -> Optional[str]:
    """Full dotted name of an expression, or None if not a plain path."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Suppressions:
    """Per-line ``repro: ignore[<rule>] -- <reason>`` map for one file."""

    def __init__(self, source: str, path: str):
        self.by_line: dict[int, str] = {}
        self.bad: list[Finding] = []
        for i, line in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if m is None:
                continue
            rule, reason = m.group(1), m.group(3)
            if rule not in RULES:
                self.bad.append(Finding(
                    "bad-suppression", f"{path}:{i}",
                    f"ignore[{rule}] names an unknown rule "
                    f"(known: {', '.join(sorted(RULES))})"))
            elif not reason:
                self.bad.append(Finding(
                    "bad-suppression", f"{path}:{i}",
                    f"ignore[{rule}] has no reason; write "
                    f"'# repro: ignore[{rule}] -- <why this is safe>'"))
            else:
                self.by_line[i] = rule

    def covers(self, line: int, rule: str) -> bool:
        return self.by_line.get(line) == rule


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, source: str, *, is_keys_module: bool,
                 is_timer_module: bool = False):
        self.path = path
        self.is_keys_module = is_keys_module
        self.is_timer_module = is_timer_module
        self.suppressions = _Suppressions(source, path)
        self.findings: list[Finding] = list(self.suppressions.bad)
        # stack of (function node, set-of-param-names-or-None): the param
        # set is non-None while inside a jit scope
        self._jit_params: list[set] = []
        self._loop_depth = 0
        # names of functions passed (by name) to a jit wrapper anywhere in
        # the file — their defs are jit scopes too (two-phase: collected
        # up front by _collect_wrapped)
        self._wrapped_names: set[str] = set()

    # ---- helpers ----------------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str):
        line = getattr(node, "lineno", 0)
        if self.suppressions.covers(line, rule):
            return
        self.findings.append(Finding(rule, f"{self.path}:{line}", message))

    def lint(self, tree: ast.Module) -> list[Finding]:
        self._collect_wrapped(tree)
        self.visit(tree)
        return self.findings

    def _collect_wrapped(self, tree: ast.Module):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if _func_name(node.func) in _JIT_WRAPPERS:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        self._wrapped_names.add(arg.id)

    def _is_jit_scope(self, node) -> bool:
        if self._jit_params and self._jit_params[-1] is not None:
            return True   # nested inside a jit scope
        if any(_func_name(d) in _JIT_WRAPPERS for d in node.decorator_list):
            return True
        return node.name in self._wrapped_names

    @staticmethod
    def _params_of(node) -> set:
        a = node.args
        names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        if a.vararg:
            names.add(a.vararg.arg)
        if a.kwarg:
            names.add(a.kwarg.arg)
        return names

    # ---- scope tracking ---------------------------------------------------

    def _visit_func(self, node):
        params = None
        if self._is_jit_scope(node):
            params = self._params_of(node)
            if self._jit_params and self._jit_params[-1] is not None:
                params |= self._jit_params[-1]   # closure over traced names
        self._jit_params.append(params)
        # a def inside a loop body is not *executed* per iteration — loop
        # context does not extend into a nested function's body
        outer_loops, self._loop_depth = self._loop_depth, 0
        self._check_key_reuse(node)
        self.generic_visit(node)
        self._loop_depth = outer_loops
        self._jit_params.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Lambda(self, node: ast.Lambda):
        self._jit_params.append(self._jit_params[-1]
                                if self._jit_params else None)
        outer_loops, self._loop_depth = self._loop_depth, 0
        self.generic_visit(node)
        self._loop_depth = outer_loops
        self._jit_params.pop()

    # ---- rules ------------------------------------------------------------

    def _traced_names_in_test(self, test: ast.AST) -> list[str]:
        """Jit-scope parameter names referenced by a branch test, minus any
        that only appear in static ``is (not) None`` comparisons."""
        params = self._jit_params[-1] if self._jit_params else None
        if not params:
            return []
        static: set[int] = set()
        for node in ast.walk(test):
            if isinstance(node, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in node.ops):
                for sub in ast.walk(node):
                    static.add(id(sub))
        return [n.id for n in ast.walk(test)
                if isinstance(n, ast.Name) and n.id in params
                and id(n) not in static]

    def visit_If(self, node: ast.If):
        for name in self._traced_names_in_test(node.test):
            self._emit("traced-branch", node,
                       f"Python `if` on parameter {name!r} of a jit-scoped "
                       f"function; use lax.cond/jnp.where (traced values "
                       f"have no host truth value)")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        for name in self._traced_names_in_test(node.test):
            self._emit("traced-branch", node,
                       f"Python `while` on parameter {name!r} of a "
                       f"jit-scoped function; use lax.while_loop")
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_For(self, node: ast.For):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if node.type is None:
            self._emit("bare-except", node,
                       "bare `except:` swallows KeyboardInterrupt/SystemExit; "
                       "name the exception type")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        dotted = _dotted(node.func)
        # raw-timer
        if not self.is_timer_module and dotted in (
                "time.time", "time.perf_counter", "time.monotonic"):
            self._emit("raw-timer", node,
                       f"raw {dotted}() window; jax dispatch is async — "
                       f"use obs.fenced/time_fenced or an obs span")
        # magic-fold
        if (not self.is_keys_module and dotted is not None
                and dotted.endswith("random.fold_in") and len(node.args) == 2
                and isinstance(node.args[1], ast.Constant)
                and isinstance(node.args[1].value, int)):
            self._emit("magic-fold", node,
                       f"literal fold slot {node.args[1].value}; register it "
                       f"in repro/keys.py and fold via keys.fold(key, SLOT)")
        # unhoisted-const
        if self._loop_depth > 0 and dotted is not None and "." in dotted:
            head, tail = dotted.split(".", 1)
            if head in ("jnp", "jax") and tail.split(".")[-1] in (
                    "zeros", "ones", "full", "eye", "arange", "array",
                    "identity") and node.args and all(
                        _is_literal(a) for a in node.args):
                self._emit("unhoisted-const", node,
                           f"{dotted}(...) of literals rebuilt every loop "
                           f"iteration; hoist it above the loop")
        # label-link
        if _func_name(node.func) == "SplitStep":
            for kw in node.keywords:
                if kw.arg == "client_fwd":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Name) and (
                                sub.id in _LABELISH
                                or sub.id.startswith("y_")):
                            self._emit(
                                "label-link", kw.value,
                                f"client_fwd references label-like name "
                                f"{sub.id!r}; its output crosses the "
                                f"client->server link — labels must not "
                                f"leave the client tier")
        self.generic_visit(node)

    def _check_key_reuse(self, func):
        """Within one function body: a var assigned from PRNGKey consumed
        raw by >= 2 jax.random samplers is correlated sampling."""
        key_vars: set[str] = set()
        consumed: dict[str, int] = {}
        for node in ast.walk(func):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                d = _dotted(node.value.func)
                if d is not None and d.endswith("random.PRNGKey"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            key_vars.add(t.id)
        if not key_vars:
            return
        for node in ast.walk(func):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d is None or ".random." not in f".{d}.":
                continue
            fn = d.split(".")[-1]
            if fn in _SAMPLERS_EXEMPT or fn == "PRNGKey":
                continue
            if node.args and isinstance(node.args[0], ast.Name) and \
                    node.args[0].id in key_vars:
                name = node.args[0].id
                consumed[name] = consumed.get(name, 0) + 1
                if consumed[name] == 2:
                    self._emit(
                        "key-reuse", node,
                        f"PRNG key {name!r} consumed by multiple samplers "
                        f"without fold_in/split; the draws are correlated")


def _is_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_is_literal(e) for e in node.elts)
    if isinstance(node, ast.UnaryOp):
        return _is_literal(node.operand)
    # dtype names (jnp.float32, "float32") count as literal-ish
    if isinstance(node, ast.Attribute):
        return _dotted(node) is not None
    return False


def lint_file(path: Path, repo_root: Optional[Path] = None) -> list[Finding]:
    source = path.read_text()
    rel = str(path.relative_to(repo_root)) if repo_root else str(path)
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return [Finding("bare-except", f"{rel}:{e.lineno}",
                        f"file does not parse: {e.msg}", severity="error")]
    linter = _FileLinter(
        rel, source,
        is_keys_module=path.name == "keys.py",
        is_timer_module=str(path).replace("\\", "/").endswith(
            "obs/timeline.py"))
    return linter.lint(tree)


def lint_paths(paths: Iterable[Path],
               repo_root: Optional[Path] = None) -> Report:
    """Lint every ``.py`` under ``paths`` (files or directories)."""
    report = Report()
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    for f in files:
        report.findings.extend(lint_file(f, repo_root))
        report.checked.append(str(f.relative_to(repo_root))
                              if repo_root else str(f))
    return report


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    """Lint a source string (the analyzer tests' fixture entry point)."""
    tree = ast.parse(source, filename=path)
    linter = _FileLinter(path, source, is_keys_module=False)
    return linter.lint(tree)
