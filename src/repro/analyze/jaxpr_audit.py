"""Pass 1: structural invariants of compiled engine rounds, at the jaxpr /
lowered-HLO level.

Every compiled ``Plan`` exposes its jitted round through
``plan._run._audit`` (attached by ``api.plan`` at lowering time:
the jitted callable, its ``donate_argnums``, and how the uniform
``run(state, batches, mask)`` surface maps onto its positional
signature). The auditor rebuilds the exact example arguments a round
receives — ``plan.init()`` state, one ``round_batches`` draw, a ones
mask when the engine is mask-aware — then checks, without executing
anything:

``jaxpr-donation``
    every donated input buffer is actually aliased to an output in the
    lowered StableHLO (``tf.aliasing_output``); a donated-but-copied
    buffer silently doubles peak memory for the engine state.
``jaxpr-callback``
    no host callback primitives (``pure_callback`` / ``io_callback`` /
    ``debug_callback`` — incl. ``jax.debug.print``) anywhere in the
    round body, recursively through scan/cond/pjit/shard_map.
``jaxpr-f64``
    no float64/complex128/int64 values under the repo's default x32
    policy — a silent promotion doubles bytes on the wire and on device.
``jaxpr-collective-axis``
    every named collective axis (``psum``/``pmean``/``all_gather``...)
    exists on the plan's bound mesh.
``jaxpr-trace-stability``
    tracing the round twice yields the identical jaxpr — a mismatch
    means some Python-side state (fresh consts, mutable default, id-keyed
    cache) leaks into the trace, the classic silent-retrace hazard the
    obs recompile gauge catches only at runtime.
``jaxpr-const-budget``
    no closure constant above ``const_budget_bytes`` (default 1 MiB)
    is baked into the jaxpr — hoisted energy/link/FLOP constants are
    O(clients) scalars; anything bigger (a captured dataset, a stacked
    batch) should be a traced operand.

``audit_plan`` runs all six over a plan's round; ``audit_mc`` audits the
Monte-Carlo vmap rollout (the other jitted hot path) the same way.
Hetero-bucketed plans have no single jittable round and are rejected,
mirroring ``run_monte_carlo``.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .findings import Finding, Report

_CALLBACK_PRIMS = ("callback", "debug_print")
_WIDE_DTYPES = ("float64", "complex128")
_AXIS_PARAM_KEYS = ("axes", "axis_name", "axis_names")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def iter_eqns(jaxpr):
    """Every equation of ``jaxpr``, recursing into call/control-flow
    sub-jaxprs (scan, cond branches, pjit, shard_map, custom_*)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        yield from _jaxprs_in(v)


def _jaxprs_in(v):
    if hasattr(v, "eqns"):                       # Jaxpr
        yield v
    elif hasattr(v, "jaxpr"):                    # ClosedJaxpr
        yield v.jaxpr
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _jaxprs_in(item)


def _collective_axes(eqn) -> list[str]:
    names: list[str] = []
    for k in _AXIS_PARAM_KEYS:
        v = eqn.params.get(k)
        if v is None:
            continue
        for item in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(item, str):
                names.append(item)
    return names


# ---------------------------------------------------------------------------
# individual checks (each: ClosedJaxpr / lowered text -> findings)
# ---------------------------------------------------------------------------

def check_callbacks(closed, where: str) -> list[Finding]:
    out = []
    for eqn in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if any(tag in prim for tag in _CALLBACK_PRIMS):
            out.append(Finding(
                "jaxpr-callback", where,
                f"host callback primitive {prim!r} inside the compiled "
                f"round body — every call crosses the device boundary "
                f"per step"))
    return out


def check_f64(closed, where: str) -> list[Finding]:
    out = []
    seen = set()

    def dtype_of(v):
        aval = getattr(v, "aval", None)
        return str(getattr(aval, "dtype", ""))

    for eqn in iter_eqns(closed.jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            dt = dtype_of(v)
            if dt in _WIDE_DTYPES and dt not in seen:
                seen.add(dt)
                out.append(Finding(
                    "jaxpr-f64", where,
                    f"{dt} value produced by {eqn.primitive.name!r} under "
                    f"the x32 policy — a silent promotion doubles device "
                    f"and wire bytes"))
    for const in closed.consts:
        dt = str(getattr(const, "dtype", ""))
        if dt in _WIDE_DTYPES and dt not in seen:
            seen.add(dt)
            out.append(Finding(
                "jaxpr-f64", where,
                f"{dt} closure constant baked into the round"))
    return out


def check_collective_axes(closed, mesh, where: str) -> list[Finding]:
    mesh_axes = (set() if mesh is None
                 else {str(a) for a in mesh.axis_names})
    out = []
    for eqn in iter_eqns(closed.jaxpr):
        for axis in _collective_axes(eqn):
            if axis not in mesh_axes:
                out.append(Finding(
                    "jaxpr-collective-axis", where,
                    f"{eqn.primitive.name!r} reduces over axis {axis!r} "
                    f"which is not on the bound mesh "
                    f"(axes: {sorted(mesh_axes) or 'none'})"))
    return out


def check_const_budget(closed, where: str,
                       const_budget_bytes: int = 1 << 20) -> list[Finding]:
    out = []
    for const in closed.consts:
        nbytes = getattr(const, "nbytes", 0)
        if nbytes > const_budget_bytes:
            shape = getattr(const, "shape", ())
            out.append(Finding(
                "jaxpr-const-budget", where,
                f"closure constant of {nbytes} bytes (shape {shape}) baked "
                f"into the jaxpr; budget is {const_budget_bytes} — pass it "
                f"as a traced operand or hoist it to O(clients) scalars"))
    return out


def _canon_jaxpr(closed) -> str:
    # custom_jvp/vjp eqn params embed thunk reprs whose 0x addresses differ
    # per trace; strip them so only structural differences count
    return re.sub(r" at 0x[0-9a-f]+", " at 0x", str(closed))


def check_trace_stability(fn, args, where: str) -> list[Finding]:
    # trace through a fresh wrapper object each time: jax caches traces by
    # function identity, so tracing `fn` twice directly would never re-run
    # the Python and instability could never surface
    first = _canon_jaxpr(jax.make_jaxpr(lambda *a: fn(*a))(*args))
    second = _canon_jaxpr(jax.make_jaxpr(lambda *a: fn(*a))(*args))
    if first != second:
        return [Finding(
            "jaxpr-trace-stability", where,
            "two traces of the round produced different jaxprs — "
            "Python-side state leaks into the trace (fresh consts or an "
            "id-keyed cache), which retraces/recompiles silently at run "
            "time")]
    return []


def check_donation(jit_fn, args, donate_argnums, where: str) -> list[Finding]:
    """Donated-leaf count vs ``tf.aliasing_output`` count in the lowered
    StableHLO. jax on this toolchain emits no catchable warning for a
    donated-but-unused buffer, but an un-aliased donation is visible
    structurally: the input parameter lacks the aliasing attribute."""
    donated_leaves = sum(
        len(jax.tree_util.tree_leaves(args[i])) for i in donate_argnums
        if i < len(args))
    if donated_leaves == 0:
        return []
    txt = jit_fn.lower(*args).as_text()
    # single-device lowerings resolve donation to a concrete output alias
    # (tf.aliasing_output); on a multi-device mesh the parameter is marked
    # jax.buffer_donor instead and XLA picks the alias at compile time —
    # either marker proves the donated leaf is not silently copied
    aliased = (txt.count("tf.aliasing_output")
               + txt.count("jax.buffer_donor"))
    if aliased < donated_leaves:
        return [Finding(
            "jaxpr-donation", where,
            f"only {aliased}/{donated_leaves} donated input buffers are "
            f"aliased to outputs in the lowered program; the rest are "
            f"silently copied (peak memory = 2x engine state for those "
            f"leaves)")]
    return []


# ---------------------------------------------------------------------------
# plan-level entry points
# ---------------------------------------------------------------------------

def _example_round_args(plan) -> tuple[tuple, dict]:
    audit = getattr(plan._run, "_audit", None)
    if audit is None:
        raise ValueError(
            "plan's run closure carries no _audit handle; hetero-bucketed "
            "plans dispatch per bucket on the host and have no single "
            "jittable round to audit (same restriction as run_monte_carlo)")
    state = plan.init()
    cohort = plan._round_cohort(state)
    batches = plan.round_batches(state, cohort=cohort)
    es = state.engine_state
    args = tuple(es) if audit["unpack_state"] else (es,)
    args += (batches,)
    if audit["masked"]:
        args += (jnp.ones(plan.spec.clients.num_clients, jnp.float32),)
    return args, audit


def audit_plan(plan, *, const_budget_bytes: int = 1 << 20) -> Report:
    """All six structural checks over ``plan``'s compiled round."""
    args, audit = _example_round_args(plan)
    jit_fn = audit["jit_fn"]
    where = f"round[{plan.spec.describe()}]"
    report = Report(checked=[where])
    closed = jax.make_jaxpr(jit_fn)(*args)
    report.findings += check_donation(jit_fn, args,
                                      audit["donate_argnums"], where)
    report.findings += check_callbacks(closed, where)
    report.findings += check_f64(closed, where)
    report.findings += check_collective_axes(closed, plan.mesh, where)
    report.findings += check_const_budget(
        closed, where, const_budget_bytes=const_budget_bytes)
    report.findings += check_trace_stability(jit_fn, args, where)
    return report


def audit_mc(plan, *, num_seeds: int = 2,
             const_budget_bytes: Optional[int] = None) -> Report:
    """Audit the Monte-Carlo vmap rollout exactly as it would execute.

    The rollout legitimately closes over the stacked per-round batch pool
    (it IS passed as an operand — ``build_vmap_rollout`` returns it in the
    example args), so the const budget defaults to the per-round batch
    bytes plus the 1 MiB scalar allowance.
    """
    from ..sim.monte_carlo import build_vmap_rollout
    mc_fn, example_args = build_vmap_rollout(plan, num_seeds)
    where = f"mc_vmap[{plan.spec.describe()}]"
    if const_budget_bytes is None:
        const_budget_bytes = 1 << 20
    report = Report(checked=[where])
    closed = jax.make_jaxpr(mc_fn)(*example_args)
    report.findings += check_callbacks(closed, where)
    report.findings += check_f64(closed, where)
    report.findings += check_collective_axes(closed, plan.mesh, where)
    report.findings += check_const_budget(
        closed, where, const_budget_bytes=const_budget_bytes)
    report.findings += check_trace_stability(mc_fn, example_args, where)
    return report


def audit_keys() -> Report:
    """Re-validate the central fold-slot registry: per-domain uniqueness of
    both names and values (``keys.register`` enforces this at import; the
    audit proves the loaded registry state, so a bypassing mutation or a
    stale duplicate still fails the gate)."""
    from .. import keys
    report = Report(checked=["repro.keys registry"])
    seen_vals: dict[tuple[str, int], str] = {}
    for slot in keys.registered_slots():
        k = (slot.domain, slot.value)
        if k in seen_vals:
            report.findings.append(Finding(
                "jaxpr-fold-slot", "repro/keys.py",
                f"fold value {slot.value} in domain {slot.domain!r} is "
                f"registered twice ({seen_vals[k]!r} and {slot.name!r})"))
        seen_vals[k] = slot.name
    return report
