"""Finding records shared by both analysis passes."""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation, locatable and machine-renderable.

    ``rule`` is the stable id (``jaxpr-*`` for pass 1, everything else
    pass 2); ``where`` is ``file:line`` for AST findings and the engine
    variant / closure name for jaxpr findings.
    """

    rule: str
    where: str
    message: str
    severity: str = "error"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "where": self.where,
            "message": self.message,
            "severity": self.severity,
        }

    def __str__(self) -> str:
        return f"{self.where}: [{self.rule}] {self.message}"


@dataclass
class Report:
    """A pass's findings plus what it actually covered (for the CLI)."""

    findings: list[Finding] = field(default_factory=list)
    checked: list[str] = field(default_factory=list)

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.checked.extend(other.checked)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "checked": self.checked,
            "findings": [f.to_dict() for f in self.findings],
        }
