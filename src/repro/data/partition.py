"""Client data partitioners.

The paper simulates non-IID by giving each of 4 clients data from exactly
3 of the 12 classes (Section IV-C). ``partition_non_iid`` reproduces that;
``partition_dirichlet`` is the standard generalization (spec-reachable via
``DataSpec(partition="dirichlet", dirichlet_alpha=...)``); ``partition_iid``
is the uniform split token pipelines use.
"""
from __future__ import annotations

import numpy as np

# ceiling on DISTINCT data partitions materialized for a sampled population
# (ClientSpec.population): partition construction is host-side Python over
# the partition count, so a million-client population shares
# min(population, n_samples, cap) distinct shards, cycled over population
# ids (pid -> pid % count — the same cycling device edge_profiles use).
# Data memory stays O(dataset); engine state stays O(cohort).
POPULATION_PARTITION_CAP = 1024


def population_partition_count(population: int, num_samples: int,
                               *, cap: int = POPULATION_PARTITION_CAP) -> int:
    """Distinct partitions to build for a ``population``-client fleet:
    every partition must be non-empty (``<= num_samples``) and host-side
    construction must stay cheap (``<= cap``)."""
    if population < 1:
        raise ValueError(f"population must be >= 1, got {population}")
    return max(1, min(population, num_samples, cap))


def partition_non_iid(labels: np.ndarray, num_clients: int,
                      classes_per_client: int, *, num_classes: int | None = None,
                      seed: int = 0) -> list[np.ndarray]:
    """Assign each client `classes_per_client` distinct classes (paper: 4×3).

    Returns a list of index arrays, one per client. Classes are dealt round-
    robin so every class is owned by >=1 client when
    num_clients*classes_per_client >= num_classes.
    """
    labels = np.asarray(labels)
    ncls = int(num_classes if num_classes is not None else labels.max() + 1)
    rng = np.random.RandomState(seed)
    class_order = rng.permutation(ncls)
    # deal classes to clients round-robin
    owners: list[list[int]] = [[] for _ in range(num_clients)]
    i = 0
    for _ in range(num_clients * classes_per_client):
        owners[i % num_clients].append(int(class_order[i % ncls]))
        i += 1
    out = []
    for cl in range(num_clients):
        mask = np.isin(labels, owners[cl])
        idx = np.where(mask)[0]
        rng.shuffle(idx)
        out.append(idx)
    return out


def partition_dirichlet(labels: np.ndarray, num_clients: int, *, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 0) -> list[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew partition (the paper's pest data
    is non-IID across farms; small alpha -> strong skew).

    ``min_size > 0`` rebalances after sampling: clients left below the floor
    (a real outcome at small alpha) steal indices from the largest partition
    so every client can fill minibatches. Rebalancing is deterministic given
    ``seed``.
    """
    labels = np.asarray(labels)
    ncls = int(labels.max() + 1)
    rng = np.random.RandomState(seed)
    client_idx: list[list[int]] = [[] for _ in range(num_clients)]
    for c in range(ncls):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for cl, part in enumerate(np.split(idx, cuts)):
            client_idx[cl].extend(part.tolist())
    if min_size > 0:
        if min_size * num_clients > len(labels):
            raise ValueError(f"cannot give {num_clients} clients "
                             f"{min_size} samples each from {len(labels)}")
        for cl in range(num_clients):
            while len(client_idx[cl]) < min_size:
                donor = max(range(num_clients), key=lambda d: len(client_idx[d]))
                client_idx[cl].append(client_idx[donor].pop())
    return [np.asarray(sorted(v)) for v in client_idx]


def partition_iid(num_samples: int, num_clients: int, *,
                  seed: int = 0) -> list[np.ndarray]:
    """Uniform random split (the token-stream pipelines, where labels carry
    no class structure to skew)."""
    rng = np.random.RandomState(seed)
    order = rng.permutation(num_samples)
    return [np.sort(part) for part in np.array_split(order, num_clients)]
