from .synthetic import (SyntheticPestImages, synthetic_tokens, PEST_CLASSES)
from .partition import partition_non_iid, partition_dirichlet, partition_iid
from .pipeline import BatchIterator, shard_batch

__all__ = ["SyntheticPestImages", "synthetic_tokens", "PEST_CLASSES",
           "partition_non_iid", "partition_dirichlet", "partition_iid",
           "BatchIterator", "shard_batch"]
