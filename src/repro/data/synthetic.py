"""Synthetic stand-ins for the paper's data (offline container — no KAP download).

The Kaggle Agricultural Pests (KAP) dataset has 12 classes. We generate a
*learnable* class-conditional image distribution: each class is a mixture of
oriented sinusoidal textures + class-specific blob layout + noise. A small
CNN can separate the classes but not trivially (noise + shared nuisance
factors), so relative comparisons between FL and SL splits remain meaningful
even though absolute accuracies are not the paper's.

Token data for the LLM-family architectures is a deterministic Zipf-ish
stream with a copy structure so cross-entropy decreases under training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

PEST_CLASSES = ["ants", "bees", "beetles", "caterpillars", "moths",
                "earthworms", "earwigs", "grasshoppers", "slugs", "snails",
                "wasps", "weevils"]


@dataclasses.dataclass
class SyntheticPestImages:
    """Deterministic class-conditional image generator (NHWC, float32 [0,1])."""

    num_classes: int = 12
    image_size: int = 64          # paper resizes to 224; 64 keeps CPU tests fast
    channels: int = 3
    seed: int = 0

    def _class_params(self):
        rng = np.random.RandomState(self.seed)
        # per-class texture frequency/orientation and colour
        freqs = rng.uniform(2.0, 8.0, size=(self.num_classes,))
        thetas = rng.uniform(0, np.pi, size=(self.num_classes,))
        colors = rng.uniform(0.2, 0.9, size=(self.num_classes, self.channels))
        blob_xy = rng.uniform(0.2, 0.8, size=(self.num_classes, 2))
        return freqs, thetas, colors, blob_xy

    def sample(self, key: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
        """Returns (images (n,H,W,C), labels (n,))."""
        freqs, thetas, colors, blob_xy = self._class_params()
        freqs = jnp.asarray(freqs); thetas = jnp.asarray(thetas)
        colors = jnp.asarray(colors); blob_xy = jnp.asarray(blob_xy)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        labels = jax.random.randint(k1, (n,), 0, self.num_classes)
        H = W = self.image_size
        yy, xx = jnp.meshgrid(jnp.linspace(0, 1, H), jnp.linspace(0, 1, W), indexing="ij")

        def one(label, key):
            ka, kb = jax.random.split(key)
            f = freqs[label]; th = thetas[label] + 0.1 * jax.random.normal(ka, ())
            u = xx * jnp.cos(th) + yy * jnp.sin(th)
            tex = 0.5 + 0.5 * jnp.sin(2 * jnp.pi * f * u)
            cx, cy = blob_xy[label]
            blob = jnp.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
            base = 0.6 * tex + 0.4 * blob
            img = base[..., None] * colors[label][None, None, :]
            img = img + 0.15 * jax.random.normal(kb, (H, W, self.channels))
            return jnp.clip(img, 0.0, 1.0)

        keys = jax.random.split(k2, n)
        images = jax.vmap(one)(labels, keys)
        return images.astype(jnp.float32), labels

    def dataset(self, n: int, seed: int | None = None):
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        return self.sample(key, n)


def synthetic_tokens(key: jax.Array, batch: int, seq_len: int, vocab: int,
                     *, copy_period: int = 16) -> jax.Array:
    """Deterministic learnable token stream: Zipf marginals + periodic copy.

    tokens[t] == tokens[t - copy_period] with prob ~0.5, so even a small
    model achieves < ln(vocab) loss quickly.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish sampling via inverse CDF on a power-law
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6, maxval=1.0)
    ranks = jnp.floor((u ** -0.9 - 1.0)).astype(jnp.int32) % vocab
    copy_mask = jax.random.bernoulli(k2, 0.5, (batch, seq_len))
    rolled = jnp.roll(ranks, copy_period, axis=1)
    toks = jnp.where(copy_mask, rolled, ranks)
    return toks.astype(jnp.int32)
