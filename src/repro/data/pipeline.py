"""Batching / sharding pipeline.

CPU-side numpy batching with optional device sharding via
``jax.device_put(x, NamedSharding(mesh, spec))`` — the same call pattern a
real multi-host input pipeline uses per-process.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class BatchIterator:
    """Epoch-shuffling minibatch iterator over in-memory arrays."""

    def __init__(self, arrays: tuple, batch_size: int, *, seed: int = 0,
                 drop_last: bool = True):
        self.arrays = tuple(np.asarray(a) for a in arrays)
        n = self.arrays[0].shape[0]
        assert all(a.shape[0] == n for a in self.arrays)
        self.n = n
        self.batch_size = batch_size
        self.drop_last = drop_last
        self.rng = np.random.RandomState(seed)

    def __iter__(self) -> Iterator[tuple]:
        order = self.rng.permutation(self.n)
        stop = self.n - (self.n % self.batch_size) if self.drop_last else self.n
        for i in range(0, stop, self.batch_size):
            sel = order[i:i + self.batch_size]
            yield tuple(a[sel] for a in self.arrays)

    def steps_per_epoch(self) -> int:
        return self.n // self.batch_size


def shard_batch(batch, mesh, spec: Optional[P] = None):
    """Place a host batch onto the mesh, sharded on the 'data' axis."""
    if spec is None:
        spec = P(("pod", "data") if "pod" in mesh.axis_names else "data")

    def put(x):
        s = NamedSharding(mesh, P(*spec) if not isinstance(spec, P) else spec)
        return jax.device_put(x, s)

    return jax.tree_util.tree_map(put, batch)
