"""Faithful reproduction pipelines: FL baseline vs SL (Algorithm 3).

DEPRECATED SHIMS — ``train_fl`` / ``train_sl`` keep their historical
signatures and return dicts for one release, but both now delegate to the
unified experiment layer: ``paper_spec`` maps a ``PaperTrainConfig`` to an
``repro.api.ExperimentSpec`` and ``repro.api.compile_experiment`` lowers it
to the same compiled engines these functions used to hand-wire
(``make_fl_round`` with a scanned client axis for FL;
``make_multi_client_round`` — the sequential Alg. 3 — for SL). New code
should build specs directly; see ``src/repro/api/README.md``.

What the shims preserve:

  FL      : each client trains the FULL model on its shard for
            ``local_steps`` minibatches; server FedAvg's all client models
            each global round.
  SL      : eEnergy-Split / SplitFed — client prefix (cut at SL_{a,b}) runs
            locally; smashed activations (+labels) go to the server model,
            which backprops and returns the cut gradient; server params
            update per client-batch (sequential, as the UAV visits clients
            one at a time); client prefixes FedAvg every global round.

Both run as ONE jitted XLA program per global round (donated state, batches
pre-gathered per round), with energy/link accounting hoisted to per-step
analytic constants from symmetric XLA-counted FLOPs on both tiers
(``repro.api.runtime``: A5000 roofline, client side scaled to Jetson via
Eq. 9, link bytes via Eq. 8).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                   ExperimentSpec, LinkPolicy, ModelSpec, compile_experiment)
# Re-exported for callers that historically imported these from here
# (benchmarks/bench_resource.py, tests/test_engine.py, fleet.campaign):
from ..api.runtime import (classification_metrics,  # noqa: F401
                           count_fl_step_flops, count_sl_step_flops)
from .energy import CO2_G_PER_J, EnergyRecord


@dataclasses.dataclass
class PaperTrainConfig:
    model: str = "mobilenetv2"
    num_clients: int = 4
    classes_per_client: int = 3
    num_classes: int = 12
    client_fraction: float = 0.25      # SL_{a,b}: a = client share
    global_rounds: int = 8
    local_steps: int = 4
    batch_size: int = 16
    lr: float = 1e-3
    image_size: int = 32
    compress_link: bool = False
    seed: int = 0


def paper_spec(cfg: PaperTrainConfig, kind: str) -> ExperimentSpec:
    """The ``ExperimentSpec`` a legacy ``PaperTrainConfig`` stands for.

    ``kind`` is ``'fl'`` or ``'sl'`` — both lower to the sequential
    (``client_axis='scan'``) engines the faithful reproduction uses. The
    shim-equivalence tests run this spec directly and compare against the
    ``train_fl``/``train_sl`` wrappers.
    """
    return ExperimentSpec(
        model=ModelSpec(name=cfg.model, num_classes=cfg.num_classes),
        data=DataSpec(kind="arrays", image_size=cfg.image_size,
                      classes_per_client=cfg.classes_per_client,
                      shrink_batches=True),
        clients=ClientSpec(num_clients=cfg.num_clients),
        cut_policy=CutPolicy(mode="fraction", fraction=cfg.client_fraction),
        link_policy=LinkPolicy(
            compress="int8" if cfg.compress_link else "none"),
        engine=EngineSpec(kind=kind, client_axis="scan"),
        global_rounds=cfg.global_rounds, local_steps=cfg.local_steps,
        batch_size=cfg.batch_size, lr=cfg.lr, seed=cfg.seed)


def _energy_record(label: str, time_s: float, energy_j: float) -> EnergyRecord:
    return EnergyRecord(label=label, time_s=time_s, energy_j=energy_j,
                        co2_g=energy_j * CO2_G_PER_J)


def _run_rounds(plan):
    """Drive a compiled plan for its round budget; returns
    (state, records, history, wall_s, steps_per_s)."""
    t0 = time.time()
    state = plan.init()
    records, history = [], []
    for _ in range(plan.num_rounds):
        state, rec = plan.run_round(state)
        records.append(rec)
        history.append(state.last_metrics)
    wall_s = time.time() - t0
    n_steps = (plan.num_rounds * plan.spec.clients.num_clients
               * plan.spec.local_steps)
    return state, records, history, wall_s, n_steps / max(wall_s, 1e-9)


# ---------------------------------------------------------------------------
# FL baseline (deprecated shim)
# ---------------------------------------------------------------------------

def train_fl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    plan = compile_experiment(paper_spec(cfg, "fl"),
                              data=(x_train, y_train, x_test, y_test))
    state, records, history, wall_s, sps = _run_rounds(plan)
    return {"params": state.engine_state, "history": history,
            "client_energy": _energy_record(
                "total", sum(r.client_time_s for r in records),
                sum(r.client_energy_j for r in records)),
            "server_energy": _energy_record(
                "total", sum(r.server_time_s for r in records),
                sum(r.server_energy_j for r in records)),
            "metrics": history[-1], "step_flops": plan.flops["full"],
            "wall_s": wall_s, "steps_per_s": sps}


# ---------------------------------------------------------------------------
# SL (Algorithm 3) (deprecated shim)
# ---------------------------------------------------------------------------

def train_sl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    plan = compile_experiment(paper_spec(cfg, "sl"),
                              data=(x_train, y_train, x_test, y_test))
    state, records, history, wall_s, sps = _run_rounds(plan)
    client_stack, server_params, _, _ = state.engine_state
    client_params = jax.tree_util.tree_map(lambda v: v[0], client_stack)
    k = plan.cut_of_client[0]
    fl_client, fl_server, _smashed = plan.flops[k]
    return {"client_params": client_params, "server_params": server_params,
            "history": history, "metrics": history[-1],
            "client_energy": _energy_record(
                "total", sum(r.client_time_s for r in records),
                sum(r.client_energy_j for r in records)),
            "server_energy": _energy_record(
                "total", sum(r.server_time_s for r in records),
                sum(r.server_energy_j for r in records)),
            "link_bytes": sum(r.link_bytes for r in records),
            "link_time_s": sum(r.link_time_s for r in records),
            "cut_index": k,
            "client_flops": fl_client, "server_flops": fl_server,
            "wall_s": wall_s, "steps_per_s": sps}
