"""Faithful reproduction configs: FL baseline vs SL (Algorithm 3) as specs.

The legacy ``train_fl`` / ``train_sl`` entry points are GONE (they spent
one release as deprecated shims over the unified experiment layer — see
CHANGES.md). What remains is the mapping layer: ``PaperTrainConfig`` is the
historical config surface, and ``paper_spec`` turns one into the
``repro.api.ExperimentSpec`` the old trainers stood for:

  FL : each client trains the FULL model on its shard for ``local_steps``
       minibatches; the server FedAvg's all client models each global round
       (``EngineSpec('fl', 'scan')``).
  SL : eEnergy-Split / SplitFed — client prefix (cut at SL_{a,b}) runs
       locally; smashed activations (+labels) go to the server model, which
       backprops and returns the cut gradient; server params update per
       client-batch (sequential, as the UAV visits clients one at a time);
       client prefixes FedAvg every global round
       (``EngineSpec('sl', 'scan')``).

Run them with ``repro.api.compile_experiment(paper_spec(cfg, kind),
data=...)`` — one jitted XLA program per global round (donated state,
batches pre-gathered), energy/link accounting hoisted to per-step analytic
constants (``repro.api.runtime``: A5000 roofline, client side scaled via
Eq. 9, link bytes via Eq. 8). ``benchmarks/bench_sl_accuracy.py`` is the
reference caller; the old-call-site -> spec table lives in
``src/repro/api/README.md``.
"""
from __future__ import annotations

import dataclasses

from ..api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,
                   ExperimentSpec, LinkPolicy, ModelSpec)
# Re-exported for callers that historically imported these from here
# (benchmarks/bench_resource.py, tests/test_engine.py):
from ..api.runtime import (classification_metrics,  # noqa: F401
                           count_fl_step_flops, count_sl_step_flops)


@dataclasses.dataclass
class PaperTrainConfig:
    model: str = "mobilenetv2"
    num_clients: int = 4
    classes_per_client: int = 3
    num_classes: int = 12
    client_fraction: float = 0.25      # SL_{a,b}: a = client share
    global_rounds: int = 8
    local_steps: int = 4
    batch_size: int = 16
    lr: float = 1e-3
    image_size: int = 32
    compress_link: bool = False
    seed: int = 0


def paper_spec(cfg: PaperTrainConfig, kind: str) -> ExperimentSpec:
    """The ``ExperimentSpec`` a legacy ``PaperTrainConfig`` stands for.

    ``kind`` is ``'fl'`` or ``'sl'`` — both lower to the sequential
    (``client_axis='scan'``) engines the faithful reproduction uses.
    """
    return ExperimentSpec(
        model=ModelSpec(name=cfg.model, num_classes=cfg.num_classes),
        data=DataSpec(kind="arrays", image_size=cfg.image_size,
                      classes_per_client=cfg.classes_per_client,
                      shrink_batches=True),
        clients=ClientSpec(num_clients=cfg.num_clients),
        cut_policy=CutPolicy(mode="fraction", fraction=cfg.client_fraction),
        link_policy=LinkPolicy(
            compress="int8" if cfg.compress_link else "none"),
        engine=EngineSpec(kind=kind, client_axis="scan"),
        global_rounds=cfg.global_rounds, local_steps=cfg.local_steps,
        batch_size=cfg.batch_size, lr=cfg.lr, seed=cfg.seed)
