"""Faithful reproduction pipelines: FL baseline vs SL (Algorithm 3).

Multi-client (explicit client list, non-IID partitions, 4 clients x 3
classes as in §IV-C):

  FL      : each client trains the FULL model on its shard for `local_steps`
            minibatches; server FedAvg's all client models each global round.
  SL      : eEnergy-Split / SplitFed — client prefix (cut at SL_{a,b}) runs
            locally; smashed activations (+labels) go to the server model,
            which backprops and returns the cut gradient; server params
            update per client-batch (sequential, as the UAV visits clients
            one at a time); client prefixes FedAvg every global round.

Device-resident engine (stacked-client layout)
----------------------------------------------
Every per-client quantity — model params, Adam moments, and the round's
minibatches — carries a leading ``num_clients`` axis. One global round is
ONE jitted XLA program built by ``repro.core.split``:

  * FL: ``make_fl_round`` — outer ``lax.scan`` over clients, inner scan over
    local steps, FedAvg folded into the same program.
  * SL: ``make_multi_client_round`` — outer scan over the ``local_steps``
    UAV visits, inner scan over clients (server updates stay sequential per
    client batch, exactly Alg. 3's inner loop), client-prefix FedAvg at the
    end of the compiled round.

State buffers are donated round-over-round and batches are gathered once
per round on the host ((clients, steps, batch, ...) arrays), so the hot
loop performs `global_rounds` dispatches total instead of
`rounds x clients x local_steps`.

Energy / link accounting
------------------------
Nothing is metered inside the hot loop. Per-step FLOPs are counted ONCE
from the compiled step programs (XLA ``cost_analysis`` with an analytic
jaxpr-walk fallback — ``repro.core.flops``), symmetrically for both
pipelines and both tiers: full fwd+bwd for FL, client-prefix fwd+bwd
(``jax.vjp``) and server-suffix fwd+bwd (grad w.r.t. params *and* smashed
input) for SL. The smashed-tensor shape comes from ``jax.eval_shape``.
Those counts become per-step analytic constants (A5000 roofline, client
side scaled to Jetson via Eq. 9, link bytes via Eq. 8) multiplied by the
step counts and recorded per (round, client) through EnergyTracker
(Table III) / LinkConfig.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data.partition import partition_non_iid
from ..models.cnn import CNN_BUILDERS, cross_entropy_loss
from ..optim import adamw, init_stacked
from .energy import (EnergyTracker, HardwareProfile, JETSON_AGX_ORIN,
                     RTX_A5000, scale_time)
from .flops import flops_of
from .link import LinkConfig
from .split import (SplitStep, apply_stages, init_stages, make_fl_round,
                    make_multi_client_round, partition_stages)


@dataclasses.dataclass
class PaperTrainConfig:
    model: str = "mobilenetv2"
    num_clients: int = 4
    classes_per_client: int = 3
    num_classes: int = 12
    client_fraction: float = 0.25      # SL_{a,b}: a = client share
    global_rounds: int = 8
    local_steps: int = 4
    batch_size: int = 16
    lr: float = 1e-3
    image_size: int = 32
    compress_link: bool = False
    seed: int = 0


def _round_batches(x, y, parts, batch_size, steps, rng):
    """One global round of minibatches, pre-gathered and stacked on a
    leading client axis: ((clients, steps, b, ...), (clients, steps, b))."""
    bs = min(batch_size, min(len(idx) for idx in parts))
    sel = np.stack([rng.choice(idx, size=(steps, bs), replace=True)
                    for idx in parts])
    return jnp.asarray(x[sel]), jnp.asarray(y[sel])


def _stack_replicas(tree, n: int):
    """Broadcast one pytree to n identical replicas on a leading axis."""
    return jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), tree)


def _roofline_s(flops: float, hw: HardwareProfile) -> float:
    return flops / (hw.fp32_tflops * 1e12)


def _client_step_time_s(flops: float) -> float:
    """Edge-device seconds per step: A5000 roofline scaled via Eq. 9."""
    return scale_time(_roofline_s(flops, RTX_A5000), RTX_A5000,
                      JETSON_AGX_ORIN)


# ---------------------------------------------------------------------------
# symmetric per-step FLOP counting (shared with benchmarks/bench_resource)
# ---------------------------------------------------------------------------

def count_fl_step_flops(stages, params, bx, by) -> float:
    """XLA-counted (analytic fallback) fwd+bwd FLOPs of one full-model
    training step on one minibatch."""
    return flops_of(
        lambda p, xx, yy: jax.grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, xx), yy))(p),
        params, bx, by)


def count_sl_step_flops(cs, cp, ss, sp, bx, by):
    """Per-tier fwd+bwd FLOPs of one split step, counted symmetrically with
    ``count_fl_step_flops``.

    client: prefix forward + the VJP that turns the returned cut gradient
    into client-param gradients (the full client-side backward).
    server: suffix forward + backward w.r.t. server params AND the smashed
    input (the cut gradient it sends back).
    Returns (client_flops, server_flops, smashed_shape_dtype_struct).
    """
    smashed_sd = jax.eval_shape(lambda p, xx: apply_stages(cs, p, xx), cp, bx)
    cut_grad = jnp.zeros(smashed_sd.shape, smashed_sd.dtype)

    def client_step(p, xx, ct):
        smashed, vjp = jax.vjp(lambda q: apply_stages(cs, q, xx), p)
        return smashed, vjp(ct)

    def server_step(p, sm, yy):
        return jax.grad(
            lambda q, s: cross_entropy_loss(apply_stages(ss, q, s), yy),
            argnums=(0, 1))(p, sm)

    client_fl = flops_of(client_step, cp, bx, cut_grad)
    server_fl = flops_of(server_step, sp, cut_grad, by)
    return client_fl, server_fl, smashed_sd


# ---------------------------------------------------------------------------
# FL baseline
# ---------------------------------------------------------------------------

def train_fl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    stages = CNN_BUILDERS[cfg.model](cfg.num_classes)
    key = jax.random.PRNGKey(cfg.seed)
    global_params = init_stages(key, stages)
    opt = adamw(cfg.lr)
    x_train = np.asarray(x_train)
    y_train = np.asarray(y_train)
    parts = partition_non_iid(y_train, cfg.num_clients,
                              cfg.classes_per_client,
                              num_classes=cfg.num_classes, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    tracker_c = EnergyTracker(JETSON_AGX_ORIN)
    tracker_s = EnergyTracker(RTX_A5000)

    def grad_fn(params, batch):
        bx, by = batch
        return jax.value_and_grad(
            lambda p: cross_entropy_loss(apply_stages(stages, p, bx), by))(params)

    # one compiled program per global round; global params donated through
    fl_round = jax.jit(make_fl_round(grad_fn, opt), donate_argnums=(0,))

    # hoisted energy constants: full fwd+bwd on the edge device, per step
    sample = (jnp.asarray(x_train[:cfg.batch_size]),
              jnp.asarray(y_train[:cfg.batch_size]))
    step_flops = count_fl_step_flops(stages, global_params, *sample)
    t_client_step = _client_step_time_s(step_flops)

    x_test_j = jnp.asarray(x_test)
    eval_logits = jax.jit(lambda p: apply_stages(stages, p, x_test_j))

    t0 = time.time()
    history = []
    for rnd in range(cfg.global_rounds):
        batches = _round_batches(x_train, y_train, parts, cfg.batch_size,
                                 cfg.local_steps, rng)
        global_params, _losses = fl_round(global_params, batches)
        for ci in range(cfg.num_clients):
            # full fwd+bwd on the edge device (Jetson-scaled via Eq. 9)
            tracker_c.track_time(f"r{rnd}/c{ci}", t_client_step,
                                 count=cfg.local_steps)
        # server cost: aggregation only (negligible flops, small time)
        tracker_s.track_time(f"r{rnd}/agg", 1e-3)
        history.append(classification_metrics(eval_logits(global_params),
                                              y_test, cfg.num_classes))
    wall_s = time.time() - t0
    n_steps = cfg.global_rounds * cfg.num_clients * cfg.local_steps
    return {"params": global_params, "history": history,
            "client_energy": tracker_c.total(), "server_energy": tracker_s.total(),
            "metrics": history[-1], "step_flops": step_flops,
            "wall_s": wall_s, "steps_per_s": n_steps / max(wall_s, 1e-9)}


# ---------------------------------------------------------------------------
# SL (Algorithm 3)
# ---------------------------------------------------------------------------

def train_sl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    stages = CNN_BUILDERS[cfg.model](cfg.num_classes)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_stages(key, stages)
    cs, cp0, ss, sp, k = partition_stages(stages, params, cfg.client_fraction)
    opt_c, opt_s = adamw(cfg.lr), adamw(cfg.lr)
    x_train = np.asarray(x_train)
    y_train = np.asarray(y_train)
    parts = partition_non_iid(y_train, cfg.num_clients,
                              cfg.classes_per_client,
                              num_classes=cfg.num_classes, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    tracker_c = EnergyTracker(JETSON_AGX_ORIN)
    tracker_s = EnergyTracker(RTX_A5000)
    link = LinkConfig(compress="int8" if cfg.compress_link else "none")

    maybe_compress = None
    if cfg.compress_link:
        from ..kernels.quant.ops import link_compress as maybe_compress

    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
        link_constraint=maybe_compress,
    )
    sl_round = jax.jit(
        make_multi_client_round(step, opt_c, opt_s,
                                local_rounds=cfg.local_steps),
        donate_argnums=(0, 1, 2, 3))

    # stacked-client state: leading num_clients axis everywhere
    client_stack = _stack_replicas(cp0, cfg.num_clients)
    oc_stack = init_stacked(opt_c, cp0, cfg.num_clients)
    server_params = sp
    server_opt = opt_s.init(sp)

    # hoisted per-step constants: symmetric FLOP accounting + link bytes
    sample = (jnp.asarray(x_train[:cfg.batch_size]),
              jnp.asarray(y_train[:cfg.batch_size]))
    fl_client, fl_server, smashed_sd = count_sl_step_flops(
        cs, cp0, ss, sp, *sample)
    t_client_step = _client_step_time_s(fl_client)
    t_server_step = _roofline_s(fl_server, RTX_A5000)
    sm_bytes = smashed_sd.size * smashed_sd.dtype.itemsize
    step_link_bytes = link.roundtrip_bytes(sm_bytes,
                                           smashed_sd.dtype.itemsize,
                                           scale_block=smashed_sd.shape[-1])

    x_test_j = jnp.asarray(x_test)
    eval_logits = jax.jit(
        lambda cp, sp_: apply_stages(ss, sp_, apply_stages(cs, cp, x_test_j)))

    t0 = time.time()
    history = []
    link_bytes_total = 0.0
    for rnd in range(cfg.global_rounds):
        bx, by = _round_batches(x_train, y_train, parts, cfg.batch_size,
                                cfg.local_steps, rng)
        (client_stack, server_params, oc_stack, server_opt,
         _losses) = sl_round(client_stack, server_params, oc_stack,
                             server_opt, {"inputs": bx, "targets": by})
        for ci in range(cfg.num_clients):
            tracker_c.track_time(f"r{rnd}/c{ci}", t_client_step,
                                 count=cfg.local_steps)
            tracker_s.track_time(f"r{rnd}/c{ci}", t_server_step,
                                 count=cfg.local_steps)
        link_bytes_total += (cfg.num_clients * cfg.local_steps
                             * step_link_bytes)
        avg_prefix = jax.tree_util.tree_map(lambda v: v[0], client_stack)
        history.append(classification_metrics(
            eval_logits(avg_prefix, server_params), y_test, cfg.num_classes))
    wall_s = time.time() - t0
    n_steps = cfg.global_rounds * cfg.num_clients * cfg.local_steps
    client_params = jax.tree_util.tree_map(lambda v: v[0], client_stack)
    return {"client_params": client_params, "server_params": server_params,
            "history": history, "metrics": history[-1],
            "client_energy": tracker_c.total(),
            "server_energy": tracker_s.total(),
            "link_bytes": link_bytes_total,
            # link_bytes_total is already wire bytes (compression applied);
            # Eq. (8) directly, not transfer_time_s (would re-compress)
            "link_time_s": 8.0 * link_bytes_total / link.rate_bps,
            "cut_index": k,
            "client_flops": fl_client, "server_flops": fl_server,
            "wall_s": wall_s, "steps_per_s": n_steps / max(wall_s, 1e-9)}


# ---------------------------------------------------------------------------
# metrics (paper Fig. 3 radar: Acc / Precision / Recall / F1 / MCC)
# ---------------------------------------------------------------------------

def classification_metrics(logits: jax.Array, labels: jax.Array,
                           num_classes: int) -> dict:
    pred = np.asarray(logits.argmax(-1))
    y = np.asarray(labels)
    acc = float((pred == y).mean())
    precs, recs, f1s = [], [], []
    for c in range(num_classes):
        tp = float(((pred == c) & (y == c)).sum())
        fp = float(((pred == c) & (y != c)).sum())
        fn = float(((pred != c) & (y == c)).sum())
        p = tp / (tp + fp) if tp + fp else 0.0
        r = tp / (tp + fn) if tp + fn else 0.0
        precs.append(p)
        recs.append(r)
        f1s.append(2 * p * r / (p + r) if p + r else 0.0)
    # multiclass MCC
    n = len(y)
    t_k = np.bincount(y, minlength=num_classes).astype(float)
    p_k = np.bincount(pred, minlength=num_classes).astype(float)
    c = float((pred == y).sum())
    s2 = n * n
    num = c * n - float(t_k @ p_k)
    den = np.sqrt(max(s2 - float(p_k @ p_k), 0.0)) * \
        np.sqrt(max(s2 - float(t_k @ t_k), 0.0))
    mcc = num / den if den else 0.0
    return {"accuracy": acc, "precision": float(np.mean(precs)),
            "recall": float(np.mean(recs)), "f1": float(np.mean(f1s)),
            "mcc": float(mcc)}
