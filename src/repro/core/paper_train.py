"""Faithful reproduction pipelines: FL baseline vs SL (Algorithm 3).

Multi-client (explicit client list, non-IID partitions, 4 clients x 3
classes as in §IV-C):

  FL      : each client trains the FULL model on its shard for `local_steps`
            minibatches; server FedAvg's all client models each global round.
  SL      : eEnergy-Split / SplitFed — client prefix (cut at SL_{a,b}) runs
            locally; smashed activations (+labels) go to the server model,
            which backprops and returns the cut gradient; server params
            update per client-batch (sequential, as the UAV visits clients
            one at a time); client prefixes FedAvg every global round.

Both loops meter FLOPs-based client/server energy through EnergyTracker
(Table III) and the UAV link through LinkConfig (Eq. 8).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data.partition import partition_non_iid
from ..models.cnn import CNN_BUILDERS, accuracy, cross_entropy_loss
from ..optim import adamw, apply_updates
from .energy import (EnergyTracker, HardwareProfile, JETSON_AGX_ORIN,
                     RTX_A5000, scale_time)
from .fedavg import fedavg
from .link import LinkConfig
from .split import apply_stages, init_stages, partition_stages


@dataclasses.dataclass
class PaperTrainConfig:
    model: str = "mobilenetv2"
    num_clients: int = 4
    classes_per_client: int = 3
    num_classes: int = 12
    client_fraction: float = 0.25      # SL_{a,b}: a = client share
    global_rounds: int = 8
    local_steps: int = 4
    batch_size: int = 16
    lr: float = 1e-3
    image_size: int = 32
    compress_link: bool = False
    seed: int = 0


def _flops_of(fn, *args) -> float:
    """XLA-counted FLOPs of a jitted callable (per invocation)."""
    try:
        c = jax.jit(fn).lower(*args).compile().cost_analysis()
        return float(c.get("flops", 0.0)) if c else 0.0
    except Exception:
        return 0.0


def _client_batches(x, y, parts, batch_size, steps, rng):
    """per-client list of `steps` minibatches."""
    out = []
    for idx in parts:
        sel = rng.choice(idx, size=(steps, min(batch_size, len(idx))),
                         replace=True)
        out.append([(x[s], y[s]) for s in sel])
    return out


# ---------------------------------------------------------------------------
# FL baseline
# ---------------------------------------------------------------------------

def train_fl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    stages = CNN_BUILDERS[cfg.model](cfg.num_classes)
    key = jax.random.PRNGKey(cfg.seed)
    global_params = init_stages(key, stages)
    opt = adamw(cfg.lr)
    parts = partition_non_iid(np.asarray(y_train), cfg.num_clients,
                              cfg.classes_per_client,
                              num_classes=cfg.num_classes, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    tracker_c = EnergyTracker(JETSON_AGX_ORIN)
    tracker_s = EnergyTracker(RTX_A5000)

    @jax.jit
    def local_step(params, opt_state, bx, by):
        def loss_fn(p):
            return cross_entropy_loss(apply_stages(stages, p, bx), by)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    sample = (x_train[:cfg.batch_size], y_train[:cfg.batch_size])
    step_flops = _flops_of(
        lambda p, bx, by: jax.grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, bx), by))(p),
        global_params, *sample)

    history = []
    for rnd in range(cfg.global_rounds):
        batches = _client_batches(x_train, y_train, parts, cfg.batch_size,
                                  cfg.local_steps, rng)
        client_models = []
        for ci in range(cfg.num_clients):
            params = jax.tree_util.tree_map(jnp.copy, global_params)
            opt_state = opt.init(params)
            for bx, by in batches[ci]:
                params, opt_state, loss = local_step(params, opt_state, bx, by)
                # full fwd+bwd on the edge device (Jetson-scaled via Eq. 9)
                t_src = _roofline_s(step_flops, RTX_A5000)
                tracker_c.track_time(f"r{rnd}/c{ci}",
                                     scale_time(t_src, RTX_A5000,
                                                JETSON_AGX_ORIN))
            client_models.append(params)
        global_params = fedavg(client_models)
        # server cost: aggregation only (negligible flops, small time)
        tracker_s.track_time(f"r{rnd}/agg", 1e-3)
        history.append(_evaluate(stages, global_params, x_test, y_test))
    return {"params": global_params, "history": history,
            "client_energy": tracker_c.total(), "server_energy": tracker_s.total(),
            "metrics": history[-1], "step_flops": step_flops}


# ---------------------------------------------------------------------------
# SL (Algorithm 3)
# ---------------------------------------------------------------------------

def train_sl(cfg: PaperTrainConfig, x_train, y_train, x_test, y_test):
    stages = CNN_BUILDERS[cfg.model](cfg.num_classes)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_stages(key, stages)
    cs, cp0, ss, sp, k = partition_stages(stages, params, cfg.client_fraction)
    opt_c, opt_s = adamw(cfg.lr), adamw(cfg.lr)
    parts = partition_non_iid(np.asarray(y_train), cfg.num_clients,
                              cfg.classes_per_client,
                              num_classes=cfg.num_classes, seed=cfg.seed)
    rng = np.random.RandomState(cfg.seed)
    tracker_c = EnergyTracker(JETSON_AGX_ORIN)
    tracker_s = EnergyTracker(RTX_A5000)
    link = LinkConfig(compress="int8" if cfg.compress_link else "none")
    link_bytes_total = 0.0

    client_params = [jax.tree_util.tree_map(jnp.copy, cp0)
                     for _ in range(cfg.num_clients)]
    client_opts = [opt_c.init(cp0) for _ in range(cfg.num_clients)]
    server_params = sp
    server_opt = opt_s.init(sp)

    maybe_compress = None
    if cfg.compress_link:
        from ..kernels.quant.ops import link_compress as maybe_compress

    @jax.jit
    def split_step(cp, cop, spar, sop, bx, by):
        def loss_fn(cp_, sp_):
            smashed = apply_stages(cs, cp_, bx)
            if maybe_compress is not None:
                smashed = maybe_compress(smashed)
            logits = apply_stages(ss, sp_, smashed)
            return cross_entropy_loss(logits, by), smashed
        (loss, smashed), (gc, gs) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(cp, spar)
        upc, cop = opt_c.update(gc, cop, cp)
        ups, sop = opt_s.update(gs, sop, spar)
        return (apply_updates(cp, upc), cop, apply_updates(spar, ups), sop,
                loss, smashed)

    # FLOP accounting split by tier
    sample = (x_train[:cfg.batch_size], y_train[:cfg.batch_size])
    fl_client = _flops_of(
        lambda p, bx: apply_stages(cs, p, bx), cp0, sample[0])
    smashed_shape = jax.eval_shape(lambda p, bx: apply_stages(cs, p, bx),
                                   cp0, sample[0])
    fl_server = _flops_of(
        lambda p, sm, by: jax.grad(
            lambda q: cross_entropy_loss(apply_stages(ss, q, sm), by))(p),
        sp, jnp.zeros(smashed_shape.shape, smashed_shape.dtype), sample[1])

    history = []
    for rnd in range(cfg.global_rounds):
        batches = _client_batches(x_train, y_train, parts, cfg.batch_size,
                                  cfg.local_steps, rng)
        for step in range(cfg.local_steps):
            for ci in range(cfg.num_clients):
                bx, by = batches[ci][step]
                (client_params[ci], client_opts[ci], server_params,
                 server_opt, loss, smashed) = split_step(
                    client_params[ci], client_opts[ci], server_params,
                    server_opt, bx, by)
                # client: fwd + bwd of the prefix ~ 3x prefix fwd flops
                t_src = _roofline_s(3 * fl_client, RTX_A5000)
                tracker_c.track_time(
                    f"r{rnd}/c{ci}", scale_time(t_src, RTX_A5000,
                                                JETSON_AGX_ORIN))
                tracker_s.track_time(f"r{rnd}/c{ci}",
                                     _roofline_s(fl_server, RTX_A5000))
                sm_bytes = smashed.size * smashed.dtype.itemsize
                link_bytes_total += 2 * link.wire_bytes(
                    sm_bytes, smashed.dtype.itemsize)  # fwd + grad return
        # FedAvg of client prefixes (Alg. 3 line 19)
        avg = fedavg(client_params)
        client_params = [jax.tree_util.tree_map(jnp.copy, avg)
                         for _ in range(cfg.num_clients)]
        history.append(_evaluate_split(cs, avg, ss, server_params,
                                       x_test, y_test))
    return {"client_params": client_params[0], "server_params": server_params,
            "history": history, "metrics": history[-1],
            "client_energy": tracker_c.total(),
            "server_energy": tracker_s.total(),
            "link_bytes": link_bytes_total,
            "link_time_s": link.transfer_time_s(link_bytes_total, 1),
            "cut_index": k,
            "client_flops": fl_client, "server_flops": fl_server}


def _roofline_s(flops: float, hw: HardwareProfile) -> float:
    return flops / (hw.fp32_tflops * 1e12)


# ---------------------------------------------------------------------------
# metrics (paper Fig. 3 radar: Acc / Precision / Recall / F1 / MCC)
# ---------------------------------------------------------------------------

def classification_metrics(logits: jax.Array, labels: jax.Array,
                           num_classes: int) -> dict:
    pred = np.asarray(logits.argmax(-1))
    y = np.asarray(labels)
    acc = float((pred == y).mean())
    precs, recs, f1s = [], [], []
    for c in range(num_classes):
        tp = float(((pred == c) & (y == c)).sum())
        fp = float(((pred == c) & (y != c)).sum())
        fn = float(((pred != c) & (y == c)).sum())
        p = tp / (tp + fp) if tp + fp else 0.0
        r = tp / (tp + fn) if tp + fn else 0.0
        precs.append(p)
        recs.append(r)
        f1s.append(2 * p * r / (p + r) if p + r else 0.0)
    # multiclass MCC
    n = len(y)
    t_k = np.bincount(y, minlength=num_classes).astype(float)
    p_k = np.bincount(pred, minlength=num_classes).astype(float)
    c = float((pred == y).sum())
    s2 = n * n
    num = c * n - float(t_k @ p_k)
    den = np.sqrt(max(s2 - float(p_k @ p_k), 0.0)) * \
        np.sqrt(max(s2 - float(t_k @ t_k), 0.0))
    mcc = num / den if den else 0.0
    return {"accuracy": acc, "precision": float(np.mean(precs)),
            "recall": float(np.mean(recs)), "f1": float(np.mean(f1s)),
            "mcc": float(mcc)}


def _evaluate(stages, params, x_test, y_test) -> dict:
    logits = apply_stages(stages, params, x_test)
    return classification_metrics(logits, y_test, int(logits.shape[-1]))


def _evaluate_split(cs, cp, ss, sp, x_test, y_test) -> dict:
    logits = apply_stages(ss, sp, apply_stages(cs, cp, x_test))
    return classification_metrics(logits, y_test, int(logits.shape[-1]))
