"""eEnergy-Split core: the paper's contribution as composable JAX modules."""
from .deployment import (deploy_edge_devices, deploy_kmeans, deploy_gasbac,
                         uniform_grid_sensors, random_sensors, coverage_ok,
                         Deployment, build_csr_adjacency, field_side_meters)
from .trajectory import (plan_tour, greedy_tour_plan, solve_tsp, held_karp,
                         nearest_neighbor_tour, two_opt, TourPlan,
                         budget_rounds)
from .uav_energy import UAVParams, DEFAULT_UAV, tour_energy
from .energy import (EnergyTracker, HardwareProfile, RTX_A5000,
                     JETSON_AGX_ORIN, TPU_V5E, scale_time, roofline_time,
                     CO2_G_PER_J)
from .link import LinkConfig, smashed_bytes
from .split import (Stage, SplitStep, init_stages, apply_stages,
                    partition_stages, cut_index_for_fraction, split_stack,
                    merge_stack, stack_cut_index, make_split_train_step,
                    make_multi_client_round, make_fl_round)
from .fedavg import fedavg, fedavg_stack, fedavg_mean, fedavg_pmean
from .flops import flops_of, jaxpr_flops, xla_flops, compiled_cost
from .adaptive_cut import (profile_cuts_cnn, profile_cuts_transformer,
                           select_cut, CutChoice)

__all__ = [n for n in dir() if not n.startswith("_")]
