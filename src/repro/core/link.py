"""UAV relay link model — paper Eq. (8): T_SL = L / R.

L is the smashed-data byte volume crossing the cut layer; R the effective
UAV<->edge data rate. The link also models the paper's stated future work —
activation compression — via int8 quantization (our Pallas kernel in
``repro.kernels.quant``) which shrinks L by ~4x vs f32 / ~2x vs bf16.

In the SPMD mapping, the link is the `pod`-axis resharding collective at the
cut; its byte volume is *measured* from the lowered HLO by the roofline
layer and fed back here for time/energy accounting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    rate_bps: float = 100e6      # 100 Mb/s effective UAV<->edge rate
    compress: str = "none"       # "none" | "int8"
    radio_power_w: float = 2.0   # edge-device radio power while transmitting

    def wire_bytes(self, activation_bytes: float, dtype_bytes: int = 4, *,
                   scale_block: int = 256) -> float:
        """``scale_block`` is the number of elements sharing one f32 scale.
        The quant kernel emits one scale per row of the flattened
        (rows, last_dim) tensor, so callers that know the activation shape
        should pass ``scale_block=last_dim`` (``fleet.link`` does); the
        default 256 approximates wide activations."""
        if self.compress == "int8":
            # int8 payload + one f32 scale per scale_block elements
            return activation_bytes / dtype_bytes * (1.0 + 4.0 / scale_block)
        return activation_bytes

    def roundtrip_bytes(self, activation_bytes: float, dtype_bytes: int = 4,
                        *, scale_block: int = 256) -> float:
        """Wire bytes of one split step: smashed fwd + cut-gradient return."""
        return 2.0 * self.wire_bytes(activation_bytes, dtype_bytes,
                                     scale_block=scale_block)

    def transfer_time_s(self, activation_bytes: float, dtype_bytes: int = 4,
                        *, scale_block: int = 256) -> float:
        """Eq. (8): T_SL = L/R (R in bits/s)."""
        return 8.0 * self.wire_bytes(activation_bytes, dtype_bytes,
                                     scale_block=scale_block) / self.rate_bps

    def transfer_energy_j(self, activation_bytes: float, dtype_bytes: int = 4,
                          *, scale_block: int = 256) -> float:
        return self.transfer_time_s(activation_bytes, dtype_bytes,
                                    scale_block=scale_block) * self.radio_power_w


def smashed_bytes(batch: int, *feature_shape: int, dtype_bytes: int = 4) -> int:
    n = batch
    for s in feature_shape:
        n *= s
    return n * dtype_bytes
