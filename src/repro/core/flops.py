"""FLOP counting shared by the trainers, the cut profiler and the benches.

Two sources, tried in order:

1. **XLA** — ``compiled.cost_analysis()``. Its return type varies across jax
   versions (dict, or a per-device *list* of dicts on 0.4.3x); ``compiled_cost``
   normalizes both. On some backends it is missing or reports 0.
2. **Analytic jaxpr walk** — ``jaxpr_flops`` traverses the traced jaxpr and
   counts matmul/conv FLOPs exactly (2*M*N*K style) and one FLOP per output
   element for the remaining arithmetic primitives, recursing through
   pjit/scan/while/cond/custom-vjp sub-jaxprs. This is the roofline fallback:
   approximate on elementwise tails but exact on the dominant contractions.

``flops_of`` is the public entry point and **never returns 0 silently**: if
XLA yields nothing usable it falls back to the analytic count, and raises if
that is zero for a non-trivial program.
"""
from __future__ import annotations

import math
from typing import Optional

import jax

# Primitives that move/alias data without arithmetic — zero FLOPs.
_FREE_PRIMS = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "slice",
    "dynamic_slice", "dynamic_update_slice", "concatenate", "pad", "rev",
    "gather", "scatter", "convert_element_type", "bitcast_convert_type",
    "copy", "device_put", "stop_gradient", "iota", "eq", "ne", "lt", "le",
    "gt", "ge", "select_n", "argmax", "argmin", "reduce_and", "reduce_or",
    "and", "or", "not", "xor", "sign", "is_finite", "clamp", "squeeze",
})


def _size(aval) -> float:
    return float(math.prod(getattr(aval, "shape", ()) or (1,)))


def _dot_general_flops(eqn) -> float:
    lhs = eqn.invars[0].aval
    (lhs_contract, _), _ = eqn.params["dimension_numbers"]
    k = math.prod(lhs.shape[i] for i in lhs_contract) if lhs_contract else 1
    out = _size(eqn.outvars[0].aval)
    return 2.0 * out * k


def _conv_flops(eqn) -> float:
    rhs_shape = eqn.invars[1].aval.shape
    dn = eqn.params["dimension_numbers"]
    kernel_spatial = math.prod(rhs_shape[i] for i in dn.rhs_spec[2:])
    cin_per_group = rhs_shape[dn.rhs_spec[1]]
    out = _size(eqn.outvars[0].aval)
    return 2.0 * out * kernel_spatial * cin_per_group


def _subjaxprs(params: dict):
    """Yield (closed_or_open_jaxpr, repeat_count) pairs inside eqn params."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr"):
        if key in params and params[key] is not None:
            yield params[key], 1.0
    for branch in params.get("branches", ()) or ():
        yield branch, 1.0


def _walk(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_general_flops(eqn)
        elif name == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif name == "scan":
            inner = eqn.params["jaxpr"]
            total += float(eqn.params.get("length", 1)) * _walk(inner.jaxpr)
        elif any(True for _ in _subjaxprs(eqn.params)):
            for sub, reps in _subjaxprs(eqn.params):
                total += reps * _walk(getattr(sub, "jaxpr", sub))
        elif name in _FREE_PRIMS:
            continue
        elif name.startswith("reduce_"):
            total += sum(_size(v.aval) for v in eqn.invars)
        else:
            # elementwise default: one FLOP per output element
            total += sum(_size(v.aval) for v in eqn.outvars)
    return total


def jaxpr_flops(fn, *args) -> float:
    """Analytic FLOP count of ``fn(*args)`` from its traced jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    return _walk(closed.jaxpr)


def compiled_cost(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Returns an (possibly empty) dict: newer jax returns a dict directly,
    0.4.3x returns a one-element list of per-device dicts.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}


def xla_flops(fn, *args) -> Optional[float]:
    """XLA-counted FLOPs of one invocation, or None when unavailable."""
    try:
        compiled = jax.jit(fn).lower(*args).compile()
    except Exception:
        return None
    flops = float(compiled_cost(compiled).get("flops", -1.0))
    return flops if flops > 0.0 else None


def flops_of(fn, *args) -> float:
    """FLOPs of ``fn(*args)``: XLA-counted, analytic fallback, never a
    silent 0 (raises if both counters report nothing for a real program)."""
    counted = xla_flops(fn, *args)
    if counted is not None:
        return counted
    fallback = jaxpr_flops(fn, *args)
    if fallback <= 0.0:
        raise RuntimeError(
            "FLOP counting failed: XLA cost_analysis unavailable and the "
            "analytic jaxpr walk found no arithmetic in the program")
    return fallback
