"""Algorithm 2 — Energy-Constrained UAV Tour Planning Using an Exact TSP Solver.

Exact TSP via Held–Karp dynamic programming, O(2^M · M^2) — the paper notes
deployments have only a few edge devices (farms up to 250 acres), so exact
solving is near-instant; we cap exact at M<=16 and fall back to
nearest-neighbour + 2-opt beyond that (the paper's own stated adaptation for
larger scales).

Also provides the greedy (nearest-neighbour) tour the baselines use.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .uav_energy import UAVParams, DEFAULT_UAV


def _dist_matrix(points: np.ndarray) -> np.ndarray:
    return np.linalg.norm(points[:, None] - points[None], axis=-1)


def held_karp(points: np.ndarray) -> tuple[list[int], float]:
    """Exact TSP cycle over all points. Returns (order, cycle_length)."""
    m = len(points)
    if m == 1:
        return [0], 0.0
    if m == 2:
        return [0, 1], 2 * float(np.linalg.norm(points[0] - points[1]))
    d = _dist_matrix(points)
    # DP over subsets containing node 0
    full = 1 << (m - 1)  # subsets of {1..m-1}
    INF = float("inf")
    dp = np.full((full, m - 1), INF)
    parent = np.full((full, m - 1), -1, dtype=np.int64)
    for j in range(m - 1):
        dp[1 << j, j] = d[0, j + 1]
    for mask in range(full):
        for j in range(m - 1):
            cur = dp[mask, j]
            if not np.isfinite(cur):
                continue
            for nxt in range(m - 1):
                if mask & (1 << nxt):
                    continue
                nm = mask | (1 << nxt)
                nd = cur + d[j + 1, nxt + 1]
                if nd < dp[nm, nxt]:
                    dp[nm, nxt] = nd
                    parent[nm, nxt] = j
    best, bj = INF, -1
    last_mask = full - 1
    for j in range(m - 1):
        tot = dp[last_mask, j] + d[j + 1, 0]
        if tot < best:
            best, bj = tot, j
    # reconstruct
    order = [bj + 1]
    mask = last_mask
    j = bj
    while True:
        pj = parent[mask, j]
        if pj < 0:
            break
        mask ^= 1 << j
        order.append(pj + 1)
        j = pj
    order.append(0)
    order.reverse()
    return order, float(best)


def nearest_neighbor_tour(points: np.ndarray, start: int = 0) -> tuple[list[int], float]:
    m = len(points)
    d = _dist_matrix(points)
    unvisited = set(range(m)) - {start}
    order = [start]
    while unvisited:
        last = order[-1]
        nxt = min(unvisited, key=lambda j: d[last, j])
        order.append(nxt)
        unvisited.remove(nxt)
    length = sum(d[order[i], order[i + 1]] for i in range(m - 1)) + d[order[-1], order[0]]
    return order, float(length)


def two_opt(points: np.ndarray, order: list[int], *, max_pass: int = 20) -> tuple[list[int], float]:
    d = _dist_matrix(points)
    order = order[:]
    m = len(order)

    def tour_len(o):
        return sum(d[o[i], o[(i + 1) % m]] for i in range(m))

    improved = True
    passes = 0
    while improved and passes < max_pass:
        improved = False
        passes += 1
        for i in range(1, m - 1):
            for k in range(i + 1, m):
                a, b = order[i - 1], order[i]
                c, e = order[k], order[(k + 1) % m]
                if d[a, c] + d[b, e] < d[a, b] + d[c, e] - 1e-12:
                    order[i:k + 1] = reversed(order[i:k + 1])
                    improved = True
    return order, float(tour_len(order))


def solve_tsp(points: np.ndarray, *, exact_limit: int = 16) -> tuple[list[int], float]:
    """Exact for small instances (the paper's regime), NN+2opt beyond.

    The fallback seeds 2-opt with the best nearest-neighbour tour over
    several start nodes (all of them up to 64 points, then a spread of 16)
    instead of always starting at node 0 — NN tour quality swings hard with
    the start, and the seed bounds the result: the returned cycle is never
    longer than the best seeding NN tour (and hence never longer than any
    single-start greedy baseline we improve on). Deterministic.
    """
    m = len(points)
    if m <= exact_limit:
        return held_karp(points)
    starts = range(m) if m <= 64 else range(0, m, max(m // 16, 1))
    order, _ = min((nearest_neighbor_tour(points, start=s) for s in starts),
                   key=lambda t: t[1])
    # 2-opt only ever applies improving moves, so the result is bounded by
    # the seed: <= best sampled NN tour <= the start-0 NN tour (m <= 64)
    return two_opt(points, order)


@dataclasses.dataclass
class TourPlan:
    order: list[int]          # tour over edge devices (indices into edge coords)
    tour_length: float        # cycle length D_pi [m]
    rounds: int               # gamma
    e_per_round: float        # J
    e_first: float            # J (base -> first device + full round)
    e_return: float           # J (last device -> base)
    total_energy: float       # J actually consumed for `rounds` rounds + return


def budget_rounds(beta: float, e_first: float, e_pi: float,
                  e_return: float) -> tuple[int, float]:
    """Closed form of Algorithm 2's budget loop (delayed-return strategy).

    The UAV flies base -> first device + one full round (``e_first``), then
    keeps adding ``e_pi``-cost rounds while it can still afford the return
    leg: ``gamma = 1 + floor((beta - e_first - e_return) / e_pi)``.
    Returns (rounds, total_energy_consumed); (0, 0.0) when even one round
    plus the return leg busts the budget.
    """
    if e_first + e_return > beta:
        return 0, 0.0
    extra = int(math.floor((beta - e_first - e_return) / e_pi)) if e_pi > 0 else 0
    rounds = 1 + max(extra, 0)
    return rounds, e_first + (rounds - 1) * e_pi + e_return


def _plan_from_order(order: list[int], d_pi: float, edge_coords: np.ndarray,
                     base: np.ndarray, params: UAVParams,
                     hover_s_per_stop: float, comm_s_per_stop: float) -> TourPlan:
    """Energy bookkeeping shared by the exact and greedy planners."""
    m = len(edge_coords)
    # per-round energy: movement + per-stop hover & comm (Alg. 2 line 6)
    e_pi = (d_pi / params.V) * params.xi_m() \
        + m * (hover_s_per_stop * params.xi_h + comm_s_per_stop * params.xi_c)
    first_dev = edge_coords[order[0]]
    last_dev = edge_coords[order[-1]]
    e_first = (np.linalg.norm(base - first_dev) / params.V) * params.xi_m() + e_pi
    e_return = (np.linalg.norm(last_dev - base) / params.V) * params.xi_m()
    rounds, total = budget_rounds(params.beta, e_first, e_pi, e_return)
    return TourPlan(order=order, tour_length=d_pi, rounds=rounds, e_per_round=e_pi,
                    e_first=e_first, e_return=e_return, total_energy=total)


def plan_tour(edge_coords: np.ndarray, base: np.ndarray, *,
              params: UAVParams = DEFAULT_UAV,
              hover_s_per_stop: float = 30.0, comm_s_per_stop: float = 10.0,
              exact_limit: int = 16) -> TourPlan:
    """Algorithm 2, including the delayed-return strategy."""
    order, d_pi = solve_tsp(edge_coords, exact_limit=exact_limit)
    return _plan_from_order(order, d_pi, edge_coords, base, params,
                            hover_s_per_stop, comm_s_per_stop)


def greedy_tour_plan(edge_coords: np.ndarray, base: np.ndarray, *,
                     params: UAVParams = DEFAULT_UAV,
                     hover_s_per_stop: float = 30.0,
                     comm_s_per_stop: float = 10.0) -> TourPlan:
    """Baseline: greedy nearest-neighbour visiting order (paper §IV-A:
    'the UAV follows a greedy approach to visit the edge devices')."""
    # start from device nearest to base
    start = int(np.linalg.norm(edge_coords - base, axis=-1).argmin())
    order, d_pi = nearest_neighbor_tour(edge_coords, start=start)
    return _plan_from_order(order, d_pi, edge_coords, base, params,
                            hover_s_per_stop, comm_s_per_stop)
