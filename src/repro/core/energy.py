"""EnergyTracker + cross-hardware scaling (paper Eq. 9) + CO2 accounting.

The container is CPU-only, so client/server compute time is derived
analytically from a roofline over counted FLOPs/bytes — mirroring the
paper's own methodology, which scales measured A5000 times to a Jetson via
hardware-ratio exponents (Eq. 9). Here the "source" measurement is the
analytic roofline time on the server profile; Eq. 9 scales it to the edge
profile. Powers convert time to Joules, and grid carbon intensity converts
energy to grams of CO2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    fp32_tflops: float        # FP32 throughput [TFLOP/s]
    mem_bw_gbs: float         # memory bandwidth [GB/s]
    tensor_tflops: float      # tensor-core/bf16 throughput [TFLOP/s]
    cpu_passmark: float
    power_w: float            # board power while busy [W]
    idle_power_w: float = 10.0


# Paper §IV-C / §IV-D profiles
RTX_A5000 = HardwareProfile("rtx_a5000", fp32_tflops=27.8, mem_bw_gbs=768.0,
                            tensor_tflops=216.0, cpu_passmark=35000.0,
                            power_w=230.0, idle_power_w=25.0)
JETSON_AGX_ORIN = HardwareProfile("jetson_agx_orin", fp32_tflops=2.7,
                                  mem_bw_gbs=51.2, tensor_tflops=21.6,
                                  cpu_passmark=2500.0, power_w=40.0,
                                  idle_power_w=5.0)
# TPU v5e — the dry-run target (bf16 peak; HBM bw; used by the roofline layer)
TPU_V5E = HardwareProfile("tpu_v5e", fp32_tflops=98.5, mem_bw_gbs=819.0,
                          tensor_tflops=197.0, cpu_passmark=20000.0,
                          power_w=200.0, idle_power_w=50.0)

# paper: CO2 proportional to energy; US-average grid ~0.474 kgCO2/kWh =>
# g per Joule:
CO2_G_PER_J = 0.474 * 1000.0 / 3.6e6


def scale_time(t_src_s: float, src: HardwareProfile, tgt: HardwareProfile, *,
               w1: float = 1.0, w2: float = 0.5, w3: float = 0.8, w4: float = 0.3,
               sf: float = 1.0, of: float = 1.0) -> float:
    """Paper Eq. (9): exponent-weighted hardware-ratio scaling."""
    return (t_src_s
            * (src.fp32_tflops / tgt.fp32_tflops) ** w1
            * (src.mem_bw_gbs / tgt.mem_bw_gbs) ** w2
            * (src.tensor_tflops / tgt.tensor_tflops) ** w3
            * (src.cpu_passmark / tgt.cpu_passmark) ** w4
            * sf * of)


def roofline_time(flops: float, bytes_moved: float, hw: HardwareProfile,
                  *, use_tensor: bool = True) -> float:
    """max(compute, memory) time [s] on `hw` for a kernel of given counts."""
    peak = (hw.tensor_tflops if use_tensor else hw.fp32_tflops) * 1e12
    t_c = flops / peak
    t_m = bytes_moved / (hw.mem_bw_gbs * 1e9)
    return max(t_c, t_m)


@dataclasses.dataclass
class EnergyRecord:
    label: str
    time_s: float
    energy_j: float
    co2_g: float


class EnergyTracker:
    """Algorithm 3's EnergyTracker: accumulates per-phase time/energy/CO2.

    ``track(label, flops, bytes)`` derives time analytically on the tracker's
    hardware profile; ``track_time(label, t)`` records an externally-supplied
    duration (e.g. a measured CPU run scaled via Eq. 9).
    """

    def __init__(self, hw: HardwareProfile, *, use_tensor: bool = True):
        self.hw = hw
        self.use_tensor = use_tensor
        self.records: list[EnergyRecord] = []

    def track(self, label: str, flops: float, bytes_moved: float) -> EnergyRecord:
        t = roofline_time(flops, bytes_moved, self.hw, use_tensor=self.use_tensor)
        return self.track_time(label, t)

    def track_time(self, label: str, t: float, *, count: int = 1) -> EnergyRecord:
        """Record ``count`` repetitions of a ``t``-second phase as one entry
        (the scanned trainers account a whole round of identical steps at
        once instead of per-step host round-trips)."""
        t = t * count
        e = t * self.hw.power_w
        rec = EnergyRecord(label=label, time_s=t, energy_j=e, co2_g=e * CO2_G_PER_J)
        self.records.append(rec)
        return rec

    def total(self) -> EnergyRecord:
        t = sum(r.time_s for r in self.records)
        e = sum(r.energy_j for r in self.records)
        return EnergyRecord(label="total", time_s=t, energy_j=e, co2_g=e * CO2_G_PER_J)

    def by_prefix(self, prefix: str) -> EnergyRecord:
        rs = [r for r in self.records if r.label.startswith(prefix)]
        t = sum(r.time_s for r in rs)
        e = sum(r.energy_j for r in rs)
        return EnergyRecord(label=prefix, time_s=t, energy_j=e, co2_g=e * CO2_G_PER_J)
