"""Split learning core — paper Algorithm 3 (SplitFed pattern), generalized.

Two composition styles are supported:

1. **Stage lists** (heterogeneous stacks — the paper's CNNs): a model is a
   list of ``Stage(init, apply, name)``; ``partition_stages`` cuts it into
   client/server prefix/suffix at a layer fraction. Used by the faithful
   reproduction benches.

2. **Stacked blocks** (homogeneous transformer stacks, scan-over-layers):
   block params carry a leading n_layers axis; ``split_stack`` slices that
   axis at the cut index. Used by the 10 assigned architectures, where the
   cut is additionally a sharding boundary (client prefix: pure DP; server
   suffix: DP x TP) — see DESIGN.md §3.

The split train step is ONE differentiable program: client forward ->
(link: sharding-constraint boundary whose bytes = smashed data L) -> server
forward + loss; ``jax.grad`` over (params_c, params_s) yields exactly the
distributed backward of Algorithm 3. The U-shaped variant keeps labels (and
the final head) on the client so labels never cross the link.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# 1. stage lists (CNN repro)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    init: Callable[[jax.Array], Params]
    apply: Callable[[Params, jax.Array], jax.Array]
    # relative depth weight for cut placement (a "stage" may hold several
    # paper-layers, e.g. a ResNet group of 2 blocks)
    depth: int = 1


def init_stages(key: jax.Array, stages: Sequence[Stage]) -> list[Params]:
    keys = jax.random.split(key, len(stages))
    return [s.init(k) for s, k in zip(stages, keys)]


def apply_stages(stages: Sequence[Stage], params: Sequence[Params], x: jax.Array) -> jax.Array:
    for s, p in zip(stages, params):
        x = s.apply(p, x)
    return x


def cut_index_for_fraction(stages: Sequence[Stage], client_fraction: float) -> int:
    """Smallest prefix whose depth-share >= client_fraction (paper's SL_{a,b}:
    client holds a% of layers). Always leaves >=1 stage per side."""
    total = sum(s.depth for s in stages)
    acc = 0
    for i, s in enumerate(stages):
        acc += s.depth
        if acc / total >= client_fraction - 1e-9:
            return min(max(i + 1, 1), len(stages) - 1)
    return len(stages) - 1


def partition_stages(stages: Sequence[Stage], params: Sequence[Params],
                     client_fraction: float) -> tuple[list, list, list, list, int]:
    """Returns (client_stages, client_params, server_stages, server_params, k)."""
    k = cut_index_for_fraction(stages, client_fraction)
    return list(stages[:k]), list(params[:k]), list(stages[k:]), list(params[k:]), k


# ---------------------------------------------------------------------------
# 2. stacked blocks (transformers; scan-over-layers)
# ---------------------------------------------------------------------------

def split_stack(stacked: Params, k: int) -> tuple[Params, Params]:
    """Slice every leaf's leading (layer) axis at k."""
    client = jax.tree_util.tree_map(lambda x: x[:k], stacked)
    server = jax.tree_util.tree_map(lambda x: x[k:], stacked)
    return client, server


def merge_stack(client: Params, server: Params) -> Params:
    return jax.tree_util.tree_map(lambda a, b: jnp.concatenate([a, b], axis=0),
                                  client, server)


def stack_cut_index(n_layers: int, client_fraction: float,
                    *, max_client: Optional[int] = None) -> int:
    """Cut index for a homogeneous stack; optionally clamped (e.g. MoE archs
    force the cut at/below the first MoE layer — experts can't live on the
    edge tier, DESIGN.md §4)."""
    k = max(1, min(n_layers - 1, int(math.ceil(client_fraction * n_layers))))
    if max_client is not None:
        k = min(k, max(1, max_client))
    return k


# ---------------------------------------------------------------------------
# split train/eval steps (differentiable end-to-end)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SplitStep:
    """Builds jit-able split-learning steps from client/server apply fns.

    client_fwd(params_c, inputs)            -> smashed
    server_loss(params_s, smashed, targets) -> (loss, aux)
    For the U-shaped variant additionally:
    server_body(params_s, smashed)          -> features   (no labels server-side)
    client_head_loss(params_c, feats, tgts) -> (loss, aux)
    """
    client_fwd: Callable
    server_loss: Optional[Callable] = None
    server_body: Optional[Callable] = None
    client_head_loss: Optional[Callable] = None
    link_constraint: Optional[Callable] = None  # smashed -> smashed (sharding)
    variant: str = "vanilla"  # "vanilla" | "ushaped"
    # metrics-bus taps computed inside the step (they need the smashed
    # tensor): subset of {"smashed_mean","smashed_std","smashed_absmax",
    # "quant_error"}, carried out through aux["taps"]. Empty = the exact
    # tap-free trace.
    taps: tuple = ()

    def loss_fn(self, params_c, params_s, batch):
        inputs, targets = batch["inputs"], batch["targets"]
        raw_smashed = smashed = self.client_fwd(params_c, inputs)
        if self.link_constraint is not None:
            smashed = self.link_constraint(smashed)
        if self.variant == "vanilla":
            loss, aux = self.server_loss(params_s, smashed, targets)
        elif self.variant == "ushaped":
            feats = self.server_body(params_s, smashed)
            if self.link_constraint is not None:
                feats = self.link_constraint(feats)
            loss, aux = self.client_head_loss(params_c, feats, targets)
        else:
            raise ValueError(self.variant)
        aux = dict(aux)
        aux["smashed_elems"] = jnp.asarray(
            sum(x.size for x in jax.tree_util.tree_leaves(smashed)), jnp.float32)
        if self.taps:
            from ..obs.metrics import smashed_tap_values
            aux["taps"] = smashed_tap_values(self.taps, raw_smashed, smashed)
        return loss, aux

    def grads(self, params_c, params_s, batch):
        (loss, aux), (g_c, g_s) = jax.value_and_grad(
            self.loss_fn, argnums=(0, 1), has_aux=True)(params_c, params_s, batch)
        return loss, aux, g_c, g_s


def make_split_train_step(step: SplitStep, opt_c, opt_s):
    """Returns f(params_c, params_s, oc, os, batch) -> (params_c, params_s, oc, os, metrics)."""
    from ..optim.optimizers import apply_updates

    def train_step(params_c, params_s, oc, os_, batch):
        loss, aux, g_c, g_s = step.grads(params_c, params_s, batch)
        up_c, oc = opt_c.update(g_c, oc, params_c)
        up_s, os_ = opt_s.update(g_s, os_, params_s)
        params_c = apply_updates(params_c, up_c)
        params_s = apply_updates(params_s, up_s)
        metrics = {"loss": loss, **aux}
        return params_c, params_s, oc, os_, metrics

    return train_step


# ---------------------------------------------------------------------------
# multi-client engine (faithful Algorithm 3 + the FL baseline), device-resident
# ---------------------------------------------------------------------------
#
# Both round builders below compile one *global* round into a single XLA
# program: per-client params/opt-states/minibatches carry a leading client
# axis, the round is nested ``lax.scan``s over (local steps x clients), and
# FedAvg (Alg. 3 line 19) happens inside the compiled program — no host
# round-trips between steps. Callers jit them with donated state buffers.

def make_multi_client_round(step: SplitStep, opt_c, opt_s, *, local_rounds: int,
                            taps: tuple = ()):
    """One global round of Algorithm 3 over an explicit client axis.

    params_c carries a leading client axis; the single server model is
    shared — the UAV visits clients one at a time, so server updates are
    sequential per client batch (inner scan over clients), matching Alg. 3's
    inner loop; the outer scan runs the ``local_rounds`` visits. After the
    visits, client params are FedAvg'd (leading-axis mean) and re-broadcast,
    all inside the one compiled round.

    ``batches`` is a pytree with leading (clients, local_rounds) axes;
    returned losses have shape (local_rounds, clients).

    ``taps`` enables the metrics bus (``repro.obs.metrics``): the round
    additionally returns a dict of float32 tap stacks, every leaf
    (local_rounds, clients) — the server updates once per client visit
    here, so even the server-tier taps are per-client. Empty taps lowers
    the exact tap-free program (the conditionals below are trace-time).
    """
    from ..obs.metrics import step_taps
    from ..optim.optimizers import apply_updates
    from .fedavg import fedavg_stack

    def one_client_update(carry, client_state):
        params_s, os_ = carry
        params_c, oc, batch = client_state
        loss, aux, g_c, g_s = step.grads(params_c, params_s, batch)
        up_c, oc = opt_c.update(g_c, oc, params_c)
        params_c = apply_updates(params_c, up_c)
        up_s, os_ = opt_s.update(g_s, os_, params_s)
        params_s = apply_updates(params_s, up_s)
        if taps:
            t = step_taps(taps, loss=loss, aux_taps=aux.get("taps"),
                          g_c=g_c, g_s=g_s, up_c=up_c, up_s=up_s)
            return (params_s, os_), (params_c, oc, loss, t)
        return (params_s, os_), (params_c, oc, loss)

    def global_round(params_c_stack, params_s, oc_stack, os_, batches):
        # (clients, local_rounds) -> scan over rounds, inner scan over clients
        batches_rm = jax.tree_util.tree_map(
            lambda x: jnp.swapaxes(x, 0, 1), batches)

        def round_body(carry, batch_r):
            params_c_stack, oc_stack, params_s, os_ = carry
            (params_s, os_), stacked = jax.lax.scan(
                one_client_update, (params_s, os_),
                (params_c_stack, oc_stack, batch_r))
            if taps:
                params_c_stack, oc_stack, loss_c, t = stacked
                out = (loss_c, t)
            else:
                params_c_stack, oc_stack, loss_c = stacked
                out = loss_c
            return (params_c_stack, oc_stack, params_s, os_), out

        carry = (params_c_stack, oc_stack, params_s, os_)
        carry, out = jax.lax.scan(round_body, carry, batches_rm)
        params_c_stack, oc_stack, params_s, os_ = carry
        # FedAvg of client sub-models (Alg. 3 line 19)
        params_c_stack = fedavg_stack(params_c_stack)
        if taps:
            losses, tap_stack = out
            return params_c_stack, params_s, oc_stack, os_, losses, tap_stack
        return params_c_stack, params_s, oc_stack, os_, out

    return global_round


def make_fl_round(grad_fn: Callable, opt, *, client_axis: str = "scan",
                  aggregate: bool = True, taps: tuple = ()):
    """One global round of the FL baseline over an explicit client axis.

    ``grad_fn(params, batch) -> (loss, grads)`` on the full model. Each
    client starts the round from the shared global params with a fresh
    optimizer state (the paper's per-round local training), runs its local
    minibatches via the inner scan, and the round ends with FedAvg of the
    client models — all one compiled program.

    ``client_axis`` picks how the independent clients are laid out:

      "scan" — sequential ``lax.scan`` over clients. Bit-compatible with the
               per-client host loop it replaced (1e-4 equivalence bound).
      "vmap" — clients batched into one SPMD program. Faster (the client
               axis becomes a data-parallel batch dim XLA can fuse and the
               fleet layer can shard over the ``data`` mesh axis), but
               batched convs/reductions reassociate fp32 arithmetic, so
               equivalence to the scan/host reference holds only to the
               loosened ``repro.fleet.engine.FLEET_EQUIV_ATOL`` tolerance.
               The measured steps/s delta is recorded by
               ``benchmarks/bench_engine_perf.py``.

    ``batches`` is a pytree with leading (clients, local_steps) axes;
    returns (new_global_params, losses[clients, local_steps]). With
    ``aggregate=False`` the FedAvg reduction is skipped and the raw
    client-stacked models are returned instead (the fleet layer's dropout
    path aggregates with a per-round client mask).

    The round is STATELESS in the client axis: every client starts from
    ``global_params`` with a fresh optimizer state, so the leading batch
    axis is a *cohort* axis, not a resident-fleet axis — feeding K
    cohort-gathered batch rows sampled from a population of M >> K clients
    (``ClientSpec.population``) runs the identical program with engine
    state O(1) in M (just the global params).

    ``taps`` enables the metrics bus (``repro.obs.metrics``): the round
    additionally returns a dict of float32 tap stacks, every leaf laid out
    (clients, local_steps) like the losses. FL has one tier, so only the
    client-side channels (grad/update norm, nonfinite) apply. Empty taps
    lowers the exact tap-free program (the conditionals are trace-time).
    """
    from ..obs.metrics import step_taps
    from ..optim.optimizers import apply_updates
    from .fedavg import fedavg_mean

    def global_round(global_params, batches):
        opt_state0 = opt.init(global_params)

        def local_step(carry, batch):
            params, opt_state = carry
            loss, grads = grad_fn(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params)
            new_carry = (apply_updates(params, updates), opt_state)
            if taps:
                t = step_taps(taps, loss=loss, g_c=grads, up_c=updates)
                return new_carry, (loss, t)
            return new_carry, loss

        def per_client(batch_c):
            (params, _), out = jax.lax.scan(
                local_step, (global_params, opt_state0), batch_c)
            return params, out

        if client_axis == "vmap":
            client_stack, out = jax.vmap(per_client)(batches)
        elif client_axis == "scan":
            _, (client_stack, out) = jax.lax.scan(
                lambda _, b: (None, per_client(b)), None, batches)
        else:
            raise ValueError(f"client_axis must be 'scan' or 'vmap', "
                             f"got {client_axis!r}")
        losses, tap_stack = out if taps else (out, None)
        agg = client_stack if not aggregate else fedavg_mean(client_stack)
        if taps:
            return agg, losses, tap_stack
        return agg, losses

    return global_round
