"""Adaptive split-point selection (the paper's §V future work, implemented).

Given a model (CNN stage list or transformer ArchConfig), enumerate cut
points and pick the one minimizing *client-side energy per batch*:

    E(cut) = T_client(cut) * P_edge + T_link(cut) * P_radio

where T_client comes from an XLA-counted-FLOPs roofline on the edge
profile (paper Eq. 9 methodology) and T_link = L/R (Eq. 8) with the
smashed-data bytes L of that cut (optionally int8-compressed). An optional
``min_client_layers`` floor models the privacy constraint (raw data must
not leave the device, so at least one layer stays).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax

from ..models.transformer import build_groups  # noqa: F401 (API surface)
from .energy import (HardwareProfile, JETSON_AGX_ORIN, RTX_A5000, scale_time)
from .link import LinkConfig
from .split import Stage


@dataclasses.dataclass(frozen=True)
class CutChoice:
    cut_index: int
    client_fraction: float
    client_flops: float
    smashed_bytes: int
    t_client_s: float
    t_link_s: float
    energy_j: float


def profile_cuts_cnn(stages: Sequence[Stage], params, x,
                     *, edge: HardwareProfile = JETSON_AGX_ORIN,
                     link: Optional[LinkConfig] = None,
                     min_client_layers: int = 1,
                     bwd_factor: float = 3.0) -> list[CutChoice]:
    """Energy profile for every admissible cut of a CNN stage list.

    One chained pass: per-stage FLOPs are counted analytically from each
    stage's jaxpr (``repro.core.flops.jaxpr_flops`` — exact on the convs
    that dominate) on the activation shape flowing out of the previous
    stage, and prefix FLOPs are the running sum. This never silently
    degenerates to 0 (the old XLA-only counter did on backends without
    ``cost_analysis``) and profiles all cuts without compiling
    ``len(stages)`` growing prefixes.
    """
    from .flops import jaxpr_flops

    link = link or LinkConfig()
    total_depth = sum(s.depth for s in stages)
    # chain activations through the stages once, accumulating fwd FLOPs
    act = jax.ShapeDtypeStruct(x.shape, x.dtype)
    cum_flops, smashed_after = [], []
    running = 0.0
    for s, p in zip(stages, params):
        running += jaxpr_flops(s.apply, p, act)
        act = jax.eval_shape(s.apply, p, act)
        cum_flops.append(running)
        smashed_after.append(act)
    out = []
    for k in range(min_client_layers, len(stages)):
        fwd = cum_flops[k - 1]
        smashed = smashed_after[k - 1]
        sm_bytes = int(smashed.size) * smashed.dtype.itemsize
        # edge time: fwd + bwd of the prefix, scaled per Eq. 9 methodology
        t_src = bwd_factor * fwd / (RTX_A5000.fp32_tflops * 1e12)
        t_client = scale_time(t_src, RTX_A5000, edge)
        t_link = link.transfer_time_s(2 * sm_bytes, smashed.dtype.itemsize)
        e = t_client * edge.power_w + t_link * link.radio_power_w
        out.append(CutChoice(
            cut_index=k,
            client_fraction=sum(s.depth for s in stages[:k]) / total_depth,
            client_flops=fwd, smashed_bytes=sm_bytes,
            t_client_s=t_client, t_link_s=t_link, energy_j=e))
    return out


def select_cut(choices: Sequence[CutChoice], *,
               max_link_s: Optional[float] = None) -> CutChoice:
    """Minimum-energy cut, optionally subject to a per-round link deadline
    (the UAV hover window from Algorithm 2)."""
    admissible = [c for c in choices
                  if max_link_s is None or c.t_link_s <= max_link_s]
    if not admissible:
        # fall back: the fastest-link cut even if over deadline
        return min(choices, key=lambda c: c.t_link_s)
    return min(admissible, key=lambda c: c.energy_j)


def profile_cuts_transformer(cfg, *, batch: int, seq: int,
                             edge: HardwareProfile = JETSON_AGX_ORIN,
                             link: Optional[LinkConfig] = None,
                             bwd_factor: float = 3.0) -> list[CutChoice]:
    """Analytic cut profile for a transformer ArchConfig: client layers are
    homogeneous, so per-layer FLOPs ~ 6*params_layer*tokens/3 (fwd) and the
    smashed tensor is always (batch, seq, d_model)."""
    link = link or LinkConfig()
    tokens = batch * seq
    d = cfg.d_model
    # per-layer fwd flops (dense approx; MoE uses active experts)
    if cfg.ssm_kind == "rwkv6":
        layer_params = 5 * d * d + 2 * d * cfg.d_ff + d * d
    else:
        layer_params = (d * cfg.n_heads * cfg.hd
                        + 2 * d * cfg.n_kv_heads * cfg.hd
                        + cfg.n_heads * cfg.hd * d)
        if cfg.n_experts:
            layer_params += cfg.top_k * 3 * d * (cfg.moe_d_ff or cfg.d_ff)
        else:
            layer_params += 3 * d * cfg.d_ff
    sm_bytes = tokens * d * (2 if cfg.dtype == "bfloat16" else 4)
    out = []
    n = cfg.n_enc_layers if cfg.enc_dec else cfg.n_layers
    for k in range(1, n):
        fwd = 2.0 * k * layer_params * tokens
        t_src = bwd_factor * fwd / (RTX_A5000.fp32_tflops * 1e12)
        t_client = scale_time(t_src, RTX_A5000, edge)
        t_link = link.transfer_time_s(2 * sm_bytes, 2)
        e = t_client * edge.power_w + t_link * link.radio_power_w
        out.append(CutChoice(cut_index=k, client_fraction=k / n,
                             client_flops=fwd, smashed_bytes=sm_bytes,
                             t_client_s=t_client, t_link_s=t_link,
                             energy_j=e))
    return out
