"""FedAvg aggregation (paper Algorithm 3, line 19: theta_agg = mean_e theta_e).

Three representations:
- list of per-client pytrees        -> ``fedavg`` (weighted mean)
- explicit client axis (leading dim) -> ``fedavg_mean`` (drop the axis) /
  ``fedavg_stack`` (mean + rebroadcast), with ``*_masked`` variants that
  exclude dropped-out clients (P3SL straggler semantics).
- SPMD over a mesh axis -> the ``fedavg_pmean*`` family, for use INSIDE a
  ``shard_map`` body: each device holds a (local_clients, ...) slice of the
  client stack; the global FedAvg is a local reduction composed with a
  ``lax.pmean``/``lax.psum`` over the named mesh axis, so the collective
  schedule is explicit in the program (no GSPMD inference). The masked
  variants ``psum`` the masked sums and the active count, so dropout
  semantics survive the collective exactly as in the host-side versions.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(client_params: Sequence, weights: Optional[Sequence[float]] = None):
    n = len(client_params)
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]

    def mean_leaf(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + wi * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(mean_leaf, *client_params)


def fedavg_mean(stacked_params):
    """Mean over a leading client axis, dropping the axis (one global model).

    The counterpart of ``fedavg`` for the stacked representation the scanned
    multi-client engine uses; ``fedavg_stack`` keeps/rebroadcasts the axis.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
        stacked_params)


def fedavg_stack(stacked_params):
    """Mean over a leading client axis, rebroadcast to every client."""
    def agg(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree_util.tree_map(agg, stacked_params)


def fedavg_stack_masked(stacked_params, mask):
    """FedAvg over the ACTIVE rows of a leading client axis.

    ``mask`` is a (clients,) 0/1 vector (traced — changes per round without
    recompiling). Active clients receive the mean of the active rows;
    dropped clients keep their stale row (P3SL straggler semantics: a
    client that missed the round rejoins from its last local state). When
    every client is masked out the stack passes through unchanged.
    """
    mask = jnp.asarray(mask, jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)

    def agg(x):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        avg = (x.astype(jnp.float32) * w).sum(axis=0, keepdims=True) / total
        out = jnp.where(w > 0, jnp.broadcast_to(avg, x.shape),
                        x.astype(jnp.float32))
        return out.astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params)


def fedavg_mean_masked(stacked_params, mask, fallback):
    """Mean over the active rows, dropping the client axis; returns
    ``fallback`` (the incoming global model) when no client is active."""
    mask = jnp.asarray(mask, jnp.float32)
    total = mask.sum()

    def agg(x, fb):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        avg = (x.astype(jnp.float32) * w).sum(axis=0) / jnp.maximum(total, 1.0)
        return jnp.where(total > 0, avg, fb.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params, fallback)


def fedavg_pmean(stacked_params, axis_name: str):
    """SPMD FedAvg inside a ``shard_map`` body: mean over the local leading
    client axis composed with ``lax.pmean`` over ``axis_name``, dropping the
    client axis (one replicated global model). Equal local client counts per
    shard (``validate_fleet_mesh``) make local-mean + pmean the exact global
    mean.

    CONTRACT CHANGE (PR 4): this used to be a per-leaf ``lax.pmean`` with no
    local reduction. It now expects a client-STACKED local shard — passing
    an unstacked params tree silently drops each leaf's leading dim. No
    in-repo caller used the old form; external callers must re-stack."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.pmean(
            jnp.mean(x.astype(jnp.float32), axis=0), axis_name).astype(x.dtype),
        stacked_params)


def fedavg_pmean_masked(stacked_params, mask, fallback, axis_name: str):
    """``fedavg_mean_masked`` inside a ``shard_map`` body: the masked sums
    and the active-client count are ``psum``'d over ``axis_name``, so every
    shard computes the same global mean of the ACTIVE rows; when no client
    anywhere is active, ``fallback`` (the incoming global model) passes
    through."""
    mask = jnp.asarray(mask, jnp.float32)
    total = jax.lax.psum(mask.sum(), axis_name)

    def agg(x, fb):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        s = jax.lax.psum((x.astype(jnp.float32) * w).sum(axis=0), axis_name)
        avg = s / jnp.maximum(total, 1.0)
        return jnp.where(total > 0, avg, fb.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params, fallback)


def fedavg_pmean_stack(stacked_params, axis_name: str):
    """``fedavg_stack`` inside a ``shard_map`` body: global mean over
    (local axis x mesh axis), rebroadcast to every local client row."""
    def agg(x):
        m = jax.lax.pmean(jnp.mean(x.astype(jnp.float32), axis=0,
                                   keepdims=True), axis_name)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree_util.tree_map(agg, stacked_params)


def fedavg_pmean_stack_masked(stacked_params, mask, axis_name: str):
    """``fedavg_stack_masked`` inside a ``shard_map`` body: active rows get
    the global mean of all active rows (masked ``psum``), dropped rows keep
    their stale value; an all-masked fleet passes through unchanged."""
    mask = jnp.asarray(mask, jnp.float32)
    total = jnp.maximum(jax.lax.psum(mask.sum(), axis_name), 1.0)

    def agg(x):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        s = jax.lax.psum((x.astype(jnp.float32) * w).sum(axis=0,
                                                         keepdims=True),
                         axis_name)
        avg = s / total
        out = jnp.where(w > 0, jnp.broadcast_to(avg, x.shape),
                        x.astype(jnp.float32))
        return out.astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params)
