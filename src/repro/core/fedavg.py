"""FedAvg aggregation (paper Algorithm 3, line 19: theta_agg = mean_e theta_e).

Two representations:
- explicit client axis (leading dim) -> ``fedavg_stack`` (mean + rebroadcast)
- list of per-client pytrees        -> ``fedavg`` (weighted mean)
In the SPMD mapping, FedAvg over the `data` mesh axis is a pmean — provided
as ``fedavg_pmean`` for use inside shard_map'd steps.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(client_params: Sequence, weights: Optional[Sequence[float]] = None):
    n = len(client_params)
    if weights is None:
        w = [1.0 / n] * n
    else:
        tot = float(sum(weights))
        w = [float(x) / tot for x in weights]

    def mean_leaf(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + wi * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(mean_leaf, *client_params)


def fedavg_mean(stacked_params):
    """Mean over a leading client axis, dropping the axis (one global model).

    The counterpart of ``fedavg`` for the stacked representation the scanned
    multi-client engine uses; ``fedavg_stack`` keeps/rebroadcasts the axis.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
        stacked_params)


def fedavg_stack(stacked_params):
    """Mean over a leading client axis, rebroadcast to every client."""
    def agg(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree_util.tree_map(agg, stacked_params)


def fedavg_stack_masked(stacked_params, mask):
    """FedAvg over the ACTIVE rows of a leading client axis.

    ``mask`` is a (clients,) 0/1 vector (traced — changes per round without
    recompiling). Active clients receive the mean of the active rows;
    dropped clients keep their stale row (P3SL straggler semantics: a
    client that missed the round rejoins from its last local state). When
    every client is masked out the stack passes through unchanged.
    """
    mask = jnp.asarray(mask, jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)

    def agg(x):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        avg = (x.astype(jnp.float32) * w).sum(axis=0, keepdims=True) / total
        out = jnp.where(w > 0, jnp.broadcast_to(avg, x.shape),
                        x.astype(jnp.float32))
        return out.astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params)


def fedavg_mean_masked(stacked_params, mask, fallback):
    """Mean over the active rows, dropping the client axis; returns
    ``fallback`` (the incoming global model) when no client is active."""
    mask = jnp.asarray(mask, jnp.float32)
    total = mask.sum()

    def agg(x, fb):
        w = mask.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        avg = (x.astype(jnp.float32) * w).sum(axis=0) / jnp.maximum(total, 1.0)
        return jnp.where(total > 0, avg, fb.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(agg, stacked_params, fallback)


def fedavg_pmean(params, axis_name: str):
    """SPMD FedAvg: mean over a mesh axis (use inside shard_map)."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.pmean(x, axis_name), params)
