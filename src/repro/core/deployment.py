"""Algorithm 1 — Optimized Edge Device Deployment and Sensor Assignment (CSR).

Faithful implementation of the paper's greedy max-coverage deployment with
the min-total-distance tie-break, plus the two baselines it compares against
(K-means with K=floor(sqrt(N)) grown until feasible, and a GASBAC-style
balanced-clustering heuristic).

Coordinates are in meters. ``field_acres`` helpers convert the paper's farm
sizes (1 acre = 4046.86 m²; a square farm is assumed, as in Fig. 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

ACRE_M2 = 4046.8564224


def field_side_meters(acres: float) -> float:
    return math.sqrt(acres * ACRE_M2)


def uniform_grid_sensors(acres: float, n_sensors: int, *, jitter: float = 0.0,
                         seed: int = 0) -> np.ndarray:
    """Paper Fig. 2a/2c: uniform deployment at a fixed sensor density."""
    side = field_side_meters(acres)
    g = int(round(math.sqrt(n_sensors)))
    assert g * g == n_sensors, "uniform grid wants a square count (25/36/49 in the paper)"
    xs = (np.arange(g) + 0.5) * side / g
    pts = np.stack(np.meshgrid(xs, xs, indexing="ij"), axis=-1).reshape(-1, 2)
    if jitter > 0:
        rng = np.random.RandomState(seed)
        pts = pts + rng.uniform(-jitter, jitter, size=pts.shape)
    return pts


def random_sensors(acres: float, n_sensors: int, *, seed: int = 0) -> np.ndarray:
    """Paper Fig. 2b: random deployment."""
    side = field_side_meters(acres)
    rng = np.random.RandomState(seed)
    return rng.uniform(0, side, size=(n_sensors, 2))


# ---------------------------------------------------------------------------
# CSR adjacency (as the paper specifies)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CSRAdjacency:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (nnz,)

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]


def build_csr_adjacency(coords: np.ndarray, cr: float) -> CSRAdjacency:
    """A[s] = {u : d(s,u) <= CR} (self included — a device covers itself)."""
    d = np.linalg.norm(coords[:, None, :] - coords[None, :, :], axis=-1)
    adj = d <= cr
    indptr = np.zeros(len(coords) + 1, dtype=np.int64)
    cols = []
    for i in range(len(coords)):
        nb = np.where(adj[i])[0]
        cols.append(nb)
        indptr[i + 1] = indptr[i] + len(nb)
    return CSRAdjacency(indptr=indptr, indices=np.concatenate(cols) if cols else np.zeros(0, np.int64))


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Deployment:
    coords: np.ndarray           # (N,2) all sensors
    edge_indices: np.ndarray     # (M,) indices into coords chosen as edge devices
    assignment: np.ndarray       # (N,) sensor -> edge-device index (into edge_indices)
    cr: float

    @property
    def edge_coords(self) -> np.ndarray:
        return self.coords[self.edge_indices]

    @property
    def loads(self) -> np.ndarray:
        return np.bincount(self.assignment, minlength=len(self.edge_indices))


def deploy_edge_devices(coords: np.ndarray, cr: float) -> Deployment:
    """Algorithm 1: greedy max-coverage with min-distance tie-break + balanced
    sensor→edge assignment."""
    n = len(coords)
    csr = build_csr_adjacency(coords, cr)
    uncovered = set(range(n))
    edges: list[int] = []

    def dist_to_edges(s: int) -> float:
        if not edges:
            return 0.0
        e = coords[np.asarray(edges)]
        return float(np.linalg.norm(e - coords[s], axis=-1).sum())

    while uncovered:
        best_cov = 0
        best_s: Optional[int] = None
        best_dist = float("inf")
        # iterate over uncovered candidates (paper: for each s in U)
        for s in sorted(uncovered):
            cov = sum(1 for u in csr.neighbors(s) if u in uncovered)
            if not edges:
                if cov > best_cov:
                    best_cov, best_s = cov, s
            else:
                ds = dist_to_edges(s)
                # paper line 13: |C| >= best and strictly smaller total distance
                if cov > best_cov or (cov == best_cov and ds < best_dist):
                    best_cov, best_s, best_dist = cov, s, ds
        assert best_s is not None
        edges.append(best_s)
        for u in csr.neighbors(best_s):
            uncovered.discard(u)

    edge_arr = np.asarray(edges)

    # Lines 21-26: assignment minimizing load, tie-broken by distance.
    assignment = np.full(n, -1, dtype=np.int64)
    loads = np.zeros(len(edges), dtype=np.int64)
    # edge devices are assigned to themselves
    for j, e in enumerate(edges):
        assignment[e] = j
        loads[j] += 1
    order = np.argsort(np.linalg.norm(coords - coords.mean(0), axis=-1))  # deterministic order
    for s in order:
        if assignment[s] >= 0:
            continue
        cand = [j for j, e in enumerate(edges)
                if np.linalg.norm(coords[s] - coords[e]) <= cr]
        if not cand:  # shouldn't happen (coverage constraint) but stay safe
            cand = list(range(len(edges)))
        # minimal current load, then shortest distance
        cand.sort(key=lambda j: (loads[j], np.linalg.norm(coords[s] - coords[edges[j]])))
        assignment[s] = cand[0]
        loads[cand[0]] += 1
    return Deployment(coords=coords, edge_indices=edge_arr, assignment=assignment, cr=cr)


# ---------------------------------------------------------------------------
# Baselines: K-means and GASBAC-style balanced clustering
# ---------------------------------------------------------------------------

def deploy_kmeans(coords: np.ndarray, cr: float, *, seed: int = 0,
                  max_iter: int = 100) -> Deployment:
    """Paper baseline: K = floor(sqrt(N)), incremented while any sensor is
    outside CR of its cluster head (the sensor closest to the centroid)."""
    n = len(coords)
    k = int(math.floor(math.sqrt(n)))
    rng = np.random.RandomState(seed)
    while True:
        # Lloyd's algorithm
        centroids = coords[rng.choice(n, size=k, replace=False)].copy()
        for _ in range(max_iter):
            d = np.linalg.norm(coords[:, None] - centroids[None], axis=-1)
            lab = d.argmin(1)
            new = np.stack([coords[lab == j].mean(0) if np.any(lab == j) else centroids[j]
                            for j in range(k)])
            if np.allclose(new, centroids):
                break
            centroids = new
        # cluster head = sensor nearest to the centroid
        heads = []
        for j in range(k):
            members = np.where(lab == j)[0]
            if len(members) == 0:
                continue
            hd = members[np.linalg.norm(coords[members] - centroids[j], axis=-1).argmin()]
            heads.append(hd)
        heads = np.asarray(sorted(set(heads)))
        d_head = np.linalg.norm(coords[:, None] - coords[heads][None], axis=-1)
        if (d_head.min(1) <= cr).all() or k >= n:
            assignment = d_head.argmin(1)
            return Deployment(coords=coords, edge_indices=heads,
                              assignment=assignment, cr=cr)
        k += 1


def deploy_gasbac(coords: np.ndarray, cr: float, *, seed: int = 0) -> Deployment:
    """GASBAC-style balanced clustering [Nguyen et al. 2023], adapted to a
    single UAV as the paper does: energy-balance-driven cluster formation —
    clusters are grown to equal size around farthest-point-sampled seeds,
    heads re-selected at the cluster medoid."""
    n = len(coords)
    k = max(1, int(round(math.sqrt(n))))
    rng = np.random.RandomState(seed)
    # farthest point sampling for seeds (balanced spatial spread)
    seeds = [int(rng.randint(n))]
    for _ in range(k - 1):
        d = np.min(np.linalg.norm(coords[:, None] - coords[np.asarray(seeds)][None], axis=-1), axis=1)
        seeds.append(int(d.argmax()))
    target = int(math.ceil(n / k))
    assignment = np.full(n, -1, dtype=np.int64)
    loads = np.zeros(k, dtype=np.int64)
    # balanced assignment: iterate sensors by distance to nearest seed
    d_seed = np.linalg.norm(coords[:, None] - coords[np.asarray(seeds)][None], axis=-1)
    order = np.argsort(d_seed.min(1))
    for s in order:
        pref = np.argsort(d_seed[s])
        for j in pref:
            if loads[j] < target:
                assignment[s] = j
                loads[j] += 1
                break
    # medoid heads; ensure CR feasibility by splitting overlong clusters
    heads = []
    for j in range(k):
        members = np.where(assignment == j)[0]
        if len(members) == 0:
            continue
        dm = np.linalg.norm(coords[members][:, None] - coords[members][None], axis=-1)
        heads.append(int(members[dm.sum(1).argmin()]))
    heads = np.asarray(heads)
    d_head = np.linalg.norm(coords[:, None] - coords[heads][None], axis=-1)
    # sensors outside CR of their head get the closest head (best-effort, as
    # GASBAC optimizes energy balance, not strict coverage)
    assignment = d_head.argmin(1)
    return Deployment(coords=coords, edge_indices=heads, assignment=assignment, cr=cr)


def coverage_ok(dep: Deployment) -> bool:
    """Eq. (4): every sensor within CR of its assigned edge device."""
    d = np.linalg.norm(dep.coords - dep.edge_coords[dep.assignment], axis=-1)
    return bool((d <= dep.cr + 1e-9).all())
