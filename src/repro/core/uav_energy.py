"""Rotary-wing UAV energy model — paper Eqs. (1)-(2), Table I constants.

Power model from Zeng, Xu, Zhang (TWC 2019), parameterized for the DJI
Matrice 350 RTK as in the paper.

xi_m(V): propulsion power at forward speed V [W]
xi_h   : hover power [W]
xi_c   : communication power [W] (radio front-end while exchanging data)
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class UAVParams:
    # Table I
    beta: float = 1.9e6          # energy capacity [J]
    V: float = 10.0              # cruise speed [m/s]
    v0: float = 5.5              # mean induced velocity in hover [m/s]
    U_tip: float = 180.0         # rotor tip speed [m/s]
    f: float = 0.8               # fuselage drag ratio
    r: float = 0.08              # rotor solidity
    rho: float = 1.225           # air density [kg/m^3]
    a: float = 0.7               # rotor disc area [m^2]
    delta: float = 0.011         # profile drag coefficient
    omega: float = 320.0         # blade angular velocity [rad/s]
    R: float = 0.45              # rotor radius [m]
    k: float = 0.15              # induced power correction
    W: float = 63.4              # weight [N]
    xi_c: float = 20.0           # communication power [W] (radio, typical)
    altitude: float = 30.0       # flight altitude h [m]

    @property
    def P0(self) -> float:
        """Blade profile power: (delta/8) * rho * r * a * Omega^3 R^3."""
        return (self.delta / 8.0) * self.rho * self.r * self.a * (self.omega ** 3) * (self.R ** 3)

    @property
    def Pi(self) -> float:
        """Induced power: (1+k) W^{3/2} / sqrt(2 rho a)."""
        return (1 + self.k) * (self.W ** 1.5) / math.sqrt(2 * self.rho * self.a)

    def xi_m(self, V: float | None = None) -> float:
        """Eq. (1): propulsion power at speed V [W]."""
        V = self.V if V is None else V
        blade = self.P0 * (1 + 3 * V ** 2 / self.U_tip ** 2)
        induced = self.Pi * math.sqrt(
            max(math.sqrt(1 + V ** 4 / (4 * self.v0 ** 4)) - V ** 2 / (2 * self.v0 ** 2), 0.0))
        parasite = 0.5 * self.f * self.rho * self.r * self.a * V ** 3
        return blade + induced + parasite

    @property
    def xi_h(self) -> float:
        """Eq. (2): hover power P0 + Pi [W]."""
        return self.P0 + self.Pi

    def reception_range(self, cr: float) -> float:
        """Rr = sqrt(CR^2 - h^2)."""
        return math.sqrt(max(cr ** 2 - self.altitude ** 2, 0.0))


DEFAULT_UAV = UAVParams()


def tour_energy(distance_m: float, n_hover: int, *, params: UAVParams = DEFAULT_UAV,
                hover_s_per_stop: float = 30.0, comm_s_per_stop: float = 10.0) -> dict:
    """Energy (J) for one tour: movement + hover + communication.

    T_m = D/V ; hover/comm per stop are deployment knobs (the paper's
    Table II varies only deployment, so these are held constant across
    methods, matching its controlled comparison).
    """
    t_m = distance_m / params.V
    t_h = n_hover * hover_s_per_stop
    t_c = n_hover * comm_s_per_stop
    e_m = t_m * params.xi_m()
    e_h = t_h * params.xi_h
    e_c = t_c * params.xi_c
    return {"E_move": e_m, "E_hover": e_h, "E_comm": e_c,
            "E_total": e_m + e_h + e_c,
            "T_move": t_m, "T_hover": t_h, "T_comm": t_c}
