"""Architecture registry: the 10 assigned architectures + the paper's CNNs.

``get_config(name)`` returns the full production ArchConfig;
``get_config(name).reduced()`` the CPU smoke-test variant.
"""
from .base import ArchConfig, InputShape, INPUT_SHAPES, SplitConfig

from .qwen1_5_32b import CONFIG as qwen1_5_32b
from .pixtral_12b import CONFIG as pixtral_12b
from .whisper_tiny import CONFIG as whisper_tiny
from .arctic_480b import CONFIG as arctic_480b
from .h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .smollm_135m import CONFIG as smollm_135m
from .jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from .rwkv6_7b import CONFIG as rwkv6_7b
from .yi_9b import CONFIG as yi_9b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        qwen1_5_32b, pixtral_12b, whisper_tiny, arctic_480b,
        h2o_danube_1_8b, deepseek_moe_16b, smollm_135m,
        jamba_1_5_large_398b, rwkv6_7b, yi_9b,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "SplitConfig",
           "ARCHS", "get_config"]
