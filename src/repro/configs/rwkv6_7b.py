"""rwkv6-7b [ssm] — "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    head_dim=64,                       # rwkv head size
    d_ff=14336, vocab=65536,
    ssm_kind="rwkv6", attn_period=0,
    source="arXiv:2404.05892",
)
