"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave
(1 attention layer per 8), MoE 16 experts top-2 every other layer.
GQA kv=8. [arXiv:2403.19887]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_layer_period=2,
    attn_period=8, ssm_kind="mamba", ssm_state_dim=16, ssm_expand=2,
    swa_window=4096,      # attention layers use SWA for the long_500k shape
    source="arXiv:2403.19887",
)
