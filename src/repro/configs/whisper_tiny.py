"""whisper-tiny [audio] — enc-dec; conv/mel frontend is a STUB that provides
precomputed frame embeddings (B, 1500, 384). [arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    norm="layernorm", ffn="gelu",
    enc_dec=True, n_enc_layers=4, enc_seq_len=1500,
    frontend="audio_frames",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
