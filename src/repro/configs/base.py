"""Architecture configuration schema.

One ``ArchConfig`` instance per assigned architecture (see sibling modules)
plus the paper's own CNN backbones. ``reduced()`` yields the CPU-smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attn-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    ffn: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = True

    # sliding-window attention (h2o-danube; also the long_500k variant for
    # dense archs — see DESIGN.md §Shape-applicability)
    swa_window: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0        # deepseek: 2 shared (dense) experts
    moe_d_ff: Optional[int] = None   # fine-grained expert width (deepseek 1408)
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    first_moe_layer: int = 0         # deepseek: layer 0 dense
    moe_layer_period: int = 1        # jamba: MoE every 2nd layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid (jamba): 1 attention layer per `attn_period` blocks, rest mamba
    attn_period: int = 0             # 0 = all-attention (or all-ssm if ssm)
    ssm_kind: str = ""               # "" | mamba | rwkv6
    ssm_state_dim: int = 16          # mamba N
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 1500          # whisper: 30s of audio at 50 Hz

    # modality frontend stub (vlm/audio): inputs are precomputed embeddings
    frontend: str = "none"           # none | patch_embed | audio_frames
    frontend_tokens: int = 0         # e.g. vision tokens prepended

    dtype: str = "bfloat16"
    source: str = ""                 # citation

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if i < self.first_moe_layer:
            return False
        return (i - self.first_moe_layer) % self.moe_layer_period == 0

    def is_attn_layer(self, i: int) -> bool:
        if self.ssm_kind and self.attn_period == 0:
            return False                      # pure SSM (rwkv6)
        if self.attn_period == 0:
            return True                       # pure attention
        # jamba: one attention layer per attn_period, at the end of the group
        return i % self.attn_period == (self.attn_period - 1)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        d = min(self.d_model, 256)
        heads = self.n_heads
        kvh = self.n_kv_heads
        if heads > 0:
            heads = min(heads, 4)
            kvh = max(1, min(kvh, heads))
            while heads % kvh:
                kvh -= 1
        layers = min(self.n_layers, 2 * max(self.attn_period, 1))
        repl = {
            "n_layers": layers,
            "d_model": d,
            "n_heads": heads,
            "n_kv_heads": kvh,
            "head_dim": (d // heads) if heads else None,
            "d_ff": min(self.d_ff, 512),
            "vocab": min(self.vocab, 512),
            "n_experts": min(self.n_experts, 4),
            "top_k": min(self.top_k, 2) if self.top_k else 0,
            "moe_d_ff": min(self.moe_d_ff, 128) if self.moe_d_ff else None,
            "n_enc_layers": min(self.n_enc_layers, 2),
            "enc_seq_len": min(self.enc_seq_len, 64),
            "swa_window": min(self.swa_window, 32) if self.swa_window else None,
            "frontend_tokens": min(self.frontend_tokens, 16),
            "dtype": "float32",
        }
        return dataclasses.replace(self, **repl)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class SplitConfig:
    """eEnergy-Split technique config for a transformer arch."""
    client_fraction: float = 0.15     # paper's SL_{15,85} default
    variant: str = "vanilla"          # vanilla | ushaped
    compress_link: str = "none"       # none | int8
    fedavg_period: int = 1            # r local rounds per aggregation
