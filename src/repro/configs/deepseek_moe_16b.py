"""deepseek-moe-16b [moe] — fine-grained 64 routed experts top-6 + 2 shared
experts; layer 0 is dense. [arXiv:2401.06066]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_moe_layer=1,
    source="arXiv:2401.06066",
)
