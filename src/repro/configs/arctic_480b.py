"""arctic-480b [moe] — 128 experts top-2 IN PARALLEL with a dense residual
FFN on every layer. GQA kv=8. [hf:Snowflake/snowflake-arctic-base]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, dense_residual=True,
    moe_d_ff=4864,
    source="hf:Snowflake/snowflake-arctic-base",
)
