"""pixtral-12b [vlm] — pixtral-ViT frontend (stub) + mistral-nemo decoder.
GQA kv=8. [hf:mistralai/Pixtral-12B-2409]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=160,
    rope_theta=1_000_000.0,
    frontend="patch_embed", frontend_tokens=1024,   # 1024 image tokens (stub ViT)
    source="hf:mistralai/Pixtral-12B-2409",
)
