"""Minimal pure-JAX module substrate.

No flax/optax in this environment, so the framework carries its own module
system: parameters are plain pytrees (nested dicts of jnp arrays), modules
are (init, apply) pairs of pure functions, RNG is threaded explicitly.

Conventions
-----------
- ``init(key, ...) -> params``   (pytree of arrays)
- ``apply(params, x, ...) -> y`` (pure)
- Parameter dtype is configurable (bf16 for big dry-run configs, f32 for
  CPU smoke tests); compute dtype follows the input.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

Params = Any  # pytree of jnp arrays
PRNGKey = jax.Array


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _fan(shape: Sequence[int], in_axis: int = -2, out_axis: int = -1):
    if len(shape) == 1:
        return shape[0], shape[0]
    receptive = 1
    for i, s in enumerate(shape):
        if i not in (in_axis % len(shape), out_axis % len(shape)):
            receptive *= s
    return shape[in_axis] * receptive, shape[out_axis] * receptive


def normal_init(key: PRNGKey, shape, dtype=jnp.float32, stddev=0.02):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def lecun_normal(key: PRNGKey, shape, dtype=jnp.float32, in_axis=-2, out_axis=-1):
    fan_in, _ = _fan(shape, in_axis, out_axis)
    return (jax.random.normal(key, shape) / math.sqrt(max(fan_in, 1))).astype(dtype)


def he_normal(key: PRNGKey, shape, dtype=jnp.float32, in_axis=-2, out_axis=-1):
    fan_in, _ = _fan(shape, in_axis, out_axis)
    return (jax.random.normal(key, shape) * math.sqrt(2.0 / max(fan_in, 1))).astype(dtype)


def zeros_init(_key: PRNGKey, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key: PRNGKey, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------

def linear_init(key: PRNGKey, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, init: Callable = lecun_normal) -> Params:
    kw, _ = jax.random.split(key)
    p = {"w": init(kw, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear_apply(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key: PRNGKey, vocab: int, d: int, *, dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (vocab, d), dtype)}


def embed_apply(p: Params, ids: jax.Array) -> jax.Array:
    return p["table"][ids]


def embed_logits(p: Params, x: jax.Array) -> jax.Array:
    """Tied output head: x @ table.T"""
    return x @ p["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(_key: PRNGKey, d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(_key: PRNGKey, d: int, *, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def groupnorm_apply(p: Params, x: jax.Array, groups: int, *, eps: float = 1e-5):
    """Channel-last group norm for CNNs: x (..., C)."""
    dt = x.dtype
    c = x.shape[-1]
    xf = x.astype(jnp.float32).reshape(x.shape[:-1] + (groups, c // groups))
    mu = jnp.mean(xf, axis=(-1,), keepdims=True)
    var = jnp.var(xf, axis=(-1,), keepdims=True)
    y = ((xf - mu) * lax.rsqrt(var + eps)).reshape(x.shape)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# conv (NHWC) — CNN repro + whisper conv frontend stub
# ---------------------------------------------------------------------------

def conv_init(key: PRNGKey, k: int, c_in: int, c_out: int, *, bias: bool = True,
              dtype=jnp.float32, groups: int = 1) -> Params:
    p = {"w": he_normal(key, (k, k, c_in // groups, c_out), dtype, in_axis=2, out_axis=3)}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv_apply(p: Params, x: jax.Array, *, stride: int = 1, padding="SAME",
               groups: int = 1) -> jax.Array:
    y = lax.conv_general_dilated(
        x, p["w"].astype(x.dtype),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# activations / misc
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu_ffn_init(key: PRNGKey, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d, d_ff, dtype=dtype),
        "up": linear_init(k2, d, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu_ffn_apply(p: Params, x: jax.Array) -> jax.Array:
    return linear_apply(p["down"], silu(linear_apply(p["gate"], x)) * linear_apply(p["up"], x))


def gelu_ffn_init(key: PRNGKey, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {"up": linear_init(k1, d, d_ff, bias=True, dtype=dtype),
            "down": linear_init(k2, d_ff, d, bias=True, dtype=dtype)}


def gelu_ffn_apply(p: Params, x: jax.Array) -> jax.Array:
    return linear_apply(p["down"], gelu(linear_apply(p["up"], x)))


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, *, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """x: (..., T, H, D) ; positions: (..., T) broadcastable."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta=theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    # rotate-half convention
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# pytree stacking helpers (scan-over-layers)
# ---------------------------------------------------------------------------

def stack_layers(key: PRNGKey, n: int, init_fn: Callable[[PRNGKey], Params]) -> Params:
    """Initialize n identical layers and stack each leaf on a leading axis."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def scan_layers(apply_fn: Callable, stacked: Params, x, *, unroll: int = 1):
    """Run ``x = apply_fn(layer_params, x)`` over the stacked leading axis."""
    def body(carry, layer):
        return apply_fn(layer, carry), None
    y, _ = lax.scan(body, x, stacked, unroll=unroll)
    return y


def scan_layers_carry(apply_fn: Callable, stacked: Params, x, state, *, unroll: int = 1):
    """Like scan_layers but threads an extra per-layer state (e.g. KV cache).

    ``apply_fn(layer_params, x, layer_state) -> (x, new_layer_state)``;
    state leaves carry a leading n_layers axis.
    """
    def body(carry, inp):
        layer, st = inp
        y, new_st = apply_fn(layer, carry, st)
        return y, new_st
    y, new_state = lax.scan(body, x, (stacked, state), unroll=unroll)
    return y, new_state


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(p.size * p.dtype.itemsize) for p in jax.tree_util.tree_leaves(params))
