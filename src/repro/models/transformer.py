"""Unified transformer family: dense / MoE / SSM / hybrid / VLM / enc-dec.

A model is a ``frontend -> [layer groups] -> final norm -> tied head``
pipeline. Layers are *grouped* into homogeneous stacks (leading layer axis,
scan-over-layers) so HLO size stays O(1) in depth — essential for
compiling 512-device dry-runs of 64-72 layer models on this container.

Group kinds
-----------
  attn   : [norm→GQA attention→res] + [norm→(dense|MoE|MoE+dense)→res]
  rwkv   : [ln→time-mix→res] + [ln→channel-mix→res]   (RWKV-6)
  jamba  : super-block of ``attn_period`` sublayers (mamba×(P-1) + attn×1),
           FFN alternating dense/MoE per the config period
  enc    : bidirectional attention + FFN (whisper encoder)
  xdec   : self-attn + cross-attn + FFN (whisper decoder)

The eEnergy-Split cut is a *group boundary*: ``build_groups(cfg, cut)``
splits the stack there, and the launcher gives client groups DP-only
sharding and server groups TP sharding (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..parallel.sharding import shard_act
from . import modules as nn
from .attention import (attn_init, chunked_causal_attention, decode_attention,
                        qkv_project, update_kv_cache)
from .moe import moe_apply, moe_init
from .ssm import (mamba_apply, mamba_empty_state, mamba_init, mamba_step,
                  rwkv6_apply, rwkv6_empty_state, rwkv6_ffn_apply,
                  rwkv6_ffn_init, rwkv6_init, rwkv6_step)

Params = Any


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# group plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupSpec:
    kind: str                 # attn | rwkv | jamba | enc | xdec
    count: int                # layers (or super-blocks for jamba)
    layer_offset: int         # first absolute layer index
    moe: bool = False         # FFN is MoE (attn groups)
    tier: str = "server"      # client | server  (split-learning tier)


def build_groups(cfg: ArchConfig, *, cut_layer: Optional[int] = None) -> list[GroupSpec]:
    """Homogeneous layer groups; optionally split at ``cut_layer``."""
    groups: list[GroupSpec] = []
    if cfg.enc_dec:
        groups.append(GroupSpec("enc", cfg.n_enc_layers, 0))
        groups.append(GroupSpec("xdec", cfg.n_layers, cfg.n_enc_layers))
    elif cfg.ssm_kind == "rwkv6" and cfg.attn_period == 0:
        groups.append(GroupSpec("rwkv", cfg.n_layers, 0))
    elif cfg.ssm_kind == "mamba" and cfg.attn_period > 0:
        assert cfg.n_layers % cfg.attn_period == 0
        groups.append(GroupSpec("jamba", cfg.n_layers // cfg.attn_period, 0))
    else:
        # attention stack; break where the moe-ness changes (deepseek layer 0)
        flags = [cfg.is_moe_layer(i) for i in range(cfg.n_layers)]
        start = 0
        for i in range(1, cfg.n_layers + 1):
            if i == cfg.n_layers or flags[i] != flags[start]:
                groups.append(GroupSpec("attn", i - start, start, moe=flags[start]))
                start = i

    if cut_layer is not None:
        groups = _split_at(groups, cut_layer, cfg)
    return groups


def _split_at(groups: list[GroupSpec], cut_layer: int, cfg: ArchConfig) -> list[GroupSpec]:
    """Split group list at an absolute layer index; tag tiers.

    For enc-dec, the cut lives in the encoder (client = early acoustic
    layers). For jamba the cut snaps to a super-block boundary.
    """
    out: list[GroupSpec] = []
    for g in groups:
        span = g.count * (cfg.attn_period if g.kind == "jamba" else 1)
        lo, hi = g.layer_offset, g.layer_offset + span
        if cut_layer <= lo:
            out.append(dataclasses.replace(g, tier="server"))
        elif cut_layer >= hi:
            out.append(dataclasses.replace(g, tier="client"))
        else:
            per = cfg.attn_period if g.kind == "jamba" else 1
            k = max(1, round((cut_layer - lo) / per))
            k = min(k, g.count - 1) if g.count > 1 else g.count
            if k > 0:
                out.append(dataclasses.replace(g, count=k, tier="client"))
            if g.count - k > 0:
                out.append(dataclasses.replace(
                    g, count=g.count - k, layer_offset=lo + k * per, tier="server"))
    return out


def default_cut_layer(cfg: ArchConfig, client_fraction: float) -> int:
    """Paper SL_{a,b}: client holds a% of layers. MoE archs clamp the cut at
    the first MoE layer when it would otherwise include experts client-side
    (experts cannot live on the edge tier — DESIGN.md §4)."""
    n = cfg.n_enc_layers if cfg.enc_dec else cfg.n_layers
    k = max(1, min(n - 1, int(math.ceil(client_fraction * n))))
    if cfg.n_experts and not cfg.enc_dec:
        first_moe = cfg.first_moe_layer
        if cfg.is_moe_layer(0):
            first_moe = 0
        # find first actually-MoE layer
        fm = next((i for i in range(cfg.n_layers) if cfg.is_moe_layer(i)), n)
        if fm == 0:
            return k           # all layers MoE (arctic): documented exception
        k = min(k, fm)
    return k


# ---------------------------------------------------------------------------
# per-kind layer init
# ---------------------------------------------------------------------------

def _norm_init(key, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return nn.layernorm_init(key, d, dtype=cfg.param_dtype)
    return nn.rmsnorm_init(key, d, dtype=cfg.param_dtype)


def _norm_apply(cfg, p, x):
    if cfg.norm == "layernorm":
        return nn.layernorm_apply(p, x)
    return nn.rmsnorm_apply(p, x)


def _ffn_init(key, cfg):
    if cfg.ffn == "gelu":
        return nn.gelu_ffn_init(key, cfg.d_model, cfg.d_ff, dtype=cfg.param_dtype)
    return nn.swiglu_ffn_init(key, cfg.d_model, cfg.d_ff, dtype=cfg.param_dtype)


def _ffn_apply(cfg, p, x):
    if cfg.ffn == "gelu":
        return nn.gelu_ffn_apply(p, x)
    return nn.swiglu_ffn_apply(p, x)


def _moe_init(key, cfg):
    return moe_init(key, cfg.d_model, cfg.n_experts,
                    cfg.moe_d_ff or cfg.d_ff, cfg.top_k,
                    n_shared=cfg.n_shared_experts,
                    shared_d_ff=cfg.moe_d_ff or cfg.d_ff,
                    dtype=cfg.param_dtype)


def _attn_layer_init(key, cfg, *, moe: bool, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {"ln1": _norm_init(ks[0], cfg),
         "attn": attn_init(ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.hd, qkv_bias=cfg.qkv_bias, dtype=cfg.param_dtype),
         "ln2": _norm_init(ks[2], cfg)}
    if cross:
        p["lnx"] = _norm_init(ks[3], cfg)
        p["xattn"] = attn_init(ks[4], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, dtype=cfg.param_dtype)
    if moe:
        p["moe"] = _moe_init(ks[5], cfg)
        if cfg.dense_residual:
            from ..keys import INIT_FFN_ALT, fold
            p["ffn"] = _ffn_init(fold(ks[5], INIT_FFN_ALT), cfg)
    else:
        p["ffn"] = _ffn_init(ks[5], cfg)
    return p


def _rwkv_layer_init(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"ln1": _norm_init(k1, cfg),
            "mix": rwkv6_init(k2, cfg.d_model, head_size=cfg.hd, dtype=cfg.param_dtype),
            "ln2": _norm_init(k3, cfg),
            "ffn": rwkv6_ffn_init(k4, cfg.d_model, cfg.d_ff, dtype=cfg.param_dtype)}


def _jamba_super_init(key, cfg):
    p = {}
    for i in range(cfg.attn_period):
        ki = jax.random.fold_in(key, i)
        is_attn = (i == cfg.attn_period - 1)
        is_moe = cfg.n_experts > 0 and (i % cfg.moe_layer_period == cfg.moe_layer_period - 1)
        ks = jax.random.split(ki, 4)
        sub = {"ln1": _norm_init(ks[0], cfg), "ln2": _norm_init(ks[1], cfg)}
        if is_attn:
            sub["attn"] = attn_init(ks[2], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, dtype=cfg.param_dtype)
        else:
            sub["mamba"] = mamba_init(ks[2], cfg.d_model, expand=cfg.ssm_expand,
                                      state_dim=cfg.ssm_state_dim,
                                      conv_width=cfg.ssm_conv_width,
                                      dtype=cfg.param_dtype)
        sub["moe" if is_moe else "ffn"] = (_moe_init(ks[3], cfg) if is_moe
                                           else _ffn_init(ks[3], cfg))
        p[f"sub{i}"] = sub
    return p


def group_init(key, cfg: ArchConfig, g: GroupSpec) -> Params:
    if g.kind == "attn":
        fn = partial(_attn_layer_init, cfg=cfg, moe=g.moe)
    elif g.kind == "enc":
        fn = partial(_attn_layer_init, cfg=cfg, moe=False)
    elif g.kind == "xdec":
        fn = partial(_attn_layer_init, cfg=cfg, moe=False, cross=True)
    elif g.kind == "rwkv":
        fn = partial(_rwkv_layer_init, cfg=cfg)
    elif g.kind == "jamba":
        fn = partial(_jamba_super_init, cfg=cfg)
    else:
        raise ValueError(g.kind)
    return nn.stack_layers(key, g.count, fn)


# ---------------------------------------------------------------------------
# forward (train / prefill): scan over each group's stack
# ---------------------------------------------------------------------------

def _attn_block(cfg, p, x, positions, *, window, causal=True, kv=None,
                attn_impl="xla"):
    """One attention sublayer (pre-norm residual). kv: external (cross).

    ``attn_impl`` is the kernel-dispatch seam: ``"xla"`` keeps the chunked
    jnp path (bit-identical to the pre-kernel lowerings); ``"pallas"`` /
    ``"ref"`` route through ``kernels.attn.ops.attention`` — the Pallas
    flash kernel (interpret mode off-accelerator) or the O(S²) oracle.
    """
    h = _norm_apply(cfg, p["ln1"], x)
    attn_p = p["attn"]
    b, s, _ = h.shape
    q = nn.linear_apply(attn_p["wq"], h).reshape(b, s, cfg.n_heads, cfg.hd)
    k = nn.linear_apply(attn_p["wk"], h).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = nn.linear_apply(attn_p["wv"], h).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if causal:  # rope only for (causal) self-attention stacks
        q = nn.apply_rope(q, positions, theta=cfg.rope_theta)
        k = nn.apply_rope(k, positions, theta=cfg.rope_theta)
    if attn_impl == "xla":
        out = chunked_causal_attention(q, k, v, window=window, causal=causal)
    else:
        from ..kernels.attn.ops import attention
        from ..kernels.dispatch import accelerator_backend
        out = attention(q, k, v, causal=causal, window=window,
                        use_pallas=(attn_impl == "pallas"),
                        interpret=not accelerator_backend())
    out = nn.linear_apply(attn_p["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))
    return x + out


def _ffn_block(cfg, p, x, aux, moe: bool, moe_groups: int = 1):
    h = _norm_apply(cfg, p["ln2"], x)
    if moe:
        y, a = moe_apply(p["moe"], h, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         n_groups=moe_groups)
        aux = aux + a
        if cfg.dense_residual:
            y = y + _ffn_apply(cfg, p["ffn"], h)
    else:
        y = _ffn_apply(cfg, p["ffn"], h)
    return x + y, aux


def group_apply(cfg: ArchConfig, g: GroupSpec, stacked: Params, x, aux, *,
                positions, window, enc_out=None, unroll: int = 1,
                remat: bool = False, act_spec=("dp", None, None),
                moe_groups: int = 1, attn_impl: str = "xla"):
    """Full-sequence pass (train/prefill). Returns (x, aux). With
    ``remat`` each scanned layer body is rematerialized in the backward
    pass (only the residual-stream carry is saved)."""
    def _maybe_remat(f):
        return jax.checkpoint(f) if remat else f

    if g.kind in ("attn", "enc", "xdec"):
        causal = g.kind != "enc"

        def body(carry, layer):
            h, a = carry
            h = _attn_block(cfg, layer, h, positions, window=window,
                            causal=causal, attn_impl=attn_impl)
            if g.kind == "xdec":
                h = h + _x_cross(cfg, layer, h, enc_out)
            h, a = _ffn_block(cfg, layer, h, a, moe=g.moe,
                              moe_groups=moe_groups)
            h = shard_act(h, act_spec)
            return (h, a), None

        (x, aux), _ = lax.scan(_maybe_remat(body), (x, aux), stacked, unroll=unroll)
        return x, aux

    if g.kind == "rwkv":
        def body(carry, layer):
            h, a = carry
            mix, _ = rwkv6_apply(layer["mix"], _norm_apply(cfg, layer["ln1"], h),
                                 head_size=cfg.hd)
            h = h + mix
            hf = _norm_apply(cfg, layer["ln2"], h)
            h = h + rwkv6_ffn_apply(layer["ffn"], hf,
                                    jnp.zeros_like(hf[:, 0]))
            h = shard_act(h, act_spec)
            return (h, a), None

        (x, aux), _ = lax.scan(_maybe_remat(body), (x, aux), stacked, unroll=unroll)
        return x, aux

    if g.kind == "jamba":
        def body(carry, layer):
            h, a = carry
            for i in range(cfg.attn_period):
                sub = layer[f"sub{i}"]
                if "attn" in sub:
                    h = _attn_block(cfg, sub, h, positions, window=window,
                                    attn_impl=attn_impl)
                else:
                    y, _ = mamba_apply(sub["mamba"], _norm_apply(cfg, sub["ln1"], h),
                                       expand=cfg.ssm_expand,
                                       state_dim=cfg.ssm_state_dim,
                                       conv_width=cfg.ssm_conv_width)
                    h = h + y
                h, a = _ffn_block(cfg, sub, h, a, moe="moe" in sub,
                                  moe_groups=moe_groups)
                h = shard_act(h, act_spec)
            return (h, a), None

        (x, aux), _ = lax.scan(_maybe_remat(body), (x, aux), stacked, unroll=unroll)
        return x, aux

    raise ValueError(g.kind)


def _x_cross(cfg, layer, h, enc_out):
    """Cross-attention sublayer (whisper decoder)."""
    q_in = _norm_apply(cfg, layer["lnx"], h)
    b, s, _ = q_in.shape
    sk = enc_out.shape[1]
    xp = layer["xattn"]
    q = nn.linear_apply(xp["wq"], q_in).reshape(b, s, cfg.n_heads, cfg.hd)
    k = nn.linear_apply(xp["wk"], enc_out).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    v = nn.linear_apply(xp["wv"], enc_out).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    out = chunked_causal_attention(q, k, v, window=None, causal=False)
    return nn.linear_apply(xp["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------

def vocab_padded(cfg: ArchConfig) -> int:
    """Pad vocab to a multiple of 16 so the embedding shards over 'model'
    (whisper's 51865 -> 51872). Padded ids never appear as labels."""
    return round_up(cfg.vocab, 16)


def model_init(cfg: ArchConfig, key, *, cut_layer: Optional[int] = None) -> Params:
    groups = build_groups(cfg, cut_layer=cut_layer)
    ks = jax.random.split(key, len(groups) + 3)
    params: dict = {
        "embed": nn.embed_init(ks[0], vocab_padded(cfg), cfg.d_model,
                               dtype=cfg.param_dtype),
        "final_norm": _norm_init(ks[1], cfg),
        "groups": [group_init(ks[2 + i], cfg, g) for i, g in enumerate(groups)],
    }
    if cfg.enc_dec:
        params["enc_norm"] = _norm_init(ks[-1], cfg)
    if not cfg.tie_embeddings:
        params["head"] = nn.linear_init(ks[-1], cfg.d_model, vocab_padded(cfg),
                                        dtype=cfg.param_dtype)
    return params


def _embed_inputs(cfg: ArchConfig, params, batch):
    """Token (+frontend) embedding. Returns (x, positions, enc_x)."""
    tokens = batch["tokens"]
    x = nn.embed_apply(params["embed"], tokens)
    if cfg.frontend == "patch_embed":
        # VLM stub: precomputed patch embeddings prepended to the text
        patches = batch["patch_embeds"].astype(x.dtype)          # (B, Np, D)
        x = jnp.concatenate([patches, x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_x = None
    if cfg.enc_dec:
        enc_x = batch["frames"].astype(x.dtype)                  # (B, Senc, D)
        # sinusoidal positions for the encoder
        senc = enc_x.shape[1]
        d = cfg.d_model
        pos = jnp.arange(senc, dtype=jnp.float32)[:, None]
        dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
        ang = pos / jnp.power(10000.0, 2 * dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        enc_x = enc_x + pe[None].astype(enc_x.dtype)
    return x, positions, enc_x


def model_forward(cfg: ArchConfig, params, batch, *,
                  window: Optional[int] = "cfg", unroll: int = 1,
                  cut_layer: Optional[int] = None, remat: bool = False,
                  seq_parallel_tiers: tuple = (), moe_groups: int = 1):
    """Full-sequence forward. Returns (logits, aux)."""
    if window == "cfg":
        window = cfg.swa_window
    groups = build_groups(cfg, cut_layer=cut_layer)
    x, positions, enc_x = _embed_inputs(cfg, params, batch)
    x = shard_act(x, ("dp", None, None))
    aux = jnp.zeros((), jnp.float32)
    enc_out = None
    gi = 0
    for g, gp in zip(groups, params["groups"]):
        if g.kind == "enc":
            epos = jnp.broadcast_to(
                jnp.arange(enc_x.shape[1], dtype=jnp.int32),
                (enc_x.shape[0], enc_x.shape[1]))
            enc_x, aux = group_apply(cfg, g, gp, enc_x, aux, positions=epos,
                                     window=None, unroll=unroll, remat=remat)
            gi += 1
            # last encoder group -> encoder output
            if gi == len(groups) - sum(1 for gg in groups if gg.kind != "enc") \
               or all(gg.kind != "enc" for gg in groups[gi:]):
                enc_out = _norm_apply(cfg, params["enc_norm"], enc_x)
        else:
            act = (("dp", "tp", None) if g.tier in seq_parallel_tiers
                   else ("dp", None, None))
            x, aux = group_apply(cfg, g, gp, x, aux, positions=positions,
                                 window=window, enc_out=enc_out, unroll=unroll,
                                 remat=remat, act_spec=act,
                                 moe_groups=moe_groups)
            gi += 1
    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = nn.embed_logits(params["embed"], x)
    else:
        logits = nn.linear_apply(params["head"], x)
    return logits, aux


def lm_loss(cfg: ArchConfig, params, batch, *, window="cfg", unroll: int = 1,
            cut_layer=None, remat: bool = False, seq_parallel_tiers=(),
            moe_groups: int = 1):
    """Next-token CE (+ router aux). Loss only on text positions for VLM."""
    logits, aux = model_forward(cfg, params, batch, window=window,
                                unroll=unroll, cut_layer=cut_layer, remat=remat,
                                seq_parallel_tiers=seq_parallel_tiers,
                                moe_groups=moe_groups)
    labels = batch["labels"]
    # align: for VLM the first Np logits correspond to patches -> skip them
    if cfg.frontend == "patch_embed":
        np_tok = batch["patch_embeds"].shape[1]
        logits = logits[:, np_tok:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp[:, :-1], labels[:, 1:, None], axis=-1)[..., 0]
    ce = -ll.mean()
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode: state init + one-token step
# ---------------------------------------------------------------------------

def decode_state_init(cfg: ArchConfig, batch_size: int, max_len: int, *,
                      window: Optional[int] = "cfg",
                      cut_layer: Optional[int] = None,
                      dtype=None, kv_dtype: str = "param") -> list:
    """Per-group decode state (KV caches / SSM states). Shapes only depend on
    (cfg, batch, max_len) so ShapeDtypeStructs can stand in for the dry-run."""
    if window == "cfg":
        window = cfg.swa_window
    dtype = dtype or cfg.param_dtype
    cache_len = min(window, max_len) if window else max_len
    groups = build_groups(cfg, cut_layer=cut_layer)
    state = []
    for g in groups:
        if g.kind in ("attn",):
            kdt = jnp.int8 if kv_dtype == "int8" else dtype
            st = {
                "k": jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads, cfg.hd), kdt),
                "v": jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads, cfg.hd), kdt),
            }
            if kv_dtype == "int8":
                st["k_scale"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads), jnp.float32)
                st["v_scale"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads), jnp.float32)
            state.append(st)
        elif g.kind == "enc":
            state.append({})  # encoder has no decode state
        elif g.kind == "xdec":
            state.append({
                "k": jnp.zeros((g.count, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((g.count, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dtype),
                "ck": jnp.zeros((g.count, batch_size, cfg.enc_seq_len, cfg.n_kv_heads, cfg.hd), dtype),
                "cv": jnp.zeros((g.count, batch_size, cfg.enc_seq_len, cfg.n_kv_heads, cfg.hd), dtype),
            })
        elif g.kind == "rwkv":
            state.append({
                "S": jnp.zeros((g.count, batch_size, cfg.d_model // cfg.hd, cfg.hd, cfg.hd), jnp.float32),
                "x_prev": jnp.zeros((g.count, batch_size, cfg.d_model), dtype),
                "ffn_x_prev": jnp.zeros((g.count, batch_size, cfg.d_model), dtype),
            })
        elif g.kind == "jamba":
            st = {}
            for i in range(cfg.attn_period):
                if i == cfg.attn_period - 1:
                    kdt = jnp.int8 if kv_dtype == "int8" else dtype
                    st[f"k{i}"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads, cfg.hd), kdt)
                    st[f"v{i}"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads, cfg.hd), kdt)
                    if kv_dtype == "int8":
                        st[f"k{i}_scale"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads), jnp.float32)
                        st[f"v{i}_scale"] = jnp.zeros((g.count, batch_size, cache_len, cfg.n_kv_heads), jnp.float32)
                else:
                    d_inner = cfg.ssm_expand * cfg.d_model
                    st[f"h{i}"] = jnp.zeros((g.count, batch_size, d_inner, cfg.ssm_state_dim), jnp.float32)
                    st[f"c{i}"] = jnp.zeros((g.count, batch_size, cfg.ssm_conv_width - 1, d_inner), dtype)
            state.append(st)
    return state


def _quant_kv(x):
    """(B,1,Kh,hd) -> int8 codes + per-(B,1,Kh) scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale


def _decode_attn_sub(cfg, p_attn, h, pos, cache_k, cache_v, *, window,
                     scales=None):
    """One-token attention against a (possibly ring) cache.
    h (B,1,D); caches (B,C,Kh,hd) in bf16/f32 or int8 (+`scales` dict).
    Returns (out, k_cache, v_cache, new_scales)."""
    b = h.shape[0]
    q = nn.linear_apply(p_attn["wq"], h).reshape(b, 1, cfg.n_heads, cfg.hd)
    k = nn.linear_apply(p_attn["wk"], h).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
    v = nn.linear_apply(p_attn["wv"], h).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = nn.apply_rope(q, posb, theta=cfg.rope_theta)
    k = nn.apply_rope(k, posb, theta=cfg.rope_theta)
    cache_size = cache_k.shape[1]
    slot = (pos % cache_size) if window else pos
    new_scales = None
    if scales is not None:                      # int8 KV cache path
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        cache_k, cache_v = update_kv_cache(cache_k, cache_v, kq, vq, slot)
        k_sc = lax.dynamic_update_slice_in_dim(scales["k"], ks, slot, axis=1)
        v_sc = lax.dynamic_update_slice_in_dim(scales["v"], vs, slot, axis=1)
        new_scales = {"k": k_sc, "v": v_sc}
        # dequantize straight to the compute dtype: the convert+mul fuses
        # into the attention dot's operand load (no f32 cache-sized temp)
        k_eff = cache_k.astype(q.dtype) * k_sc[..., None].astype(q.dtype)
        v_eff = cache_v.astype(q.dtype) * v_sc[..., None].astype(q.dtype)
    else:
        cache_k, cache_v = update_kv_cache(cache_k, cache_v, k, v, slot)
        k_eff, v_eff = cache_k, cache_v
    cache_len = jnp.minimum(pos + 1, cache_size)
    out = decode_attention(q, k_eff, v_eff, cache_len)
    out = nn.linear_apply(p_attn["wo"], out.reshape(b, 1, cfg.n_heads * cfg.hd))
    return out, cache_k, cache_v, new_scales


def model_decode_step(cfg: ArchConfig, params, state: list, token, pos, *,
                      window: Optional[int] = "cfg",
                      cut_layer: Optional[int] = None):
    """One decode step. token (B,1) int32; pos scalar int32 (tokens so far).
    Returns (logits (B,1,V), new_state)."""
    if window == "cfg":
        window = cfg.swa_window
    groups = build_groups(cfg, cut_layer=cut_layer)
    x = nn.embed_apply(params["embed"], token)
    x = shard_act(x, (None, None, "tp"))
    new_state = []
    for g, gp, gs in zip(groups, params["groups"], state):
        if g.kind == "enc":
            new_state.append(gs)
            continue
        x, ns = _group_decode(cfg, g, gp, gs, x, pos, window=window)
        new_state.append(ns)
    x = _norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = nn.embed_logits(params["embed"], x)
    else:
        logits = nn.linear_apply(params["head"], x)
    return logits, new_state


def _group_decode(cfg, g: GroupSpec, stacked, gstate, x, pos, *, window):
    from .modules import scan_layers_carry

    if g.kind in ("attn", "xdec"):
        def body(carry, inp):
            layer, st = inp
            h = carry
            a_in = _norm_apply(cfg, layer["ln1"], h)
            scales = ({"k": st["k_scale"], "v": st["v_scale"]}
                      if "k_scale" in st else None)
            out, ck, cv, nsc = _decode_attn_sub(cfg, layer["attn"], a_in, pos,
                                                st["k"], st["v"],
                                                window=window, scales=scales)
            h = h + out
            nst = {"k": ck, "v": cv}
            if nsc is not None:
                nst["k_scale"], nst["v_scale"] = nsc["k"], nsc["v"]
            if g.kind == "xdec":
                xq = _norm_apply(cfg, layer["lnx"], h)
                b = xq.shape[0]
                q = nn.linear_apply(layer["xattn"]["wq"], xq).reshape(b, 1, cfg.n_heads, cfg.hd)
                xo = decode_attention(q, st["ck"], st["cv"], st["ck"].shape[1])
                h = h + nn.linear_apply(layer["xattn"]["wo"],
                                        xo.reshape(b, 1, cfg.n_heads * cfg.hd))
                nst["ck"], nst["cv"] = st["ck"], st["cv"]
            hf = _norm_apply(cfg, layer["ln2"], h)
            if g.moe:
                y, _ = moe_apply(layer["moe"], hf, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
                if cfg.dense_residual:
                    y = y + _ffn_apply(cfg, layer["ffn"], hf)
            else:
                y = _ffn_apply(cfg, layer["ffn"], hf)
            h = h + y
            return h, nst

        def wrapped(layer, carry, st):
            return body(carry, (layer, st))

        x, ns = scan_layers_carry(wrapped, stacked, x, gstate)
        return x, ns

    if g.kind == "rwkv":
        def body(layer, carry, st):
            h = carry
            mix, mst = rwkv6_step(layer["mix"], _norm_apply(cfg, layer["ln1"], h),
                                  {"S": st["S"], "x_prev": st["x_prev"]},
                                  head_size=cfg.hd)
            h = h + mix
            hf = _norm_apply(cfg, layer["ln2"], h)
            h = h + rwkv6_ffn_apply(layer["ffn"], hf, st["ffn_x_prev"])
            nst = {"S": mst["S"], "x_prev": mst["x_prev"],
                   "ffn_x_prev": hf[:, -1, :]}
            return h, nst

        x, ns = scan_layers_carry(body, stacked, x, gstate)
        return x, ns

    if g.kind == "jamba":
        def body(layer, carry, st):
            h = carry
            nst = {}
            for i in range(cfg.attn_period):
                sub = layer[f"sub{i}"]
                if "attn" in sub:
                    a_in = _norm_apply(cfg, sub["ln1"], h)
                    scales = ({"k": st[f"k{i}_scale"], "v": st[f"v{i}_scale"]}
                              if f"k{i}_scale" in st else None)
                    out, ck, cv, nsc = _decode_attn_sub(
                        cfg, sub["attn"], a_in, pos,
                        st[f"k{i}"], st[f"v{i}"], window=window,
                        scales=scales)
                    h = h + out
                    nst[f"k{i}"], nst[f"v{i}"] = ck, cv
                    if nsc is not None:
                        nst[f"k{i}_scale"] = nsc["k"]
                        nst[f"v{i}_scale"] = nsc["v"]
                else:
                    m_in = _norm_apply(cfg, sub["ln1"], h)
                    y, ms = mamba_step(sub["mamba"], m_in,
                                       {"h": st[f"h{i}"], "conv": st[f"c{i}"]})
                    h = h + y
                    nst[f"h{i}"], nst[f"c{i}"] = ms["h"], ms["conv"]
                hf = _norm_apply(cfg, sub["ln2"], h)
                if "moe" in sub:
                    y, _ = moe_apply(sub["moe"], hf, top_k=cfg.top_k,
                                     capacity_factor=cfg.capacity_factor)
                else:
                    y = _ffn_apply(cfg, sub["ffn"], hf)
                h = h + y
            return h, nst

        x, ns = scan_layers_carry(body, stacked, x, gstate)
        return x, ns

    raise ValueError(g.kind)
