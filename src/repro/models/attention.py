"""Attention: GQA, sliding-window, chunked (flash-style) prefill, cached decode.

All implementations are plain jnp/einsum so the GSPMD partitioner can shard
them from the weight/activation constraints alone. The Pallas flash kernel
(repro.kernels.attn) is a drop-in for the chunked path on real TPUs; the
model code selects it via ``use_pallas`` (off for CPU dry-runs/tests).

Memory strategy (prefill_32k and up): online-softmax over KV blocks inside a
q-block scan — peak temp is (B, H, q_blk, kv_blk), never (B, H, S, S).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import modules as nn

NEG_INF = -1e30


def gqa_repeat(kv: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,Kh,D) -> (B,S,Kh*n_rep,D)."""
    if n_rep == 1:
        return kv
    b, s, kh, d = kv.shape
    kv = jnp.broadcast_to(kv[:, :, :, None, :], (b, s, kh, n_rep, d))
    return kv.reshape(b, s, kh * n_rep, d)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              *, qkv_bias: bool = False, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": nn.linear_init(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": nn.linear_init(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": nn.linear_init(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": nn.linear_init(ko, n_heads * head_dim, d_model, dtype=dtype),
    }


def qkv_project(p, x: jax.Array, n_heads: int, n_kv_heads: int, head_dim: int,
                positions: jax.Array, *, rope_theta: float = 10000.0):
    b, s, _ = x.shape
    q = nn.linear_apply(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = nn.linear_apply(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = nn.linear_apply(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    q = nn.apply_rope(q, positions, theta=rope_theta)
    k = nn.apply_rope(k, positions, theta=rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked causal attention (training / prefill)
# ---------------------------------------------------------------------------

def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             window: Optional[int] = None,
                             q_block: int = 512, kv_block: int = 1024,
                             causal: bool = True) -> jax.Array:
    """Online-softmax attention. q (B,S,H,D); k,v (B,S,Kh,D) already RoPE'd.

    With ``window`` set, each query attends to keys in (pos-window, pos]
    — and the kv-block scan is *clipped* to the window so the cost is
    O(S * window), not O(S^2): this is what makes long_500k lowerable for
    SWA variants.
    """
    b, s, h, d = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    n_rep = h // kh
    k = gqa_repeat(k, n_rep)
    v = gqa_repeat(v, n_rep)
    scale = 1.0 / math.sqrt(d)

    q_block = min(q_block, s)
    kv_block = min(kv_block, sk)
    while s % q_block:
        q_block //= 2
    while sk % kv_block:
        kv_block //= 2
    nq, nk = s // q_block, sk // kv_block

    # (B,H,S,D) layouts for clean einsums
    qt = q.transpose(0, 2, 1, 3) * scale
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    if window is not None:
        # keys needed by a q block span (q_block + window - 1) positions
        kv_span = min(nk, int(math.ceil((q_block + window - 1) / kv_block)) + 1)
    else:
        kv_span = nk

    def q_step(_, qi):
        qb = lax.dynamic_slice_in_dim(qt, qi * q_block, q_block, axis=2)
        q_pos = qi * q_block + jnp.arange(q_block)

        # first kv block this q block must see (lowest key of the FIRST query)
        if window is not None:
            lo_pos = jnp.maximum(qi * q_block - (window - 1), 0)
            kv_lo = jnp.minimum(lo_pos // kv_block, nk - kv_span)
            kv_lo = jnp.maximum(kv_lo, 0)
        else:
            kv_lo = jnp.array(0, jnp.int32)

        def kv_step(carry, j):
            m, l, acc = carry
            kj = kv_lo + j
            kb = lax.dynamic_slice_in_dim(kt, kj * kv_block, kv_block, axis=2)
            vb = lax.dynamic_slice_in_dim(vt, kj * kv_block, kv_block, axis=2)
            scores = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                                preferred_element_type=jnp.float32)
            k_pos = kj * kv_block + jnp.arange(kv_block)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, scores.max(-1))
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, d), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(kv_span))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, blocks = lax.scan(q_step, None, jnp.arange(nq))
    # blocks: (nq, B, H, q_block, D) -> (B, S, H, D)
    out = blocks.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out


# ---------------------------------------------------------------------------
# cached decode attention (one new token vs a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len) -> jax.Array:
    """q (B,1,H,D); caches (B,S,Kh,D); attends to positions < cache_len.

    Contracts over the cache's sequence axis — when that axis is sharded
    (decode sharding: seq over 'model'), GSPMD turns the softmax/contraction
    into the split-KV (flash-decoding) pattern with a small psum.
    """
    b, _, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kh
    scale = 1.0 / math.sqrt(d)
    # grouped einsum without materializing repeated KV
    qg = q.reshape(b, 1, kh, n_rep, d) * scale
    scores = jnp.einsum("bqgrd,bsgd->bgrqs", qg, k_cache,
                        preferred_element_type=jnp.float32)  # (B,Kh,rep,1,S)
    pos = jnp.arange(s)
    mask = pos[None, None, None, None, :] < cache_len
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqs,bsgd->bqgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def update_kv_cache(k_cache: jax.Array, v_cache: jax.Array,
                    k_new: jax.Array, v_new: jax.Array, pos) -> tuple:
    """Write one token (B,1,Kh,D) at `pos` (dynamic)."""
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


def reference_attention(q, k, v, *, window=None, causal=True):
    """O(S^2) oracle for tests."""
    b, s, h, d = q.shape
    k = gqa_repeat(k, h // k.shape[2])
    v = gqa_repeat(v, h // v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(d)
    qp, kp = jnp.arange(s)[:, None], jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v).astype(q.dtype)
