"""The paper's three backbones in pure JAX, expressed as split-able Stage lists.

ResNet-18 (He et al. 16), GoogleNet (Szegedy et al. 15, trimmed faithful
inception blocks), MobileNetV2 (Sandler et al. 18, inverted residuals).
BatchNorm is replaced by GroupNorm (batch-stat-free -> correct under both
FL's local batches and SL's split execution, and jit-friendly without
mutable state); this is noted in DESIGN.md as an adaptation.

Each builder returns ``list[Stage]`` so ``repro.core.split`` can cut at any
fraction {15, 25, 40, 75}% exactly as the paper's SL_{a,b} variants. Each
Stage carries a ``depth`` weight = number of paper-layers it contains so
cut fractions track the paper's "% of layers" semantics.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.split import Stage
from . import modules as nn


def _gn_init(key, c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _gn(p, x, c):
    """Spatial GroupNorm (NHWC): normalize over (H, W, C/G) per group —
    the batch-stat-free replacement for the paper models' BatchNorm."""
    groups = c // 8 if c % 8 == 0 else (c // 4 if c % 4 == 0 else 1)
    b, h, w, _ = x.shape
    xf = x.astype(jnp.float32).reshape(b, h, w, groups, c // groups)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, h, w, c)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _conv_gn_relu_init(key, k, cin, cout):
    kc, kn = jax.random.split(key)
    return {"conv": nn.conv_init(kc, k, cin, cout, bias=False),
            "gn": _gn_init(kn, cout)}


def _conv_gn_relu(p, x, *, stride=1, cout=None, relu=True, groups=1):
    y = nn.conv_apply(p["conv"], x, stride=stride, groups=groups)
    y = _gn(p["gn"], y, y.shape[-1])
    return jax.nn.relu(y) if relu else y


# ---------------------------------------------------------------------------
# ResNet-18
# ---------------------------------------------------------------------------

def _basic_block_init(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_gn_relu_init(k1, 3, cin, cout),
         "c2": _conv_gn_relu_init(k2, 3, cout, cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _conv_gn_relu_init(k3, 1, cin, cout)
    return p


def _basic_block(p, x, *, stride):
    y = _conv_gn_relu(p["c1"], x, stride=stride)
    y = _conv_gn_relu(p["c2"], y, relu=False)
    sc = _conv_gn_relu(p["proj"], x, stride=stride, relu=False) if "proj" in p else x
    return jax.nn.relu(y + sc)


def resnet18_stages(num_classes: int = 12, *, width: int = 64) -> list[Stage]:
    w = width
    plan = [(w, w, 1), (w, w, 1),              # conv2_x
            (w, 2 * w, 2), (2 * w, 2 * w, 1),  # conv3_x
            (2 * w, 4 * w, 2), (4 * w, 4 * w, 1),
            (4 * w, 8 * w, 2), (8 * w, 8 * w, 1)]
    stages: list[Stage] = [
        Stage("stem",
              init=lambda k: _conv_gn_relu_init(k, 7, 3, w),
              apply=lambda p, x: jax.lax.reduce_window(
                  _conv_gn_relu(p, x, stride=2), -jnp.inf, jax.lax.max,
                  (1, 3, 3, 1), (1, 2, 2, 1), "SAME"),
              depth=1)]
    for i, (cin, cout, s) in enumerate(plan):
        stages.append(Stage(
            f"block{i}",
            init=partial(_basic_block_init, cin=cin, cout=cout, stride=s),
            apply=partial(_basic_block, stride=s),
            depth=2))
    stages.append(Stage(
        "head",
        init=lambda k: nn.linear_init(k, 8 * w, num_classes, bias=True),
        apply=lambda p, x: nn.linear_apply(p, x.mean(axis=(1, 2))),
        depth=1))
    return stages


# ---------------------------------------------------------------------------
# GoogleNet (inception v1, GN instead of LRN/BN, aux heads omitted)
# ---------------------------------------------------------------------------

def _inception_init(key, cin, c1, c3r, c3, c5r, c5, cp):
    ks = jax.random.split(key, 6)
    return {"b1": _conv_gn_relu_init(ks[0], 1, cin, c1),
            "b3r": _conv_gn_relu_init(ks[1], 1, cin, c3r),
            "b3": _conv_gn_relu_init(ks[2], 3, c3r, c3),
            "b5r": _conv_gn_relu_init(ks[3], 1, cin, c5r),
            "b5": _conv_gn_relu_init(ks[4], 5, c5r, c5),
            "bp": _conv_gn_relu_init(ks[5], 1, cin, cp)}


def _inception(p, x):
    b1 = _conv_gn_relu(p["b1"], x)
    b3 = _conv_gn_relu(p["b3"], _conv_gn_relu(p["b3r"], x))
    b5 = _conv_gn_relu(p["b5"], _conv_gn_relu(p["b5r"], x))
    mp = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 1, 1, 1), "SAME")
    bp = _conv_gn_relu(p["bp"], mp)
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")


def googlenet_stages(num_classes: int = 12) -> list[Stage]:
    # (cin, 1x1, 3x3r, 3x3, 5x5r, 5x5, pool) — faithful table from the paper
    inc = {
        "3a": (192, 64, 96, 128, 16, 32, 32),
        "3b": (256, 128, 128, 192, 32, 96, 64),
        "4a": (480, 192, 96, 208, 16, 48, 64),
        "4b": (512, 160, 112, 224, 24, 64, 64),
        "4c": (512, 128, 128, 256, 24, 64, 64),
        "4d": (512, 112, 144, 288, 32, 64, 64),
        "4e": (528, 256, 160, 320, 32, 128, 128),
        "5a": (832, 256, 160, 320, 32, 128, 128),
        "5b": (832, 384, 192, 384, 48, 128, 128),
    }
    stages: list[Stage] = []

    def stem_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"c1": _conv_gn_relu_init(k1, 7, 3, 64),
                "c2": _conv_gn_relu_init(k2, 1, 64, 64),
                "c3": _conv_gn_relu_init(k3, 3, 64, 192)}

    def stem(p, x):
        y = _maxpool2(_conv_gn_relu(p["c1"], x, stride=2))
        y = _conv_gn_relu(p["c3"], _conv_gn_relu(p["c2"], y))
        return _maxpool2(y)

    stages.append(Stage("stem", init=stem_init, apply=stem, depth=3))
    for name, cfg in inc.items():
        cin, c1, c3r, c3, c5r, c5, cp = cfg
        pool_after = name in ("3b", "4e")
        if pool_after:
            stages.append(Stage(
                f"inc{name}",
                init=partial(_inception_init, cin=cin, c1=c1, c3r=c3r, c3=c3,
                             c5r=c5r, c5=c5, cp=cp),
                apply=lambda p, x: _maxpool2(_inception(p, x)),
                depth=2))
        else:
            stages.append(Stage(
                f"inc{name}",
                init=partial(_inception_init, cin=cin, c1=c1, c3r=c3r, c3=c3,
                             c5r=c5r, c5=c5, cp=cp),
                apply=_inception,
                depth=2))
    stages.append(Stage(
        "head",
        init=lambda k: nn.linear_init(k, 1024, num_classes, bias=True),
        apply=lambda p, x: nn.linear_apply(p, x.mean(axis=(1, 2))),
        depth=1))
    return stages


# ---------------------------------------------------------------------------
# MobileNetV2
# ---------------------------------------------------------------------------

def _inv_res_init(key, cin, cout, expand):
    hid = cin * expand
    ks = jax.random.split(key, 3)
    p = {}
    if expand != 1:
        p["pw1"] = _conv_gn_relu_init(ks[0], 1, cin, hid)
    p["dw"] = {"conv": nn.conv_init(ks[1], 3, hid, hid, bias=False, groups=hid),
               "gn": _gn_init(ks[1], hid)}
    p["pw2"] = _conv_gn_relu_init(ks[2], 1, hid, cout)
    return p


def _relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def _inv_res(p, x, *, stride, expand):
    y = x
    if expand != 1:
        y = _relu6(_conv_gn_relu(p["pw1"], y, relu=False))
    hid = y.shape[-1]
    y = nn.conv_apply(p["dw"]["conv"], y, stride=stride, groups=hid)
    y = _relu6(_gn(p["dw"]["gn"], y, hid))
    y = _conv_gn_relu(p["pw2"], y, relu=False)  # linear bottleneck
    if stride == 1 and x.shape[-1] == y.shape[-1]:
        y = y + x
    return y


def mobilenetv2_stages(num_classes: int = 12) -> list[Stage]:
    # (expand, cout, n, stride) — the paper's Table 2
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    stages: list[Stage] = [Stage(
        "stem", init=lambda k: _conv_gn_relu_init(k, 3, 3, 32),
        apply=lambda p, x: _relu6(_conv_gn_relu(p, x, stride=2, relu=False)),
        depth=1)]
    cin = 32
    for i, (t, c, n, s) in enumerate(cfg):
        for j in range(n):
            stride = s if j == 0 else 1
            stages.append(Stage(
                f"ir{i}_{j}",
                init=partial(_inv_res_init, cin=cin, cout=c, expand=t),
                apply=partial(_inv_res, stride=stride, expand=t),
                depth=1))
            cin = c

    def head_init(k):
        k1, k2 = jax.random.split(k)
        return {"pw": _conv_gn_relu_init(k1, 1, 320, 1280),
                "fc": nn.linear_init(k2, 1280, num_classes, bias=True)}

    def head(p, x):
        y = _relu6(_conv_gn_relu(p["pw"], x, relu=False))
        return nn.linear_apply(p["fc"], y.mean(axis=(1, 2)))

    stages.append(Stage("head", init=head_init, apply=head, depth=2))
    return stages


# ---------------------------------------------------------------------------
# tiny CNN (not a paper backbone — fast-compiling stand-in for engine tests
# and steps/sec benchmarks)
# ---------------------------------------------------------------------------

def tiny_cnn_stages(num_classes: int = 12, *, width: int = 8) -> list[Stage]:
    w = width
    stages: list[Stage] = [
        Stage("stem",
              init=lambda k: _conv_gn_relu_init(k, 3, 3, w),
              apply=lambda p, x: _conv_gn_relu(p, x, stride=2),
              depth=1),
        Stage("block",
              init=lambda k: _conv_gn_relu_init(k, 3, w, 2 * w),
              apply=lambda p, x: _conv_gn_relu(p, x, stride=2),
              depth=1),
        Stage("head",
              init=lambda k: nn.linear_init(k, 2 * w, num_classes, bias=True),
              apply=lambda p, x: nn.linear_apply(p, x.mean(axis=(1, 2))),
              depth=1),
    ]
    return stages


CNN_BUILDERS = {
    "resnet18": resnet18_stages,
    "googlenet": googlenet_stages,
    "mobilenetv2": mobilenetv2_stages,
    "tinycnn": tiny_cnn_stages,
}


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (logits.argmax(-1) == labels).mean()
