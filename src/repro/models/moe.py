"""Mixture-of-Experts: top-k router, capacity-bucketed index dispatch,
shared experts (deepseek) and dense-residual (arctic) variants.

Dispatch strategy (TPU-native, static shapes): tokens are assigned slots in
an (E, C) table via a cumsum-over-onehot position computation (GShard-style
capacity), then gathered into (E, C, D), processed by a batched expert FFN
einsum — shardable on the leading expert axis (expert parallelism over the
'model' mesh axis) — and scatter-added back with their gate weights.
Overflow tokens are dropped (standard capacity semantics); the router
carries the usual load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import modules as nn


def moe_init(key, d_model: int, n_experts: int, d_ff: int, top_k: int,
             *, n_shared: int = 0, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "router": nn.linear_init(ks[0], d_model, n_experts, dtype=jnp.float32),
        # batched expert weights: leading expert axis (shardable)
        "w_gate": nn.lecun_normal(ks[1], (n_experts, d_model, d_ff), dtype),
        "w_up": nn.lecun_normal(ks[2], (n_experts, d_model, d_ff), dtype),
        "w_down": nn.lecun_normal(ks[3], (n_experts, d_ff, d_model), dtype),
    }
    if n_shared > 0:
        from ..keys import INIT_MOE_SHARED, fold
        kss = jax.random.split(fold(key, INIT_MOE_SHARED), n_shared)
        sdff = shared_d_ff or d_ff
        p["shared"] = nn.stack_layers(
            kss[0], n_shared,
            lambda k: nn.swiglu_ffn_init(k, d_model, sdff, dtype=dtype))
    return p


def _router(p, x_flat: jax.Array, top_k: int):
    """x_flat (T, D) -> probs (T, k), idx (T, k), aux_loss."""
    logits = x_flat.astype(jnp.float32) @ p["router"]["w"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    # normalize the top-k gate weights (deepseek/mixtral convention)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    e = logits.shape[-1]
    me = jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32).mean(0)  # fraction routed (top-1 proxy)
    pe = probs.mean(0)
    aux = e * jnp.sum(me * pe)
    return top_p, top_i, aux


def moe_apply(p, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
              min_capacity: int = 4, n_groups: int = 1):
    """x (B, S, D) -> (B, S, D), aux_loss (scalar f32).

    ``n_groups > 1`` switches to GShard-style grouped dispatch: tokens are
    bucketed into G groups (aligned with the data shards by the caller's
    sharding constraints) and each group routes into a per-group capacity
    slice — the gather/scatter then stays shard-local and the only cross-
    shard traffic is the expert all-to-all. Capacity semantics are
    per-group (stricter than global; same expected occupancy).
    """
    if n_groups > 1:
        return _moe_apply_grouped(p, x, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  min_capacity=min_capacity,
                                  n_groups=n_groups)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    n_experts = p["w_gate"].shape[0]
    top_p, top_i, aux = _router(p, xf, top_k)

    capacity = max(min_capacity,
                   int(math.ceil(t * top_k * capacity_factor / n_experts)))

    # slot assignment: for each (token, k) pick, its position within its expert
    flat_e = top_i.reshape(-1)                       # (T*k,) expert ids, k-major per token
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)   # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1         # (T*k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = slot < capacity

    # token table: (E, C) of source token index (T = padding/empty)
    token_src = jnp.repeat(jnp.arange(t), top_k)
    table = jnp.full((n_experts, capacity), t, dtype=jnp.int32)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_s = jnp.where(keep, slot, capacity)          # out-of-range -> dropped
    table = table.at[safe_e, safe_s].set(jnp.where(keep, token_src, t),
                                         mode="drop")

    # gather tokens: (E, C, D); padded row is zeros
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = x_pad[table]                                # (E, C, D)

    # expert FFN (swiglu), batched over experts
    g = jnp.einsum("ecd,edf->ecf", x_e, p["w_gate"].astype(x_e.dtype))
    u = jnp.einsum("ecd,edf->ecf", x_e, p["w_up"].astype(x_e.dtype))
    h = nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x_e.dtype))

    # combine: scatter-add back with gate weights
    gate_flat = top_p.reshape(-1).astype(jnp.float32)  # (T*k,)
    gate_tab = jnp.zeros((n_experts, capacity), jnp.float32)
    gate_tab = gate_tab.at[safe_e, safe_s].set(jnp.where(keep, gate_flat, 0.0),
                                               mode="drop")
    y = jnp.zeros((t + 1, d), jnp.float32)
    y = y.at[table.reshape(-1)].add(
        (y_e * gate_tab[..., None]).reshape(-1, d).astype(jnp.float32),
        mode="drop")
    out = y[:t].reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        def shared_step(carry, layer):
            return carry + nn.swiglu_ffn_apply(layer, x), None
        out2, _ = jax.lax.scan(shared_step, jnp.zeros_like(x), p["shared"])
        out = out + out2
    return out, aux


def _moe_apply_grouped(p, x: jax.Array, *, top_k: int, capacity_factor: float,
                       min_capacity: int, n_groups: int):
    b, s, d = x.shape
    t = b * s
    assert t % n_groups == 0, (t, n_groups)
    tg = t // n_groups
    xf = x.reshape(n_groups, tg, d)
    n_experts = p["w_gate"].shape[0]
    capacity = max(min_capacity,
                   int(math.ceil(tg * top_k * capacity_factor / n_experts)))

    top_p, top_i, aux = _router(p, x.reshape(t, d), top_k)
    top_p = top_p.reshape(n_groups, tg, top_k)
    top_i = top_i.reshape(n_groups, tg, top_k)

    def dispatch_one(xg, pg, ig):
        flat_e = ig.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = slot < capacity
        token_src = jnp.repeat(jnp.arange(tg), top_k)
        table = jnp.full((n_experts, capacity), tg, dtype=jnp.int32)
        safe_e = jnp.where(keep, flat_e, 0)
        safe_s = jnp.where(keep, slot, capacity)
        table = table.at[safe_e, safe_s].set(jnp.where(keep, token_src, tg),
                                             mode="drop")
        x_pad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], axis=0)
        x_e = x_pad[table]                          # (E, C, D)
        gate = jnp.zeros((n_experts, capacity), jnp.float32)
        gate = gate.at[safe_e, safe_s].set(
            jnp.where(keep, pg.reshape(-1).astype(jnp.float32), 0.0),
            mode="drop")
        return x_e, table, gate

    x_e, table, gate = jax.vmap(dispatch_one)(xf, top_p, top_i)  # (G,E,C,D)

    g_ = jnp.einsum("gecd,edf->gecf", x_e, p["w_gate"].astype(x_e.dtype))
    u_ = jnp.einsum("gecd,edf->gecf", x_e, p["w_up"].astype(x_e.dtype))
    h = nn.silu(g_) * u_
    y_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x_e.dtype))

    def combine_one(ye, table_g, gate_g):
        y = jnp.zeros((tg + 1, d), jnp.float32)
        y = y.at[table_g.reshape(-1)].add(
            (ye * gate_g[..., None]).reshape(-1, d).astype(jnp.float32),
            mode="drop")
        return y[:tg]

    y = jax.vmap(combine_one)(y_e, table, gate)     # (G, tg, D)
    out = y.reshape(b, s, d).astype(x.dtype)
    if "shared" in p:
        def shared_step(carry, layer):
            return carry + nn.swiglu_ffn_apply(layer, x), None
        out2, _ = jax.lax.scan(shared_step, jnp.zeros_like(x), p["shared"])
        out = out + out2
    return out, aux


def moe_ref(p, x: jax.Array, *, top_k: int):
    """Dense oracle (no capacity drops): every token through its top-k experts
    via full-expert compute. O(E) FLOPs — tests only."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    top_p, top_i, aux = _router(p, xf, top_k)
    # all-expert outputs: (E, T, D)
    g = jnp.einsum("td,edf->etf", xf, p["w_gate"].astype(xf.dtype))
    u = jnp.einsum("td,edf->etf", xf, p["w_up"].astype(xf.dtype))
    y_all = jnp.einsum("etf,efd->etd", nn.silu(g) * u, p["w_down"].astype(xf.dtype))
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for j in range(top_k):
        sel = y_all[top_i[:, j], jnp.arange(xf.shape[0])]   # (T, D)
        out = out + top_p[:, j:j + 1] * sel.astype(jnp.float32)
    out = out.reshape(b, s, d).astype(x.dtype)
    if "shared" in p:
        def shared_step(carry, layer):
            return carry + nn.swiglu_ffn_apply(layer, x), None
        out2, _ = jax.lax.scan(shared_step, jnp.zeros_like(x), p["shared"])
        out = out + out2
    return out, aux
