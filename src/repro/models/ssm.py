"""State-space / linear-recurrence blocks: RWKV-6 ("Finch") and Mamba.

Both expose the same interface:

    params = *_init(key, cfg-ish dims, dtype=...)
    y, state = *_apply(params, x, state)     # x (B,S,D); scan over S
    y1, state = *_step(params, x1, state)    # x1 (B,1,D); O(1) decode step

State is O(1) in sequence length — this is what makes long_500k decode
lowerable with a tiny memory footprint for rwkv6-7b and jamba.

RWKV-6 core recurrence (per head, hd = head size):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (S: hd x hd)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent decay w_t = exp(-exp(w0 + (x_t W_w1) W_w2)) — the
"Finch" contribution — and token-shift lerps on the inputs.

Mamba:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t   (h: d_inner x N)
    y_t = C_t . h_t + D * x_t
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import modules as nn


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------

def rwkv6_init(key, d_model: int, *, head_size: int = 64, lora_r: int = 32,
               dtype=jnp.float32):
    h = d_model // head_size
    ks = jax.random.split(key, 12)
    p = {
        "mu_r": nn.normal_init(ks[0], (d_model,), dtype, 0.1),
        "mu_k": nn.normal_init(ks[1], (d_model,), dtype, 0.1),
        "mu_v": nn.normal_init(ks[2], (d_model,), dtype, 0.1),
        "mu_w": nn.normal_init(ks[3], (d_model,), dtype, 0.1),
        "wr": nn.linear_init(ks[4], d_model, d_model, dtype=dtype),
        "wk": nn.linear_init(ks[5], d_model, d_model, dtype=dtype),
        "wv": nn.linear_init(ks[6], d_model, d_model, dtype=dtype),
        "wg": nn.linear_init(ks[7], d_model, d_model, dtype=dtype),
        "wo": nn.linear_init(ks[8], d_model, d_model, dtype=dtype),
        # data-dependent decay LoRA (the Finch mechanism)
        "w0": nn.normal_init(ks[9], (d_model,), dtype, 0.5),
        "w_lora_a": nn.lecun_normal(ks[10], (d_model, lora_r), dtype),
        "w_lora_b": nn.zeros_init(ks[10], (lora_r, d_model), dtype),
        "u": nn.normal_init(ks[11], (h, head_size), dtype, 0.3),
        "ln_x": {"scale": jnp.ones((d_model,), dtype),
                 "bias": jnp.zeros((d_model,), dtype)},
    }
    return p


def rwkv6_empty_state(batch: int, d_model: int, *, head_size: int = 64,
                      dtype=jnp.float32):
    h = d_model // head_size
    return {
        "S": jnp.zeros((batch, h, head_size, head_size), jnp.float32),
        "x_prev": jnp.zeros((batch, d_model), dtype),
    }


def _rwkv6_inner(p, x, state, head_size: int):
    """x (B,S,D). Returns (y (B,S,D), new_state). Scan over S."""
    b, s, d = x.shape
    h = d // head_size
    x_prev0 = state["x_prev"].astype(x.dtype)            # (B,D)
    # token shift: x_{t-1} per position
    x_sh = jnp.concatenate([x_prev0[:, None, :], x[:, :-1, :]], axis=1)

    def lerp(mu):
        return x + (x_sh - x) * mu.astype(x.dtype)

    r = nn.linear_apply(p["wr"], lerp(p["mu_r"])).reshape(b, s, h, head_size)
    k = nn.linear_apply(p["wk"], lerp(p["mu_k"])).reshape(b, s, h, head_size)
    v = nn.linear_apply(p["wv"], lerp(p["mu_v"])).reshape(b, s, h, head_size)
    g = nn.linear_apply(p["wg"], x)
    # data-dependent decay
    xw = lerp(p["mu_w"])
    dd = (xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)
          ) @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dd)))   # (B,S,D) in (0,1)
    w = w.reshape(b, s, h, head_size)
    u = p["u"].astype(jnp.float32)                        # (H, hd)

    rf = r.astype(jnp.float32); kf = k.astype(jnp.float32); vf = v.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    S_new, ys = lax.scan(step, state["S"], xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)         # (B,S,D) f32
    # per-head groupnorm then gate
    y = nn.groupnorm_apply(p["ln_x"], y, h).astype(x.dtype)
    y = y * nn.silu(g)
    out = nn.linear_apply(p["wo"], y)
    return out, {"S": S_new, "x_prev": x[:, -1, :]}


def rwkv6_apply(p, x, state=None, *, head_size: int = 64):
    if state is None:
        state = rwkv6_empty_state(x.shape[0], x.shape[-1], head_size=head_size,
                                  dtype=x.dtype)
    return _rwkv6_inner(p, x, state, head_size)


def rwkv6_step(p, x1, state, *, head_size: int = 64):
    return _rwkv6_inner(p, x1, state, head_size)


def rwkv6_ffn_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"mu_k": nn.normal_init(k1, (d_model,), dtype, 0.1),
            "wk": nn.linear_init(k2, d_model, d_ff, dtype=dtype),
            "wv": nn.linear_init(k3, d_ff, d_model, dtype=dtype),
            "wr": nn.linear_init(k4, d_model, d_model, dtype=dtype)}


def rwkv6_ffn_apply(p, x, x_prev):
    """RWKV channel-mix: relu(k)^2 value kernel, receptance gate.
    x (B,S,D); x_prev (B,D) last token of previous chunk."""
    x_sh = jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)
    xk = x + (x_sh - x) * p["mu_k"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(nn.linear_apply(p["wk"], xk)))
    r = jax.nn.sigmoid(nn.linear_apply(p["wr"], xk))
    return r * nn.linear_apply(p["wv"], k)


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def mamba_init(key, d_model: int, *, expand: int = 2, state_dim: int = 16,
               conv_width: int = 4, dt_rank: Optional[int] = None,
               dtype=jnp.float32):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": nn.linear_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": nn.normal_init(ks[1], (conv_width, d_inner), dtype, 0.2),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_dt_a": nn.lecun_normal(ks[2], (d_inner, dt_rank), dtype),
        "w_dt_b": nn.lecun_normal(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": nn.normal_init(ks[4], (d_inner,), dtype, 0.1),
        "w_B": nn.linear_init(ks[5], d_inner, state_dim, dtype=dtype),
        "w_C": nn.linear_init(ks[6], d_inner, state_dim, dtype=dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, state_dim + 1, dtype=jnp.float32), (d_inner, state_dim))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "out_proj": nn.linear_init(ks[7], d_inner, d_model, dtype=dtype),
    }


def mamba_empty_state(batch: int, d_model: int, *, expand: int = 2,
                      state_dim: int = 16, conv_width: int = 4,
                      dtype=jnp.float32):
    d_inner = expand * d_model
    return {"h": jnp.zeros((batch, d_inner, state_dim), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype)}


def _mamba_inner(p, x, state):
    b, s, d = x.shape
    d_inner = p["conv_w"].shape[1]
    xz = nn.linear_apply(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                     # (B,S,d_inner)
    # depthwise causal conv1d with carried context
    cw = p["conv_w"].shape[0]
    ctx = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)  # (B, S+cw-1, di)
    conv = sum(ctx[:, i:i + s, :] * p["conv_w"][i].astype(xi.dtype) for i in range(cw))
    xi = nn.silu(conv + p["conv_b"].astype(xi.dtype))

    dt = jax.nn.softplus(
        (xi @ p["w_dt_a"].astype(xi.dtype)) @ p["w_dt_b"].astype(xi.dtype)
        + p["dt_bias"].astype(xi.dtype)).astype(jnp.float32)      # (B,S,di)
    Bm = nn.linear_apply(p["w_B"], xi).astype(jnp.float32)        # (B,S,N)
    Cm = nn.linear_apply(p["w_C"], xi).astype(jnp.float32)        # (B,S,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di,N)
    xf = xi.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                                 # (B,di),(B,di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])                   # (B,di,N)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]  # (B,di,N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (xf.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h_new, ys = lax.scan(step, state["h"], xs)
    y = ys.transpose(1, 0, 2) + xf * p["D"].astype(jnp.float32)[None, None]
    y = y.astype(x.dtype) * nn.silu(z)
    out = nn.linear_apply(p["out_proj"], y)
    new_conv = ctx[:, -(cw - 1):, :] if cw > 1 else state["conv"]
    return out, {"h": h_new, "conv": new_conv.astype(state["conv"].dtype)}


def mamba_apply(p, x, state=None, *, expand: int = 2, state_dim: int = 16,
                conv_width: int = 4):
    if state is None:
        state = mamba_empty_state(x.shape[0], x.shape[-1], expand=expand,
                                  state_dim=state_dim, conv_width=conv_width,
                                  dtype=x.dtype)
    return _mamba_inner(p, x, state)


def mamba_step(p, x1, state):
    return _mamba_inner(p, x1, state)
