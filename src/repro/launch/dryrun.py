import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this prints/records:
  - compiled.memory_analysis()  (per-device bytes: does it fit a v5e?)
  - compiled.cost_analysis()    (HLO FLOPs / bytes for the roofline)
  - collective bytes parsed from the partitioned HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute), the roofline's third term.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
Results append to ``results/dryrun/<arch>__<shape>__<mesh>.json``.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from ..configs import ARCHS, INPUT_SHAPES, SplitConfig          # noqa: E402
from ..core.flops import compiled_cost                          # noqa: E402
from ..obs import fenced                                        # noqa: E402
from .mesh import make_production_mesh                          # noqa: E402
from .steps import (build_step, build_body_probes,              # noqa: E402
                    shape_supported)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,4096,320]' -> bytes. '(bf16[..], f32[..])' -> sum."""
    total = 0
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in partitioned HLO.

    XLA names instructions after their op ('%all-gather.202 = f32[...]...'),
    so we key on the lhs name; async '-done' halves are skipped to avoid
    double counting their '-start'.
    """
    out = {op: {"count": 0, "bytes": 0} for op in _COLLECTIVE_OPS}
    pat = re.compile(
        r"^\s*%?(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start|-done)?[\w.\-]*\s*=\s*(.*)$")
    for line in hlo_text.splitlines():
        m = pat.match(line)
        if not m:
            continue
        op, variant, rhs = m.group(1), m.group(2), m.group(3)
        if variant == "-done":
            continue
        # output shape(s) = everything before the op token on the rhs
        idx = rhs.find(op)
        shape_str = rhs[:idx] if idx > 0 else rhs
        b = _shape_bytes(shape_str)
        out[op]["count"] += 1
        out[op]["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            outdir: str = "results/dryrun", split: SplitConfig | None = None,
            tag: str = "", opts=None) -> dict:
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag or "baseline"}
    if opts is not None:
        rec["opts"] = {k: getattr(opts, k) for k in
                       ("seq_parallel_client", "seq_parallel_server",
                        "moe_groups", "kv_dtype", "donate", "client_expert_dp")}
    ok, why = shape_supported(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _save(rec, outdir)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        built = build_step(cfg, shape_name, mesh, split=split, opts=opts)
        with mesh:
            jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings,
                             donate_argnums=built.donate_argnums)
            # lower/compile are synchronous, but the fenced primitive keeps
            # one timing idiom repo-wide (the fence is a no-op here)
            lowered, t_lower = fenced(
                lambda: jitted.lower(*built.args_sds))
            compiled, t_compile = fenced(lowered.compile)

        mem = compiled.memory_analysis()
        cost = compiled_cost(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

        rec.update({
            "status": "ok",
            "meta": built.meta,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
            "cost_raw": {k: float(v) for k, v in (cost or {}).items()
                         if isinstance(v, (int, float))},
            "collectives": coll,
            "memory": _mem_dict(mem),
            "hlo_bytes": len(hlo),
        })

        # scan-body correction: XLA cost analysis visits a while body once,
        # so per-layer group bodies are probed separately and scaled by
        # (count - 1). See build_body_probes docstring.
        try:
            corr_f = rec["flops"]
            corr_b = rec["bytes_accessed"]
            corr_c = coll["total_bytes"]
            bodies = []
            with mesh:
                for probe in build_body_probes(
                        cfg, shape_name_to_shape(shape_name), mesh,
                        split=split, opts=opts):
                    pj = jax.jit(probe.fn, in_shardings=probe.in_shardings)
                    pc = pj.lower(*probe.args_sds).compile()
                    pcost = compiled_cost(pc)
                    pcoll = collective_bytes(pc.as_text())
                    bf = float(pcost.get("flops", 0.0))
                    bb = float(pcost.get("bytes accessed", 0.0))
                    bodies.append({"group": probe.group_index,
                                   "kind": probe.kind, "count": probe.count,
                                   "flops": bf, "bytes": bb,
                                   "coll_bytes": pcoll["total_bytes"]})
                    mult = max(probe.count - 1, 0)
                    corr_f += mult * bf
                    corr_b += mult * bb
                    corr_c += mult * pcoll["total_bytes"]
            rec["bodies"] = bodies
            rec["flops_corrected"] = corr_f
            rec["bytes_corrected"] = corr_b
            rec["coll_bytes_corrected"] = corr_c
        except Exception as e:
            rec["body_probe_error"] = f"{type(e).__name__}: {e}"
    except Exception as e:  # record failures — they are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _save(rec, outdir)
    return rec


def shape_name_to_shape(name: str):
    return INPUT_SHAPES[name]


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "host_argument_size_in_bytes",
                 "peak_memory_in_bytes"):
        if hasattr(mem, attr):
            try:
                out[attr] = int(getattr(mem, attr))
            except Exception:
                pass
    return out


def _save(rec: dict, outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    slug = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("tag") and rec["tag"] != "baseline":
        slug += f"__{rec['tag']}"
    path = os.path.join(outdir, slug.replace("/", "_") + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" flops={rec['flops']:.3e} coll={rec['collectives']['total_bytes']:.3e}B"
                 f" compile={rec['compile_s']}s")
    elif status == "error":
        extra = " " + rec["error"].splitlines()[0][:120]
    print(f"[dryrun] {slug}: {status}{extra}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-parallel-client", action="store_true")
    ap.add_argument("--seq-parallel-server", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--kv-dtype", default="param")
    ap.add_argument("--donate", action="store_true")
    args = ap.parse_args()

    from .steps import PerfOptions
    opts = None
    if (args.seq_parallel_client or args.seq_parallel_server
            or args.moe_groups != 1 or args.kv_dtype != "param"
            or args.donate):
        opts = PerfOptions(seq_parallel_client=args.seq_parallel_client,
                           seq_parallel_server=args.seq_parallel_server,
                           moe_groups=args.moe_groups,
                           kv_dtype=args.kv_dtype, donate=args.donate)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.skip_existing:
                    slug = (f"{arch}__{shape}__"
                            f"{'pod2x16x16' if mp else 'pod16x16'}.json")
                    if os.path.exists(os.path.join(args.outdir, slug)):
                        print(f"[dryrun] {slug}: cached", flush=True)
                        n_ok += 1
                        continue
                rec = run_one(arch, shape, multi_pod=mp, outdir=args.outdir,
                              tag=args.tag, opts=opts)
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done ok={n_ok} err={n_err} skip={n_skip}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
