"""Serving driver: prefill a batch of prompts, then batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --batch 4 --prompt-len 32 --gen 32

Exercises the same serve_step the dry-run lowers (one token vs KV cache),
including the split-learning client/server tiers.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..data.synthetic import synthetic_tokens
from ..models.transformer import (decode_state_init, default_cut_layer,
                                  model_decode_step, model_init)
from ..obs import fenced


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--client-fraction", type=float, default=0.15)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.enc_dec:
        raise SystemExit("use examples/whisper_serve.py for enc-dec serving")
    cut = default_cut_layer(cfg, args.client_fraction)
    max_len = args.prompt_len + args.gen

    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key, cut_layer=cut)
    prompts = synthetic_tokens(key, args.batch, args.prompt_len, cfg.vocab)

    step_fn = jax.jit(
        lambda p, s, t, pos: model_decode_step(cfg, p, s, t, pos,
                                               cut_layer=cut))

    state0 = decode_state_init(cfg, args.batch, max_len, cut_layer=cut)

    def generate():
        # prefill via repeated decode steps (KV-cache exactness is tested
        # against the full forward; a fused prefill path is in launch.steps)
        logits, state = None, state0
        for t in range(args.prompt_len):
            logits, state = step_fn(params, state, prompts[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        toks = []
        for t in range(args.prompt_len, max_len):
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab],
                             axis=-1).astype(jnp.int32)
            toks.append(nxt)
            logits, state = step_fn(params, state, nxt[:, None],
                                    jnp.asarray(t, jnp.int32))
        return jnp.stack(toks, axis=1)

    # fenced: jax dispatch is async — block on the generated tokens before
    # reading the clock, or tok/s measures queueing
    gen, dt = fenced(generate)
    tps = args.batch * max_len / dt
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen} "
          f"wall {dt:.2f}s ({tps:.1f} tok/s incl. prefill)")
    print(f"[serve] sample generations (first 10 ids): {gen[:, :10].tolist()}")
    return gen


if __name__ == "__main__":
    main()
