"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init;
smoke tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def _mesh_from(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Version-portable concrete Mesh: explicit (devices-array, axis-names)
    construction — ``jax.sharding.Mesh`` wants an ndarray of devices whose
    shape IS the mesh shape, not bare ints."""
    n = math.prod(shape)
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh_from(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data*model} devices, have {n}"
    return _mesh_from((data, model), ("data", "model"))


def make_fleet_mesh(num_clients: int, *, max_data: int | None = None,
                    fsdp: int = 1, tp: int = 1):
    """``('data', 'fsdp', 'tp')`` mesh for the fleet engine.

    The ``data`` axis carries the stacked client axis (the largest size
    that divides ``num_clients`` and fits the devices left after the server
    axes) — the client tier never tensor-parallelizes (DESIGN.md §3), so
    clients only ever shard over ``data``. ``fsdp`` x ``tp`` is the server
    suffix's 2D sub-mesh: the shard_map engines leave those axes to GSPMD
    (``auto``) and constrain the server params/gradients with the
    ``launch.steps.fleet_server_pspecs`` tier specs, mirroring
    ``build_step``'s server-tier rule. Returns None when the layout needs
    more devices than exist or collapses to a single device (data = fsdp =
    tp = 1), so callers can fall back to the unsharded path."""
    navail = len(jax.devices())
    if fsdp * tp > navail:
        return None
    limit = navail // (fsdp * tp)
    if max_data is not None:
        limit = min(limit, max_data)
    data = 1
    for d in range(1, min(limit, num_clients) + 1):
        if num_clients % d == 0:
            data = d
    if data * fsdp * tp <= 1:
        return None
    return _mesh_from((data, fsdp, tp), ("data", "fsdp", "tp"))


def single_device_fleet_mesh():
    """Degenerate (1, 1, 1) fleet mesh: lets the explicit-collective
    shard_map engines compile and train on a one-device host (the
    collectives become no-ops) with the same code path as a real fleet."""
    return _mesh_from((1, 1, 1), ("data", "fsdp", "tp"))


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free AbstractMesh across jax versions: newer jax takes
    ``(sizes, names)``; 0.4.3x takes one tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
