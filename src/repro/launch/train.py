"""End-to-end training driver (runs for real on this container at reduced
scale; the same code path drives the production mesh on TPU).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --batch 8 --seq 128 --reduced

Implements the eEnergy-Split loop: split cut per --client-fraction, AdamW
on both tiers, FedAvg period r (SPMD pmean — see DESIGN.md §3), and the
EnergyTracker accounting per phase.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SplitConfig
from ..configs.base import InputShape
from ..core.energy import EnergyTracker, JETSON_AGX_ORIN, TPU_V5E
from ..data.synthetic import synthetic_tokens
from ..models.transformer import default_cut_layer, lm_loss, model_init
from ..obs import fenced
from ..optim import adamw, apply_updates, clip_by_global_norm
from ..checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--client-fraction", type=float, default=0.15)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduced config (CPU-friendly)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    cut = default_cut_layer(cfg, args.client_fraction)
    print(f"[train] arch={cfg.name} layers={cfg.n_layers} cut={cut} "
          f"(client fraction {args.client_fraction})")

    key = jax.random.PRNGKey(0)
    params = model_init(cfg, key, cut_layer=cut)
    opt = adamw(args.lr, weight_decay=0.01)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, batch, cut_layer=cut), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    tracker = EnergyTracker(TPU_V5E)
    losses = []
    # cumulative progress stamp, not a perf window (per-step windows below
    # are fenced)
    t0 = time.time()  # repro: ignore[raw-timer] -- wall-clock progress print, not a measurement
    for step in range(args.steps):
        kb = jax.random.fold_in(key, step)
        tokens = synthetic_tokens(kb, args.batch, args.seq, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        if cfg.frontend == "patch_embed":
            batch["patch_embeds"] = 0.02 * jax.random.normal(
                kb, (args.batch, cfg.frontend_tokens, cfg.d_model))
        if cfg.enc_dec:
            batch["frames"] = 0.02 * jax.random.normal(
                kb, (args.batch, cfg.enc_seq_len, cfg.d_model))
        # fenced step window: block on the step's outputs before reading
        # the clock (async dispatch would otherwise bill queueing time)
        (params, opt_state, loss, gnorm), dt = fenced(
            lambda p=params, o=opt_state, b=batch: train_step(p, o, b))
        loss = float(loss)
        tracker.track_time(f"step{step}", dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:4d} loss {loss:.4f} gnorm {float(gnorm):.3f} "
                  f"({time.time() - t0:.1f}s)")  # repro: ignore[raw-timer] -- cumulative progress stamp, not a measurement

    tot = tracker.total()
    print(f"[train] done: final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"wall {tot.time_s:.1f}s energy~{tot.energy_j/1e3:.2f}kJ "
          f"co2~{tot.co2_g:.3f}g")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, meta={"arch": cfg.name,
                                                 "steps": args.steps,
                                                 "loss": losses[-1]})
        print(f"[train] checkpoint -> {args.ckpt}")
    return losses


if __name__ == "__main__":
    main()
