"""Step builders: (train | prefill | decode) x (arch x input-shape x mesh).

Produces the jit-able step function plus ShapeDtypeStruct stand-ins and
NamedShardings for every input/output — the dry-run lowers these without
allocating anything; the real launchers feed live arrays with the same
shardings.

Split learning is first-class here: every step is built around the
``SplitConfig`` cut — client groups get DP-only sharding, server groups get
2D (fsdp x tp); the smashed activation at the cut is the UAV-link tensor
(its bytes are what the roofline layer meters as link traffic L).
"""
from __future__ import annotations

import dataclasses
import math
import re
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, InputShape, SplitConfig, INPUT_SHAPES
from ..models.transformer import (build_groups, decode_state_init,
                                  default_cut_layer, lm_loss, model_decode_step,
                                  model_forward, model_init, vocab_padded)
from ..optim import adamw, apply_updates
from ..parallel.sharding import (ShardingPolicy, mesh_axis_sizes,
                                 param_pspecs, set_policy, FSDP_AXIS, TP_AXIS)

# long-context variant for full-attention archs: block-sparse sliding window
LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class PerfOptions:
    """Beyond-paper performance levers (EXPERIMENTS.md §Perf).

    seq_parallel_client: shard the sequence over the idle 'model' axis
        during the client-tier phase (weights stay replicated -> still
        faithful to 'edge devices cannot do TP').
    seq_parallel_server: same for the server tier (Megatron-SP).
    moe_groups: GShard-style grouped MoE dispatch (1 = global).
    kv_dtype: 'param' | 'int8' — quantized KV cache for decode.
    """
    seq_parallel_client: bool = False
    seq_parallel_server: bool = False
    moe_groups: int = 1
    kv_dtype: str = "param"
    donate: bool = False       # alias cache/params in place (serving must)
    client_expert_dp: bool = False  # expert-parallel client tier over 'data'

    @property
    def tiers(self) -> tuple:
        t = ()
        if self.seq_parallel_client:
            t += ("client",)
        if self.seq_parallel_server:
            t += ("server",)
        return t


@dataclasses.dataclass(frozen=True)
class BuiltStep:
    name: str
    fn: Any                    # jit-able python callable
    args_sds: tuple            # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    out_shardings: Any
    meta: dict
    donate_argnums: tuple = ()


def _dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def _dp_size(mesh: Mesh) -> int:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return shape.get("pod", 1) * shape.get("data", 1)


def effective_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """cfg window, or the block-sparse SWA variant for long_500k on
    full-attention archs (DESIGN.md §Shape-applicability)."""
    if cfg.swa_window:
        return cfg.swa_window
    if shape.name == "long_500k":
        return LONG_CONTEXT_WINDOW
    return None


def shape_supported(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    if cfg.enc_dec and shape.name == "long_500k":
        return False, ("whisper's decoder family tops out at ~448 tokens / "
                       "30s windows; 524k decode is out of family range "
                       "(DESIGN.md skip)")
    return True, ""


def tier_fn_for(cfg: ArchConfig, cut_layer: Optional[int], *,
                client_name: str = "client"):
    """Maps a param path 'groups/<i>/...' to its split tier."""
    if cut_layer is None:
        return lambda path: "server"
    groups = build_groups(cfg, cut_layer=cut_layer)
    tiers = [g.tier for g in groups]

    def fn(path: str) -> str:
        m = re.match(r"groups/(\d+)/", path)
        if m:
            t = tiers[int(m.group(1))]
            return client_name if t == "client" else t
        if path.startswith("embed"):
            return client_name   # embedding feeds the client prefix
        return "server"

    return fn


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _tree_named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: _named(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# batch / state specs
# ---------------------------------------------------------------------------

def batch_sds(cfg: ArchConfig, shape: InputShape, *, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    d = {}
    if cfg.frontend == "patch_embed":
        s_text = s - cfg.frontend_tokens
        d["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        d["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), cfg.param_dtype)
    else:
        d["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.enc_dec:
        d["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), cfg.param_dtype)
    if with_labels:
        d["labels"] = jax.ShapeDtypeStruct(d["tokens"].shape, jnp.int32)
    return d


def batch_pspecs(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                 with_labels: bool):
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)
    bspec = dp if shape.global_batch % dpn == 0 else None
    d = {"tokens": P(bspec, None)}
    if cfg.frontend == "patch_embed":
        d["patch_embeds"] = P(bspec, None, None)
    if cfg.enc_dec:
        d["frames"] = P(bspec, None, None)
    if with_labels:
        d["labels"] = P(bspec, None)
    return d


_STATE_RULES = [
    (r"(k|v)(\d+)?_scale$", "cache_scale"),   # (n,B,C,Kh):    B->data, C->model
    (r"(^|/)(k|v|k\d+|v\d+)$", "cache"),     # (n,B,C,Kh,hd): B->data, C->model
    (r"(^|/)(ck|cv)$", "cache"),
    (r"(^|/)S$", "rwkv_S"),                  # (n,B,H,hd,hd): B->data, H->model
    (r"(^|/)h\d+$", "mamba_h"),              # (n,B,di,N):   B->data, di->model
    (r"(^|/)c\d+$", "mamba_conv"),           # (n,B,cw-1,di): B->data, di->model
    (r"x_prev$", "vec"),                     # (n,B,D):      B->data, D->model
]


def state_pspecs(state_sds, mesh: Mesh):
    shape_of = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsz, msz = shape_of.get("data", 1), shape_of.get("model", 1)

    def guard(dim, size, ax):
        return ax if (size > 1 and dim % size == 0) else None

    def spec_for(path: str, shp: tuple) -> P:
        for pat, kind in _STATE_RULES:
            if re.search(pat, path):
                if kind == "cache":
                    return P(None, guard(shp[1], dsz, "data"),
                             guard(shp[2], msz, "model"), None, None)
                if kind == "cache_scale":
                    return P(None, guard(shp[1], dsz, "data"),
                             guard(shp[2], msz, "model"), None)
                if kind == "rwkv_S":
                    return P(None, guard(shp[1], dsz, "data"),
                             guard(shp[2], msz, "model"), None, None)
                if kind == "mamba_h":
                    return P(None, guard(shp[1], dsz, "data"),
                             guard(shp[2], msz, "model"), None)
                if kind == "mamba_conv":
                    return P(None, guard(shp[1], dsz, "data"), None,
                             guard(shp[3], msz, "model"))
                if kind == "vec":
                    return P(None, guard(shp[1], dsz, "data"),
                             guard(shp[2], msz, "model"))
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_sds)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append(spec_for(name, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                     split: Optional[SplitConfig] = None,
                     remat: bool = True, lr: float = 1e-4,
                     opts: Optional[PerfOptions] = None) -> BuiltStep:
    split = split or SplitConfig()
    opts = opts or PerfOptions()
    cut = default_cut_layer(cfg, split.client_fraction)
    window = effective_window(cfg, shape)
    opt = adamw(lr, weight_decay=0.01)
    policy = ShardingPolicy(mesh)
    tier = tier_fn_for(cfg, cut, client_name=(
        "client_edp" if opts.client_expert_dp else "client"))

    def step(params, opt_state, batch):
        with set_policy(policy):
            def loss_fn(p):
                return lm_loss(cfg, p, batch, window=window,
                               cut_layer=cut, remat=remat,
                               seq_parallel_tiers=opts.tiers,
                               moe_groups=opts.moe_groups)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            updates, new_opt = opt.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            metrics = dict(metrics, loss=loss)
            return new_params, new_opt, metrics

    params_sds = jax.eval_shape(partial(model_init, cfg, cut_layer=cut),
                                jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(opt.init, params_sds)
    b_sds = batch_sds(cfg, shape, with_labels=True)

    pspecs = param_pspecs(params_sds, mesh, tier_fn=tier)
    # optimizer moments follow the param specs; step counter replicated
    from ..optim.optimizers import OptState
    ospecs = OptState(step=P(),
                      mu=param_pspecs(params_sds, mesh, tier_fn=tier),
                      nu=param_pspecs(params_sds, mesh, tier_fn=tier))
    bspecs = batch_pspecs(cfg, shape, mesh, with_labels=True)

    in_sh = (_tree_named(mesh, pspecs), _tree_named(mesh, ospecs),
             _tree_named(mesh, bspecs))
    out_sh = (_tree_named(mesh, pspecs), _tree_named(mesh, ospecs), None)
    return BuiltStep(name="train_step", fn=step,
                     args_sds=(params_sds, opt_sds, b_sds),
                     in_shardings=in_sh, out_shardings=out_sh,
                     meta={"cut_layer": cut, "window": window,
                           "kind": "train"},
                     donate_argnums=(0, 1) if opts.donate else ())


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                       split: Optional[SplitConfig] = None,
                       opts: Optional[PerfOptions] = None) -> BuiltStep:
    split = split or SplitConfig()
    opts = opts or PerfOptions()
    cut = default_cut_layer(cfg, split.client_fraction)
    window = effective_window(cfg, shape)
    policy = ShardingPolicy(mesh)
    tier = tier_fn_for(cfg, cut, client_name=(
        "client_edp" if opts.client_expert_dp else "client"))

    def step(params, batch):
        with set_policy(policy):
            logits, aux = model_forward(cfg, params, batch, window=window,
                                        cut_layer=cut,
                                        seq_parallel_tiers=opts.tiers,
                                        moe_groups=opts.moe_groups)
            return logits

    params_sds = jax.eval_shape(partial(model_init, cfg, cut_layer=cut),
                                jax.random.PRNGKey(0))
    b_sds = batch_sds(cfg, shape, with_labels=False)
    pspecs = param_pspecs(params_sds, mesh, tier_fn=tier)
    bspecs = batch_pspecs(cfg, shape, mesh, with_labels=False)
    dp = _dp_axes(mesh)
    out_spec = P(dp if shape.global_batch % _dp_size(mesh) == 0 else None,
                 None, TP_AXIS if vocab_padded(cfg) % 16 == 0 else None)
    return BuiltStep(name="prefill_step", fn=step,
                     args_sds=(params_sds, b_sds),
                     in_shardings=(_tree_named(mesh, pspecs),
                                   _tree_named(mesh, bspecs)),
                     out_shardings=_named(mesh, out_spec),
                     meta={"cut_layer": cut, "window": window,
                           "kind": "prefill"})


def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                      split: Optional[SplitConfig] = None,
                      opts: Optional[PerfOptions] = None) -> BuiltStep:
    split = split or SplitConfig()
    opts = opts or PerfOptions()
    cut = default_cut_layer(cfg, split.client_fraction)
    window = effective_window(cfg, shape)
    policy = ShardingPolicy(mesh)
    tier = tier_fn_for(cfg, cut, client_name=(
        "client_edp" if opts.client_expert_dp else "client"))
    b = shape.global_batch

    def step(params, state, token, pos):
        with set_policy(policy):
            logits, new_state = model_decode_step(
                cfg, params, state, token, pos, window=window, cut_layer=cut)
            return logits, new_state

    params_sds = jax.eval_shape(partial(model_init, cfg, cut_layer=cut),
                                jax.random.PRNGKey(0))
    state_sds = jax.eval_shape(
        partial(decode_state_init, cfg, b, shape.seq_len, window=window,
                cut_layer=cut, kv_dtype=opts.kv_dtype))
    token_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = param_pspecs(params_sds, mesh, tier_fn=tier)
    sspecs = state_pspecs(state_sds, mesh)
    dpn = _dp_size(mesh)
    dp = _dp_axes(mesh)
    tok_spec = P(dp if b % dpn == 0 else ("data" if b % 16 == 0 else None), None)
    logit_spec = P(tok_spec[0], None, TP_AXIS)

    in_sh = (_tree_named(mesh, pspecs), _tree_named(mesh, sspecs),
             _named(mesh, tok_spec), _named(mesh, P()))
    out_sh = (_named(mesh, logit_spec), _tree_named(mesh, sspecs))
    return BuiltStep(name="serve_step", fn=step,
                     args_sds=(params_sds, state_sds, token_sds, pos_sds),
                     in_shardings=in_sh, out_shardings=out_sh,
                     meta={"cut_layer": cut, "window": window,
                           "kind": "decode"},
                     donate_argnums=(1,) if opts.donate else ())


def fleet_server_pspecs(server_params: Any, mesh: Mesh) -> Any:
    """Server-tier specs for the fleet engines, on the ``('data','fsdp','tp')``
    fleet mesh (``launch.mesh.make_fleet_mesh``).

    The same DESIGN.md §3 tier rule ``build_step`` applies through
    ``param_pspecs`` — client tier never tensor-parallelizes, server tier is
    fully 2D-sharded — mapped onto the fleet mesh's literal ``fsdp``/``tp``
    axes for arbitrary param trees (the fleet's CNN stage lists have no
    transformer name rules to match): matrix-like leaves shard their last
    two dims ``(fsdp, tp)``, vectors follow their output-channel dim over
    ``tp``, every dim divisibility-guarded against its axis size exactly as
    ``parallel.sharding._spec_for`` guards the launch-layer specs. The
    shard_map fleet rounds constrain the server suffix's params and
    gradients with these specs inside the map body (the ``fsdp``/``tp``
    axes are GSPMD-``auto`` there), so the server model scales over its 2D
    sub-mesh while the client axis stays manual over ``data``.
    """
    sizes = mesh_axis_sizes(mesh)
    f, t = sizes.get("fsdp", 1), sizes.get("tp", 1)

    def spec(leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        axes = [None] * len(shape)
        if t > 1 and shape[-1] % t == 0:
            axes[-1] = "tp"
        if len(shape) >= 2 and f > 1 and shape[-2] % f == 0:
            axes[-2] = "fsdp"
        return P(*axes)

    return jax.tree_util.tree_map(spec, server_params)


def build_step(cfg: ArchConfig, shape_name: str, mesh: Mesh, *,
               split: Optional[SplitConfig] = None,
               opts: Optional[PerfOptions] = None, **kw) -> BuiltStep:
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name}: {why}")
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, split=split, opts=opts, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, split=split, opts=opts)
    return build_decode_step(cfg, shape, mesh, split=split, opts=opts)


# ---------------------------------------------------------------------------
# per-group body probes: exact scan-body costs
#
# XLA's HloCostAnalysis visits a while-loop body ONCE (trip count ignored),
# and the partitioned HLO text prints it once — so the main lowering under-
# counts scanned layers by ~count_g per group. Each probe lowers ONE layer
# of one group with the production shardings; the dry-run then corrects:
#     total = main + sum_g (count_g - 1) * body_g
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BodyProbe:
    group_index: int
    kind: str
    count: int                  # multiplicity in the real model
    fn: Any
    args_sds: tuple
    in_shardings: tuple


def build_body_probes(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                      split: Optional[SplitConfig] = None,
                      opts: Optional[PerfOptions] = None) -> list[BodyProbe]:
    from ..models.transformer import (build_groups, group_init, group_apply,
                                      decode_state_init, _group_decode)
    split = split or SplitConfig()
    opts = opts or PerfOptions()
    cut = default_cut_layer(cfg, split.client_fraction)
    window = effective_window(cfg, shape)
    groups = build_groups(cfg, cut_layer=cut)
    policy = ShardingPolicy(mesh)
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)
    b = shape.global_batch
    bspec = dp if b % dpn == 0 else None

    probes = []
    state_sds_all = None
    if shape.kind == "decode":
        state_sds_all = jax.eval_shape(partial(
            decode_state_init, cfg, b, shape.seq_len, window=window,
            cut_layer=cut, kv_dtype=opts.kv_dtype))

    for gi, g in enumerate(groups):
        g1 = dataclasses.replace(g, count=1)
        params_sds = jax.eval_shape(
            lambda k, g1=g1: group_init(k, cfg, g1), jax.random.PRNGKey(0))
        probe_tier = g.tier
        if probe_tier == "client" and opts.client_expert_dp:
            probe_tier = "client_edp"
        pspecs = param_pspecs(params_sds, mesh, tier=probe_tier)
        seq = cfg.enc_seq_len if g.kind == "enc" else shape.seq_len
        if cfg.frontend == "patch_embed" and g.kind != "enc":
            seq = shape.seq_len  # patches included in seq budget

        if shape.kind in ("train", "prefill"):
            x_sds = jax.ShapeDtypeStruct((b, seq, cfg.d_model), cfg.param_dtype)
            extra, extra_sh = (), ()
            if g.kind == "xdec":
                extra = (jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq_len, cfg.d_model), cfg.param_dtype),)
                extra_sh = (_named(mesh, P(bspec, None, None)),)
            pos_shape = (b, seq)

            if shape.kind == "train":
                def fn(gp, x, *enc, g1=g1, pos_shape=pos_shape):
                    with set_policy(policy):
                        positions = jnp.broadcast_to(
                            jnp.arange(pos_shape[1], dtype=jnp.int32), pos_shape)
                        act = (("dp", "tp", None)
                               if g1.tier in opts.tiers
                               else ("dp", None, None))
                        def fwd(gp_, x_):
                            y, aux = group_apply(
                                cfg, g1, gp_, x_, jnp.zeros((), jnp.float32),
                                positions=positions, window=window,
                                enc_out=enc[0] if enc else None, remat=True,
                                act_spec=act, moe_groups=opts.moe_groups)
                            return y.astype(jnp.float32).sum() + aux
                        g_out = jax.grad(fwd, argnums=(0, 1))(gp, x)
                        return g_out
            else:
                def fn(gp, x, *enc, g1=g1, pos_shape=pos_shape):
                    with set_policy(policy):
                        positions = jnp.broadcast_to(
                            jnp.arange(pos_shape[1], dtype=jnp.int32), pos_shape)
                        act = (("dp", "tp", None)
                               if g1.tier in opts.tiers
                               else ("dp", None, None))
                        y, aux = group_apply(
                            cfg, g1, gp, x, jnp.zeros((), jnp.float32),
                            positions=positions, window=window,
                            enc_out=enc[0] if enc else None,
                            act_spec=act, moe_groups=opts.moe_groups)
                        return y
            probes.append(BodyProbe(
                group_index=gi, kind=g.kind, count=g.count, fn=fn,
                args_sds=(params_sds, x_sds) + extra,
                in_shardings=(_tree_named(mesh, pspecs),
                              _named(mesh, P(bspec, None, None))) + extra_sh))
        else:  # decode
            if g.kind == "enc":
                continue
            st_g = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((1,) + s.shape[1:], s.dtype),
                state_sds_all[gi])
            sspecs = state_pspecs(st_g, mesh)
            x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.param_dtype)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

            def fn(gp, st, x, pos, g1=g1):
                with set_policy(policy):
                    y, ns = _group_decode(cfg, g1, gp, st, x, pos,
                                          window=window)
                    return y, ns
            probes.append(BodyProbe(
                group_index=gi, kind=g.kind, count=g.count, fn=fn,
                args_sds=(params_sds, st_g, x_sds, pos_sds),
                in_shardings=(_tree_named(mesh, pspecs),
                              _tree_named(mesh, sspecs),
                              _named(mesh, P(bspec, None, None)),
                              _named(mesh, P()))))
    return probes
