from .optimizers import (adamw, sgd, OptState, Optimizer, apply_updates,
                         clip_by_global_norm, cosine_schedule, warmup_cosine,
                         constant_schedule, init_stacked)

__all__ = ["adamw", "sgd", "OptState", "Optimizer", "apply_updates",
           "clip_by_global_norm", "cosine_schedule", "warmup_cosine",
           "constant_schedule", "init_stacked"]
