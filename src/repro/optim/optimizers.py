"""Optimizers built from scratch (no optax in the container).

API mirrors the (init, update) pair convention:

    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[..., tuple[Params, OptState]]


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def constant_schedule(v: float) -> Schedule:
    return lambda step: jnp.asarray(v, jnp.float32)


def cosine_schedule(peak: float, total_steps: int, *, floor: float = 0.0) -> Schedule:
    def sched(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return sched


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  *, floor: float = 0.0) -> Schedule:
    def sched(step):
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw(lr: Union[float, Schedule] = 1e-3, *, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01,
          moment_dtype=jnp.float32) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params: Params) -> OptState:
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree_util.tree_map(z, params),
                        nu=jax.tree_util.tree_map(z, params))

    def update(grads: Params, state: OptState, params: Params) -> tuple[Params, OptState]:
        step = state.step + 1
        lr_t = sched(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(moment_dtype)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(moment_dtype)
            return (-lr_t * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: Union[float, Schedule] = 1e-2, *, momentum: float = 0.9,
        nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params: Params) -> OptState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree_util.tree_map(z, params), nu=None)

    def update(grads: Params, state: OptState, params: Params):
        step = state.step + 1
        lr_t = sched(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            m = momentum * m + g
            d = g + momentum * m if nesterov else m
            return (-lr_t * d).astype(p.dtype), m

        out = jax.tree_util.tree_map(upd, grads, state.mu, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return updates, OptState(step=step, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def init_stacked(opt: Optimizer, params: Params, n: int) -> OptState:
    """Optimizer state for ``n`` model replicas sharing ``params``' shape,
    stacked on a leading client axis (every leaf, including the step
    counter, gains a leading ``n`` dim so ``lax.scan`` over clients slices
    one replica's state per iteration)."""
    state = opt.init(params)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), state)
