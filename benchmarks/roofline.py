"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh), from results/dryrun/*.json:

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s ICI)

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE counts,
so chips=1 in the denominators below (constants are per chip); the
collective parser sums across the module, so it is divided by chip count.

Also reports MODEL_FLOPS = 6*N(_active)*D vs HLO_FLOPs (useful-compute
ratio; catches remat/redundancy waste) and the dominant term with a one-
line lever.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, INPUT_SHAPES  # noqa: E402

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link per chip


def param_count(cfg) -> tuple[float, float]:
    """(total params N, active params N_active) — analytic."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd, H, KH = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    embed = v * d
    total = active = embed
    for i in range(L):
        if cfg.ssm_kind == "rwkv6":
            layer = 4 * d * d + d * d  # wr wk wv wg wo
            layer += 2 * d * f + d * d  # channel mix
        elif cfg.ssm_kind == "mamba" and cfg.attn_period and \
                (i % cfg.attn_period != cfg.attn_period - 1):
            di = cfg.ssm_expand * d
            layer = d * 2 * di + di * d + di * (cfg.ssm_state_dim * 2) \
                + di * max(1, d // 16) * 2
        else:
            layer = d * (H * hd) + 2 * d * (KH * hd) + (H * hd) * d
        # ffn
        if cfg.is_moe_layer(i):
            fe = cfg.moe_d_ff or f
            experts = cfg.n_experts * 3 * d * fe
            act = cfg.top_k * 3 * d * fe
            if cfg.n_shared_experts:
                act += cfg.n_shared_experts * 3 * d * fe
                experts += cfg.n_shared_experts * 3 * d * fe
            if cfg.dense_residual:
                act += 3 * d * f
                experts += 3 * d * f
            total += layer + experts
            active += layer + act
        else:
            total += layer + 3 * d * f
            active += layer + 3 * d * f
    if cfg.enc_dec:
        total += cfg.n_enc_layers * (4 * d * d + 2 * d * f)
        active = total
    return float(total), float(active)


def model_flops(cfg, shape) -> float:
    """6*N_active*D for train; 2*N_active*D for inference forward."""
    _, n_active = param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(record: dict) -> dict:
    cfg = ARCHS[record["arch"]]
    shape = INPUT_SHAPES[record["shape"]]
    chips = 512 if "2x16" in record["mesh"] else 256
    # per-device counts from the partitioned HLO, scan-body corrected
    # (build_body_probes) when available
    flops_dev = record.get("flops_corrected", record["flops"])
    bytes_dev = record.get("bytes_corrected", record["bytes_accessed"])
    coll_dev = record.get("coll_bytes_corrected",
                          record["collectives"]["total_bytes"])

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW           # collective shapes are per-shard
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / (flops_dev * chips) if flops_dev > 0 else 0.0

    lever = {
        "compute": "raise per-chip utilization: bigger fused matmul tiles / "
                   "less remat recompute",
        "memory": "cut HBM traffic: fuse elementwise chains, bf16 "
                  "activations, flash-attention tiling (no S^2 spill)",
        "collective": "reshard to kill all-gathers at layer boundaries / "
                      "overlap collectives with compute / shrink the "
                      "cut-layer link tensor (int8)",
    }[dominant]
    return {
        **{k: record[k] for k in ("arch", "shape", "mesh", "tag")},
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_compute_ratio": useful,
        "lever": lever,
        "coll_bytes": coll_dev,
        "mem_per_dev": record.get("memory", {}),
        "corrected": "flops_corrected" in record,
    }


def load_all(outdir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        r = json.load(open(path))
        if r.get("status") == "ok":
            recs.append(analyze(r))
        elif r.get("status") == "skipped":
            recs.append({**{k: r[k] for k in ("arch", "shape", "mesh", "tag")},
                         "skipped": r["reason"]})
    return recs


def run(print_csv: bool = True, outdir: str = "results/dryrun") -> list[dict]:
    rows = load_all(outdir)
    if print_csv:
        for r in rows:
            if "skipped" in r:
                print(f"roofline,{r['arch']}/{r['shape']}/{r['mesh']},0,skipped")
                continue
            tag = f"#{r['tag']}" if r.get('tag', 'baseline') != 'baseline' else ''
            print(f"roofline,{r['arch']}/{r['shape']}/{r['mesh']}{tag},0,"
                  f"tc={r['t_compute_s']:.3e}s;tm={r['t_memory_s']:.3e}s;"
                  f"tcoll={r['t_collective_s']:.3e}s;dom={r['dominant']};"
                  f"useful={r['useful_compute_ratio']:.2f}")
    return rows


def to_markdown(rows: list[dict]) -> str:
    lines = ["| arch | shape | mesh | t_compute | t_memory | t_collective | "
             "dominant | useful ratio |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | skipped | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e}s | {r['t_memory_s']:.2e}s "
            f"| {r['t_collective_s']:.2e}s | **{r['dominant']}** "
            f"| {r['useful_compute_ratio']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--outdir", default="results/dryrun")
    a = ap.parse_args()
    rows = run(print_csv=not a.markdown, outdir=a.outdir)
    if a.markdown:
        print(to_markdown(rows))
