"""Paper Fig. 2: deployment-strategy comparison.

eEnergy-Split (Algorithm 1) vs K-means vs GASBAC on the paper's three
layouts: uniform 25/100ac, random 25/100ac, uniform 49/200ac (CR = 200 m).
Reports #edge devices, TSP tour length, per-round UAV energy, load balance.
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.deployment import (coverage_ok, deploy_edge_devices,  # noqa: E402
                                   deploy_gasbac, deploy_kmeans,
                                   random_sensors, uniform_grid_sensors)
from repro.core.trajectory import greedy_tour_plan, plan_tour  # noqa: E402
from repro.obs import fenced  # noqa: E402

CR = 200.0
LAYOUTS = [
    ("uniform_100ac_25", lambda: uniform_grid_sensors(100, 25)),
    ("random_100ac_25", lambda: random_sensors(100, 25, seed=7)),
    ("uniform_200ac_49", lambda: uniform_grid_sensors(200, 49)),
]
METHODS = [
    ("eEnergy-Split", deploy_edge_devices, plan_tour),
    ("K-means", deploy_kmeans, greedy_tour_plan),
    ("GASBAC", deploy_gasbac, greedy_tour_plan),
]


def run(print_csv: bool = True) -> list[dict]:
    rows = []
    base = np.zeros(2)
    for lname, gen in LAYOUTS:
        pts = gen()
        for mname, deploy, planner in METHODS:
            # fenced: blocks on device buffers before reading the clock, so
            # the measurement is deploy+plan execution, not async dispatch
            def deploy_and_plan(deploy=deploy, planner=planner):
                dep = deploy(pts, CR)
                return dep, planner(dep.edge_coords, base)

            (dep, plan), wall_s = fenced(deploy_and_plan)
            us = wall_s * 1e6
            loads = dep.loads
            rows.append({
                "bench": "deployment(fig2)",
                "case": f"{lname}/{mname}",
                "us_per_call": us,
                "edge_devices": len(dep.edge_indices),
                "tour_m": round(plan.tour_length, 1),
                "kj_per_round": round(plan.e_per_round / 1e3, 2),
                "rounds": plan.rounds,
                "covered": coverage_ok(dep),
                "load_imbalance": round(float(loads.max() / max(loads.mean(), 1e-9)), 2),
            })
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},{r['us_per_call']:.0f},"
                  f"edges={r['edge_devices']};tour={r['tour_m']}m;"
                  f"kJ/round={r['kj_per_round']};rounds={r['rounds']};"
                  f"covered={r['covered']}")
    return rows


if __name__ == "__main__":
    run()
