"""Paper Fig. 3: FL vs SL_{a,b} classification performance (radar metrics).

Synthetic KAP stand-in (12 classes, non-IID: 4 clients x 3 classes). The
claim under test is the paper's qualitative one: with a server-heavy split
(server >= 60% of layers), SL matches or beats FL under non-IID data —
because the server sub-model is updated on every client's batch, while FL
only averages diverged full models once per round.

Default scope is CPU-budgeted: MobileNetV2 (the paper's best backbone) with
FL, SL_25,75 and SL_15,85; ``--full`` runs all 3 backbones x 5 settings.
Results cache to results/sl_accuracy.json. Runs on specs
(``paper_spec`` -> ``compile_experiment``) — the last ``train_fl``/
``train_sl`` shim caller was ported here when the shims were dropped.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import compile_experiment  # noqa: E402
from repro.core.paper_train import PaperTrainConfig, paper_spec  # noqa: E402
from repro.data.synthetic import SyntheticPestImages  # noqa: E402
from repro.obs import Obs, ObsConfig, fenced  # noqa: E402

CACHE = "results/sl_accuracy.json"

# paper Fig. 3 reference numbers (accuracy %) for the report
PAPER_ACC = {
    "resnet18": {"FL": 72.34, "SL_75_25": 71.34, "SL_40_60": 75.98,
                 "SL_25_75": 73.89, "SL_15_85": 78.53},
    "googlenet": {"FL": 63.15, "SL_40_60": 78.16, "SL_25_75": 80.35,
                  "SL_15_85": 80.16},
    "mobilenetv2": {"FL": 80.62, "SL_75_25": 81.35, "SL_40_60": 80.62,
                    "SL_25_75": 82.35, "SL_15_85": 80.98},
}


def run(models=("mobilenetv2",), settings=("FL", "SL_25_75", "SL_15_85"),
        rounds: int = 12, local_steps: int = 4, n_train: int = 1200,
        n_test: int = 240, image_size: int = 32, use_cache: bool = True,
        print_csv: bool = True, obs=None) -> list[dict]:
    obs = Obs.ensure(obs)
    cached = {}
    if use_cache and os.path.exists(CACHE):
        cached = {r["case"]: r for r in json.load(open(CACHE))}

    gen = SyntheticPestImages(image_size=image_size)
    x, y = map(np.asarray, gen.dataset(n_train))
    xt, yt = map(np.asarray, gen.sample(jax.random.PRNGKey(99), n_test))

    rows = []
    for model in models:
        for setting in settings:
            case = f"{model}/{setting}"
            if case in cached:
                rows.append(cached[case])
                continue
            t0 = time.time()
            cfg = PaperTrainConfig(model=model, global_rounds=rounds,
                                   local_steps=local_steps,
                                   image_size=image_size)
            if setting == "FL":
                kind = "fl"
            else:
                kind = "sl"
                cfg.client_fraction = {"SL_75_25": 0.75, "SL_40_60": 0.40,
                                       "SL_25_75": 0.25,
                                       "SL_15_85": 0.15}[setting]
            with obs.span(f"accuracy/{model}_{setting}"):
                plan = compile_experiment(paper_spec(cfg, kind),
                                          data=(x, y, xt, yt), obs=obs)
                # steps/s excludes spec lowering + compile-time FLOP
                # counting, matching the methodology of the rows already
                # cached (the old trainers clocked from init onward);
                # `seconds` stays total wall. `fenced` blocks on device
                # buffers before reading the clock (per-round record
                # assembly already syncs, but the fence makes it explicit).
                (state, records), train_s = fenced(plan.run)
            n_steps = (plan.num_rounds * cfg.num_clients * cfg.local_steps)
            if kind == "sl":
                extra = {"link_MB": round(
                             sum(r.link_bytes for r in records) / 1e6, 2),
                         "cut_index": plan.cut_of_client[0]}
            else:
                extra = {}
            m = state.last_metrics
            rows.append({
                "bench": "sl_accuracy(fig3)",
                "case": case,
                "seconds": round(time.time() - t0, 1),
                "steps_per_s": round(n_steps / max(train_s, 1e-9), 2),
                "accuracy": round(m["accuracy"], 4),
                "f1": round(m["f1"], 4),
                "mcc": round(m["mcc"], 4),
                "precision": round(m["precision"], 4),
                "recall": round(m["recall"], 4),
                "client_kj": round(
                    sum(r.client_energy_j for r in records) / 1e3, 4),
                "server_kj": round(
                    sum(r.server_energy_j for r in records) / 1e3, 4),
                "paper_acc_pct": PAPER_ACC.get(model, {}).get(setting),
                **extra,
            })
            os.makedirs("results", exist_ok=True)
            json.dump(rows, open(CACHE, "w"), indent=1)
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},{int(r.get('seconds', 0)*1e6)},"
                  f"acc={r['accuracy']};f1={r['f1']};mcc={r['mcc']};"
                  f"client_kJ={r['client_kj']};paper_acc={r['paper_acc_pct']}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--obs", action="store_true",
                    help="stream telemetry to results/runs/<run_id>/ "
                         "(render with tools/obs_report.py)")
    args = ap.parse_args()
    obs = Obs(ObsConfig()) if args.obs else None
    if args.full:
        run(models=("resnet18", "googlenet", "mobilenetv2"),
            settings=("FL", "SL_75_25", "SL_40_60", "SL_25_75", "SL_15_85"),
            rounds=args.rounds, use_cache=not args.no_cache, obs=obs)
    else:
        run(rounds=args.rounds, use_cache=not args.no_cache, obs=obs)
    if obs is not None:
        obs.close()
        print(f"obs,run_dir,0,{obs.run_dir}")


if __name__ == "__main__":
    main()
