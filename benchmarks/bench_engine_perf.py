"""Engine perf: tracked steps/sec log across engine variants and PRs.

Measures steps/sec of one SL global round (Algorithm 3) and one FL round on
the same model, data and optimizer state across the engine generations:

  sl_host_loop : the seed's host loop — one jitted split step per
                 (client, local step), per-step Python dispatch.
  sl_scanned   : ``make_multi_client_round`` — whole round one compiled
                 program (nested scan, FedAvg inside, donated state).
  sl_fleet     : ``fleet.engine.make_fleet_sl_round`` — parallel split
                 learning, client axis vmapped (shardable over `data`).
  fl_scan      : ``make_fl_round(client_axis='scan')``.
  fl_vmap      : ``make_fl_round(client_axis='vmap')`` — the ROADMAP
                 follow-up; the fl_vmap/fl_scan ratio is the measured
                 steps/s delta bought by the loosened FLEET_EQUIV_ATOL
                 equivalence bound.

Results append to ``results/engine_perf.json`` as a per-PR log — one row
per (commit, model, case, variant):

    {"commit": "...", "bench": "engine_perf", "model": "tinycnn",
     "case": "c4s4b16", "variant": "sl_fleet", "steps_per_s": 301.2}

and print as the usual ``bench,case,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.split import (SplitStep, apply_stages, init_stages,  # noqa: E402
                              make_fl_round, make_multi_client_round,
                              partition_stages)
from repro.fleet.engine import make_fleet_sl_round  # noqa: E402
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss  # noqa: E402
from repro.optim import adamw, apply_updates, init_stacked  # noqa: E402

CACHE = "results/engine_perf.json"


def _commit() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _setup(model: str, clients: int, steps: int, batch: int, image: int):
    stages = CNN_BUILDERS[model](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.25)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    bx = jax.random.uniform(jax.random.fold_in(key, 1),
                            (clients, steps, batch, image, image, 3))
    by = jax.random.randint(jax.random.fold_in(key, 2),
                            (clients, steps, batch), 0, 12)
    return stages, params, cs, cp0, ss, sp, step, bx, by


def bench_sl_host_loop(model: str, *, clients: int, steps: int, batch: int,
                       image: int, rounds: int) -> float:
    """Seed-style per-step dispatch; returns steps/sec (post-warmup)."""
    _, _, _, cp0, _, sp, step, bx, by = _setup(model, clients, steps, batch,
                                               image)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)

    @jax.jit
    def split_step(cp, cop, spar, sop, xx, yy):
        loss, _, gc, gs = step.grads(cp, spar, {"inputs": xx, "targets": yy})
        upc, cop = opt_c.update(gc, cop, cp)
        ups, sop = opt_s.update(gs, sop, spar)
        return apply_updates(cp, upc), cop, apply_updates(spar, ups), sop, loss

    cps = [jax.tree_util.tree_map(jnp.copy, cp0) for _ in range(clients)]
    cops = [opt_c.init(cp0) for _ in range(clients)]
    spar, sop = sp, opt_s.init(sp)
    # warmup / compile
    split_step(cps[0], cops[0], spar, sop, bx[0, 0], by[0, 0])

    t0 = time.time()
    loss = None
    for _ in range(rounds):
        for si in range(steps):
            for ci in range(clients):
                cps[ci], cops[ci], spar, sop, loss = split_step(
                    cps[ci], cops[ci], spar, sop, bx[ci, si], by[ci, si])
    jax.block_until_ready(loss)
    return rounds * steps * clients / (time.time() - t0)


def _bench_sl_engine(engine_builder, model: str, *, clients: int, steps: int,
                     batch: int, image: int, rounds: int) -> float:
    """Shared driver for the compiled SL rounds (scanned / fleet)."""
    _, _, _, cp0, _, sp, step, bx, by = _setup(model, clients, steps, batch,
                                               image)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    engine = jax.jit(engine_builder(step, opt_c, opt_s, local_rounds=steps),
                     donate_argnums=(0, 1, 2, 3))
    client_stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (clients,) + v.shape), cp0)
    oc_stack = init_stacked(opt_c, cp0, clients)
    state = (client_stack, sp, oc_stack, opt_s.init(sp))
    batches = {"inputs": bx, "targets": by}
    # warmup / compile
    *state, losses = engine(*state, batches)
    jax.block_until_ready(losses)

    t0 = time.time()
    for _ in range(rounds):
        *state, losses = engine(*state, batches)
    jax.block_until_ready(losses)
    return rounds * steps * clients / (time.time() - t0)


def bench_sl_scanned(model: str, **kw) -> float:
    return _bench_sl_engine(make_multi_client_round, model, **kw)


def bench_sl_fleet(model: str, **kw) -> float:
    return _bench_sl_engine(
        lambda step, oc, os_, local_rounds: make_fleet_sl_round(
            step, oc, os_, local_rounds=local_rounds), model, **kw)


def bench_fl(model: str, *, client_axis: str, clients: int, steps: int,
             batch: int, image: int, rounds: int) -> float:
    """FL baseline round, client axis scanned or vmapped."""
    stages, params, *_, bx, by = _setup(model, clients, steps, batch, image)
    opt = adamw(1e-3)

    def grad_fn(p, batch_):
        xx, yy = batch_
        return jax.value_and_grad(
            lambda q: cross_entropy_loss(apply_stages(stages, q, xx), yy))(p)

    engine = jax.jit(make_fl_round(grad_fn, opt, client_axis=client_axis),
                     donate_argnums=(0,))
    params, losses = engine(params, (bx, by))
    jax.block_until_ready(losses)

    t0 = time.time()
    for _ in range(rounds):
        params, losses = engine(params, (bx, by))
    jax.block_until_ready(losses)
    return rounds * steps * clients / (time.time() - t0)


def run(model: str = "tinycnn", clients: int = 4, steps: int = 4,
        batch: int = 16, image: int = 32, rounds: int = 10,
        print_csv: bool = True) -> list[dict]:
    kw = dict(clients=clients, steps=steps, batch=batch, image=image,
              rounds=rounds)
    variants = {
        "sl_host_loop": bench_sl_host_loop(model, **kw),
        "sl_scanned": bench_sl_scanned(model, **kw),
        "sl_fleet": bench_sl_fleet(model, **kw),
        "fl_scan": bench_fl(model, client_axis="scan", **kw),
        "fl_vmap": bench_fl(model, client_axis="vmap", **kw),
    }
    commit = _commit()
    case = f"c{clients}s{steps}b{batch}"
    rows = [{"commit": commit, "bench": "engine_perf", "model": model,
             "case": case, "variant": v, "steps_per_s": round(sps, 2)}
            for v, sps in variants.items()]
    os.makedirs("results", exist_ok=True)
    log = []
    if os.path.exists(CACHE):
        try:
            log = json.load(open(CACHE))
        except ValueError:
            log = []
    json.dump(log + rows, open(CACHE, "w"), indent=1)
    if print_csv:
        sl_speed = variants["sl_scanned"] / max(variants["sl_host_loop"], 1e-9)
        fl_delta = variants["fl_vmap"] / max(variants["fl_scan"], 1e-9)
        for r in rows:
            print(f"{r['bench']},{r['model']}/{case}/{r['variant']},0,"
                  f"{r['steps_per_s']}steps/s")
        print(f"engine_perf,{model}/{case}/summary,0,"
              f"scanned_vs_host={sl_speed:.2f}x;"
              f"fl_vmap_vs_scan={fl_delta:.2f}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tinycnn", choices=sorted(CNN_BUILDERS))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    run(model=args.model, clients=args.clients, steps=args.steps,
        batch=args.batch, image=args.image, rounds=args.rounds)


if __name__ == "__main__":
    main()
