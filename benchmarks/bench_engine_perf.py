"""Engine perf: tracked steps/sec log across engine variants and PRs.

Measures steps/sec of one SL global round (Algorithm 3) and one FL round on
the same model, data and optimizer state across the engine generations.
Every compiled variant is now built from the SAME ``ExperimentSpec`` —
only the ``EngineSpec`` field changes; the seed's host loop stays
hand-wired as the historical baseline:

  sl_host_loop : the seed's host loop — one jitted split step per
                 (client, local step), per-step Python dispatch.
  sl_scanned   : spec ``sl/scan`` — ``make_multi_client_round``; whole
                 round one compiled program (nested scan, FedAvg inside).
  sl_fleet     : spec ``sl/vmap`` — parallel split learning, client axis
                 vmapped (shardable over `data`).
  sl_shard_map : spec ``sl/shard_map`` — the explicit-collective variant
                 (in-map ``lax.pmean`` server gradient, ``fedavg_pmean``
                 FedAvg); the sl_shard_map/sl_fleet ratio prices the
                 pinned collective schedule vs GSPMD inference.
  fl_scan      : spec ``fl/scan`` — ``make_fl_round(client_axis='scan')``.
  fl_vmap      : spec ``fl/vmap`` — the fl_vmap/fl_scan ratio is the
                 measured steps/s delta bought by the loosened
                 FLEET_EQUIV_ATOL equivalence bound.
  fl_shard_map : spec ``fl/shard_map`` — explicit ``fedavg_pmean`` FedAvg.
  mc_vmap      : ``repro.sim.run_monte_carlo(mode='vmap')`` — one jitted
                 vmap-over-seeds scenario rollout (stochastic channel +
                 markov availability, 16 seeds).
  mc_loop      : the same rollout dispatched per (seed, round) from Python
                 — the idealized-campaign execution model. The
                 mc_vmap/mc_loop ratio is the vectorization win the
                 acceptance gate holds at >= 3x on XLA:CPU.
  fl_cohort    : spec ``fl/vmap`` with ``ClientSpec.population=M`` — one
                 round trains a sampled cohort of 8 from M registered
                 clients (stateless FL rounds). Logged per M (1e4/1e5/1e6
                 by default) with the engine-state byte size, which must
                 NOT grow with M (the O(cohort) claim).
  sl_cohort    : the same over ``sl/vmap`` — the EPSL shared client tier
                 (one client model broadcast across the cohort axis).

Results append to ``results/engine_perf.json`` as a per-PR log — one row
per (commit, model, case, variant):

    {"commit": "...", "bench": "engine_perf", "model": "tinycnn",
     "case": "c4s4b16", "variant": "sl_fleet", "steps_per_s": 301.2}

and print as the usual ``bench,case,us_per_call,derived`` CSV.
``benchmarks/report.py --check`` reads the log and flags >10% steps/s
regressions between the last two logged commits.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import (ClientSpec, CutPolicy, DataSpec, EngineSpec,  # noqa: E402
                       ExperimentSpec, ModelSpec, compile_experiment)
from repro.core.split import SplitStep, apply_stages  # noqa: E402
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss  # noqa: E402
from repro.obs import (NULL_OBS, Obs, ObsConfig, pytree_bytes,  # noqa: E402
                       time_fenced)
from repro.optim import adamw, apply_updates  # noqa: E402

CACHE = "results/engine_perf.json"


def _commit() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _base_spec(model: str, clients: int, steps: int, batch: int,
               image: int) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelSpec(name=model, num_classes=12),
        data=DataSpec(kind="synthetic", image_size=image,
                      classes_per_client=3),
        clients=ClientSpec(num_clients=clients),
        cut_policy=CutPolicy(mode="fraction", fraction=0.25),
        engine=EngineSpec(kind="sl", client_axis="scan"),
        local_steps=steps, batch_size=batch)


def bench_spec_variant(spec: ExperimentSpec, *, rounds: int,
                       obs: Obs = NULL_OBS) -> float:
    """steps/sec of one compiled plan variant (post-warmup). The same
    fixed batch stack drives every round via ``Plan.raw_round`` — rounds
    queue back-to-back with ONE block at the end (``obs.time_fenced``),
    like the legacy bench (``run_round``'s per-round record assembly
    would serialize dispatch)."""
    plan = compile_experiment(spec, obs=obs)
    state = plan.init()
    batches = plan.round_batches(state)
    es = state.engine_state
    # warmup / compile (*_: metrics-bus taps when --obs compiled them in)
    es, losses, *_ = plan.raw_round(es, batches)
    jax.block_until_ready(losses)

    def one_round():
        nonlocal es
        es, losses, *_ = plan.raw_round(es, batches)
        return losses

    wall = time_fenced(one_round, repeats=rounds)
    n = spec.clients.num_clients * spec.local_steps
    return rounds * n / wall


def bench_sl_host_loop(spec: ExperimentSpec, *, rounds: int,
                       obs: Obs = NULL_OBS) -> float:
    """Seed-style per-step dispatch; returns steps/sec (post-warmup)."""
    plan = compile_experiment(spec, obs=obs)
    clients, steps = spec.clients.num_clients, spec.local_steps
    k = plan.cut_of_client[0]
    stages, params = plan.stages, plan.params0
    cs, cp0 = list(stages[:k]), list(params[:k])
    ss, sp = list(stages[k:]), list(params[k:])
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    batches = plan.round_batches(plan.init())
    bx, by = batches["inputs"], batches["targets"]
    opt_c, opt_s = adamw(spec.lr), adamw(spec.lr)

    @jax.jit
    def split_step(cp, cop, spar, sop, xx, yy):
        loss, _, gc, gs = step.grads(cp, spar, {"inputs": xx, "targets": yy})
        upc, cop = opt_c.update(gc, cop, cp)
        ups, sop = opt_s.update(gs, sop, spar)
        return apply_updates(cp, upc), cop, apply_updates(spar, ups), sop, loss

    cps = [jax.tree_util.tree_map(jnp.copy, cp0) for _ in range(clients)]
    cops = [opt_c.init(cp0) for _ in range(clients)]
    spar, sop = sp, opt_s.init(sp)
    # warmup / compile
    split_step(cps[0], cops[0], spar, sop, bx[0, 0], by[0, 0])

    def one_round():
        nonlocal spar, sop
        loss = None
        for si in range(steps):
            for ci in range(clients):
                cps[ci], cops[ci], spar, sop, loss = split_step(
                    cps[ci], cops[ci], spar, sop, bx[ci, si], by[ci, si])
        return loss

    wall = time_fenced(one_round, repeats=rounds)
    return rounds * steps * clients / wall


def bench_monte_carlo(model: str, *, clients: int = 4, steps: int = 2,
                      batch: int = 8, image: int = 16, seeds: int = 16,
                      mc_rounds: int = 20,
                      obs: Obs = NULL_OBS) -> dict[str, float]:
    """steps/sec of the vectorized vs per-seed-looped Monte-Carlo scenario
    rollout (``repro.sim.run_monte_carlo``) on a stochastic campaign —
    a2g channel + markov availability over a UAV mission. Both modes run
    the identical per-round program; only the dispatch differs."""
    from repro.api import MissionSpec
    from repro.sim import (AvailabilityParams, ChannelParams, ScenarioSpec,
                           run_monte_carlo)
    spec = dataclasses.replace(
        _base_spec(model, clients, steps, batch, image),
        engine=EngineSpec(kind="sl", client_axis="vmap"),
        mission=MissionSpec(farm_acres=100.0),
        scenario=ScenarioSpec(
            channel=ChannelParams(kind="a2g"),
            availability=AvailabilityParams(kind="markov", p_drop=0.3,
                                            p_recover=0.5)))
    plan = compile_experiment(spec, obs=obs)
    total = seeds * mc_rounds * clients * steps
    out = {}
    for mode in ("vmap", "loop"):
        mc = run_monte_carlo(plan, seeds, rounds=mc_rounds, mode=mode)
        out[f"mc_{mode}"] = total / mc.wall_s
    return out


def bench_cohort(model: str, population: int, *, clients: int = 8,
                 steps: int = 2, batch: int = 8, image: int = 16,
                 rounds: int = 10, obs: Obs = NULL_OBS) -> dict[str, dict]:
    """steps/sec + engine-state bytes of one cohort round sampled from a
    ``population``-client fleet (fl/vmap stateless rounds; sl/vmap EPSL
    shared client tier). The byte size (``repro.obs.pytree_bytes`` — the
    same gauge telemetry stamps per round) is the O(cohort) acceptance
    bar: it must not move across populations."""
    out = {}
    for kind in ("fl", "sl"):
        spec = dataclasses.replace(
            _base_spec(model, clients, steps, batch, image),
            clients=ClientSpec(num_clients=clients, population=population),
            engine=EngineSpec(kind, "vmap"))
        plan = compile_experiment(spec, obs=obs)
        state = plan.init()
        es = state.engine_state
        state_bytes = pytree_bytes(es)
        # one representative cohort gather; the compiled round is the same
        # program whichever population ids the rows came from
        batches = plan.round_batches(state,
                                     cohort=plan._round_cohort(state))
        es, losses, *_ = plan.raw_round(es, batches)  # warmup / compile
        jax.block_until_ready(losses)

        def one_round():
            nonlocal es
            es, losses, *_ = plan.raw_round(es, batches)
            return losses

        wall = time_fenced(one_round, repeats=rounds)
        sps = rounds * clients * steps / wall
        out[f"{kind}_cohort"] = {"steps_per_s": sps,
                                 "state_bytes": state_bytes}
    return out


def bench_kernels(*, rounds: int = 20, obs: Obs = NULL_OBS) -> dict:
    """calls/sec of each Pallas hot-path kernel vs its XLA reference.

    Off-accelerator the Pallas side runs in interpret mode — those rows
    price the oracle, not the kernel (the CPU container's numbers are a
    trend pin, not a speedup claim; re-measure where a TPU/GPU backend
    compiles the kernels natively). Cases:

      attn_b2h4s256d64 : flash_attention vs flash_attention_ref (the
                         O(S²) XLA oracle) on a (2,4,256,64) block
      link_m2048d256   : fused one-kernel int8 quant+dequant vs the
                         two-op jnp reference boundary
      link_res_m2048d256 : the fused dequant+residual server epilogue vs
                         the unfused composition
    """
    from repro.kernels.attn.flash import flash_attention
    from repro.kernels.attn.ref import flash_attention_ref
    from repro.kernels.dispatch import accelerator_backend
    from repro.kernels.quant.ops import quant_dequant, quant_dequant_residual

    interpret = not accelerator_backend()
    out: dict[tuple[str, str], float] = {}

    def meas(case, variant, fn, *args):
        jax.block_until_ready(fn(*args))          # warmup / compile
        wall = time_fenced(lambda: fn(*args), repeats=rounds)
        out[(case, variant)] = rounds / wall

    b, h, s, d = 2, 4, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d)) for kk in ks)
    attn_case = f"attn_b{b}h{h}s{s}d{d}"
    meas(attn_case, "pallas", jax.jit(lambda q, k, v: flash_attention(
        q, k, v, block_q=128, block_k=128, interpret=interpret)), q, k, v)
    meas(attn_case, "xla",
         jax.jit(lambda q, k, v: flash_attention_ref(q, k, v)), q, k, v)

    m, dd = 2048, 256
    kx, kr = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (m, dd)) * 4.0
    r = jax.random.normal(kr, (m, dd))
    link_case = f"link_m{m}d{dd}"
    for variant, up in (("fused", True), ("xla", False)):
        meas(link_case, variant,
             lambda xx, up=up: quant_dequant(xx, use_pallas=up,
                                             interpret=interpret), x)
    res_case = f"link_res_m{m}d{dd}"
    for variant, up in (("fused", True), ("xla", False)):
        meas(res_case, variant,
             lambda xx, rr, up=up: quant_dequant_residual(
                 xx, rr, use_pallas=up, interpret=interpret), x, r)
    return out


def run(model: str = "tinycnn", clients: int = 4, steps: int = 4,
        batch: int = 16, image: int = 32, rounds: int = 10,
        print_csv: bool = True, commit: str | None = None,
        mc_seeds: int = 16,
        populations: tuple[int, ...] | None = None,
        kernels: bool = False,
        obs: Obs | ObsConfig | None = None) -> list[dict]:
    obs = Obs.ensure(obs)
    base = _base_spec(model, clients, steps, batch, image)
    commit = commit or _commit()
    case = f"c{clients}s{steps}b{batch}"
    if populations is None:
        populations = (10_000, 100_000, 1_000_000)
    variant_fns = [
        ("sl_host_loop",
         lambda: bench_sl_host_loop(base, rounds=rounds, obs=obs)),
        ("sl_scanned",
         lambda: bench_spec_variant(base, rounds=rounds, obs=obs)),
        ("sl_fleet", lambda: bench_spec_variant(
            dataclasses.replace(base, engine=EngineSpec("sl", "vmap")),
            rounds=rounds, obs=obs)),
        ("sl_shard_map", lambda: bench_spec_variant(
            dataclasses.replace(base, engine=EngineSpec("sl", "shard_map")),
            rounds=rounds, obs=obs)),
        ("fl_scan", lambda: bench_spec_variant(
            dataclasses.replace(base, engine=EngineSpec("fl", "scan")),
            rounds=rounds, obs=obs)),
        ("fl_vmap", lambda: bench_spec_variant(
            dataclasses.replace(base, engine=EngineSpec("fl", "vmap")),
            rounds=rounds, obs=obs)),
        ("fl_shard_map", lambda: bench_spec_variant(
            dataclasses.replace(base, engine=EngineSpec("fl", "shard_map")),
            rounds=rounds, obs=obs)),
    ]
    variants: dict[str, float] = {}
    rows = []
    with obs.span("bench", model=model, case=case, commit=commit):
        for name, fn in variant_fns:
            with obs.span(name) as sp:
                variants[name] = fn()
                sp.note(steps_per_s=round(variants[name], 2))
        rows += [{"commit": commit, "bench": "engine_perf", "model": model,
                  "case": case, "variant": v, "steps_per_s": round(sps, 2)}
                 for v, sps in variants.items()]
        # the MC workload is its own fixed case (c4s2b8x<seeds>) independent
        # of this invocation's engine case; pass --mc-seeds 0 to skip it
        # when benching several engine cases in one session (avoids
        # duplicate rows)
        mc: dict[str, float] = {}
        if mc_seeds > 0:
            with obs.span("monte_carlo", seeds=mc_seeds):
                mc = bench_monte_carlo(model, seeds=mc_seeds, obs=obs)
        mc_case = f"c4s2b8x{mc_seeds}"
        rows += [{"commit": commit, "bench": "engine_perf", "model": model,
                  "case": mc_case, "variant": v, "steps_per_s": round(sps, 2)}
                 for v, sps in mc.items()]
        # population cohort rounds: one fixed case per M (c8s2b8m<M>), each
        # trend-gated on steps/s like every other variant; state_bytes rides
        # along so the log pins the O(cohort) claim per commit. Pass
        # --population 0 to skip.
        for pop in [p for p in populations if p > 0]:
            with obs.span(f"cohort_m{pop}", population=pop):
                cres = bench_cohort(model, pop, rounds=rounds, obs=obs)
            rows += [{"commit": commit, "bench": "engine_perf",
                      "model": model, "case": f"c8s2b8m{pop}", "variant": v,
                      "steps_per_s": round(r["steps_per_s"], 2),
                      "state_bytes": r["state_bytes"]}
                     for v, r in cres.items()]
        # per-kernel rows (--kernels): fixed model "kernels", one case per
        # hot-path kernel, pallas/fused vs xla variants — trend-gated like
        # every other key
        if kernels:
            with obs.span("kernels"):
                kres = bench_kernels(rounds=max(rounds, 10), obs=obs)
            rows += [{"commit": commit, "bench": "engine_perf",
                      "model": "kernels", "case": case, "variant": v,
                      "steps_per_s": round(sps, 2)}
                     for (case, v), sps in kres.items()]
    # health probe: the timed benches go through raw_round (no record
    # assembly), so with --obs metrics enabled run a couple of recorded
    # rounds too — they stream `metrics` events into the run dir, which
    # the CI smoke gates on zero nonfinite slot-steps
    # (tools/obs_report.py --health-gate)
    if obs and obs.config.metrics is not None:
        with obs.span("health_probe", rounds=2):
            probe = compile_experiment(dataclasses.replace(
                base, engine=EngineSpec("sl", "vmap"), global_rounds=2),
                obs=obs)
            probe.run(with_eval=False)
    if obs:
        obs.manifest(bench={"bench": "engine_perf", "model": model,
                            "case": case, "commit": commit,
                            "rows": len(rows)})
        obs.flush()
    os.makedirs("results", exist_ok=True)
    log = []
    if os.path.exists(CACHE):
        try:
            log = json.load(open(CACHE))
        except ValueError:
            log = []
    json.dump(log + rows, open(CACHE, "w"), indent=1)
    if print_csv:
        sl_speed = variants["sl_scanned"] / max(variants["sl_host_loop"], 1e-9)
        fl_delta = variants["fl_vmap"] / max(variants["fl_scan"], 1e-9)
        sm_delta = variants["sl_shard_map"] / max(variants["sl_fleet"], 1e-9)
        for r in rows:
            print(f"{r['bench']},{r['model']}/{r['case']}/{r['variant']},0,"
                  f"{r['steps_per_s']}steps/s")
        summary = (f"scanned_vs_host={sl_speed:.2f}x;"
                   f"fl_vmap_vs_scan={fl_delta:.2f}x;"
                   f"sl_shard_map_vs_vmap={sm_delta:.2f}x")
        if mc:
            summary += (f";mc_vmap_vs_loop="
                        f"{mc['mc_vmap'] / max(mc['mc_loop'], 1e-9):.2f}x")
        print(f"engine_perf,{model}/{case}/summary,0,{summary}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tinycnn", choices=sorted(CNN_BUILDERS))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--mc-seeds", type=int, default=16,
                    help="Monte-Carlo sweep width for the mc_vmap/mc_loop "
                         "rows (acceptance gate: >=3x at 16 seeds)")
    ap.add_argument("--population", type=int, action="append", default=None,
                    dest="populations", metavar="M",
                    help="log fl_cohort/sl_cohort rows (steps/s + engine-"
                         "state bytes, cohort of 8 sampled from M); "
                         "repeatable; default 1e4/1e5/1e6; 0 skips")
    ap.add_argument("--kernels", action="store_true",
                    help="log per-kernel rows (flash attention + fused int8 "
                         "link vs their XLA references; interpret-mode "
                         "Pallas off-accelerator)")
    ap.add_argument("--commit", default=None,
                    help="override the logged commit label (used to append "
                         "same-machine re-measured baseline rows next to a "
                         "new commit's rows, so the trend gate compares "
                         "like with like)")
    ap.add_argument("--obs", action="store_true",
                    help="stream telemetry (phase spans, recompile/memory "
                         "gauges, manifest, the default metrics-bus tap "
                         "set + health probe) for this bench session to "
                         "results/runs/<run_id>/; render with "
                         "tools/obs_report.py")
    ap.add_argument("--obs-root", default="results/runs",
                    help="run-dir root for --obs (default results/runs)")
    args = ap.parse_args()
    from repro.obs.metrics import MetricsConfig
    obs = (Obs(ObsConfig(run_root=args.obs_root, metrics=MetricsConfig()))
           if args.obs else None)
    run(model=args.model, clients=args.clients, steps=args.steps,
        batch=args.batch, image=args.image, rounds=args.rounds,
        commit=args.commit, mc_seeds=args.mc_seeds,
        populations=(tuple(args.populations)
                     if args.populations is not None else None),
        kernels=args.kernels, obs=obs)
    if obs is not None:
        obs.close()
        print(f"obs,run_dir,0,{obs.run_dir}")


if __name__ == "__main__":
    main()
