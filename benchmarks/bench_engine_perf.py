"""Engine perf: scanned device-resident rounds vs the host-loop reference.

Measures steps/sec of one SL global round (Algorithm 3) executed two ways
on the same model, data and optimizer state:

  before : the seed's host loop — one jitted split step per
           (client, local step) with per-step Python dispatch and per-step
           energy bookkeeping on the host.
  after  : ``make_multi_client_round`` — the whole round is one compiled
           program (nested lax.scan over steps x clients, FedAvg inside)
           with donated state buffers and batches pre-gathered per round.

Both paths are warmed up (compile excluded) and timed over the same number
of rounds. Results append to results/engine_perf.json and print as the
usual ``bench,case,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.split import (SplitStep, apply_stages, init_stages,
                              make_multi_client_round, partition_stages)
from repro.models.cnn import CNN_BUILDERS, cross_entropy_loss
from repro.optim import adamw, apply_updates, init_stacked

CACHE = "results/engine_perf.json"


def _setup(model: str, clients: int, steps: int, batch: int, image: int):
    stages = CNN_BUILDERS[model](12)
    key = jax.random.PRNGKey(0)
    params = init_stages(key, stages)
    cs, cp0, ss, sp, _ = partition_stages(stages, params, 0.25)
    step = SplitStep(
        client_fwd=lambda pc, xx: apply_stages(cs, pc, xx),
        server_loss=lambda ps, sm, yy: (
            cross_entropy_loss(apply_stages(ss, ps, sm), yy), {}),
    )
    bx = jax.random.uniform(jax.random.fold_in(key, 1),
                            (clients, steps, batch, image, image, 3))
    by = jax.random.randint(jax.random.fold_in(key, 2),
                            (clients, steps, batch), 0, 12)
    return cs, cp0, ss, sp, step, bx, by


def bench_host_loop(model: str, *, clients: int, steps: int, batch: int,
                    image: int, rounds: int) -> float:
    """Seed-style per-step dispatch; returns steps/sec (post-warmup)."""
    _, cp0, _, sp, step, bx, by = _setup(model, clients, steps, batch, image)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)

    @jax.jit
    def split_step(cp, cop, spar, sop, xx, yy):
        loss, _, gc, gs = step.grads(cp, spar, {"inputs": xx, "targets": yy})
        upc, cop = opt_c.update(gc, cop, cp)
        ups, sop = opt_s.update(gs, sop, spar)
        return apply_updates(cp, upc), cop, apply_updates(spar, ups), sop, loss

    cps = [jax.tree_util.tree_map(jnp.copy, cp0) for _ in range(clients)]
    cops = [opt_c.init(cp0) for _ in range(clients)]
    spar, sop = sp, opt_s.init(sp)
    # warmup / compile
    split_step(cps[0], cops[0], spar, sop, bx[0, 0], by[0, 0])

    t0 = time.time()
    loss = None
    for _ in range(rounds):
        for si in range(steps):
            for ci in range(clients):
                cps[ci], cops[ci], spar, sop, loss = split_step(
                    cps[ci], cops[ci], spar, sop, bx[ci, si], by[ci, si])
    jax.block_until_ready(loss)
    return rounds * steps * clients / (time.time() - t0)


def bench_scanned(model: str, *, clients: int, steps: int, batch: int,
                  image: int, rounds: int) -> float:
    """Device-resident scanned rounds; returns steps/sec (post-warmup)."""
    _, cp0, _, sp, step, bx, by = _setup(model, clients, steps, batch, image)
    opt_c, opt_s = adamw(1e-3), adamw(1e-3)
    engine = jax.jit(make_multi_client_round(step, opt_c, opt_s,
                                             local_rounds=steps),
                     donate_argnums=(0, 1, 2, 3))
    client_stack = jax.tree_util.tree_map(
        lambda v: jnp.broadcast_to(v[None], (clients,) + v.shape), cp0)
    oc_stack = init_stacked(opt_c, cp0, clients)
    state = (client_stack, sp, oc_stack, opt_s.init(sp))
    batches = {"inputs": bx, "targets": by}
    # warmup / compile
    *state, losses = engine(*state, batches)
    jax.block_until_ready(losses)

    t0 = time.time()
    for _ in range(rounds):
        *state, losses = engine(*state, batches)
    jax.block_until_ready(losses)
    return rounds * steps * clients / (time.time() - t0)


def run(model: str = "tinycnn", clients: int = 4, steps: int = 4,
        batch: int = 16, image: int = 32, rounds: int = 10,
        print_csv: bool = True) -> list[dict]:
    kw = dict(clients=clients, steps=steps, batch=batch, image=image,
              rounds=rounds)
    before = bench_host_loop(model, **kw)
    after = bench_scanned(model, **kw)
    rows = [{
        "bench": "engine_perf",
        "case": f"{model}/c{clients}s{steps}b{batch}",
        "steps_per_s_host_loop": round(before, 2),
        "steps_per_s_scanned": round(after, 2),
        "speedup": round(after / before, 2),
    }]
    os.makedirs("results", exist_ok=True)
    log = []
    if os.path.exists(CACHE):
        try:
            log = json.load(open(CACHE))
        except ValueError:
            log = []
    json.dump(log + rows, open(CACHE, "w"), indent=1)
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},0,"
                  f"host_loop={r['steps_per_s_host_loop']}steps/s;"
                  f"scanned={r['steps_per_s_scanned']}steps/s;"
                  f"speedup={r['speedup']}x")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tinycnn", choices=sorted(CNN_BUILDERS))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--image", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()
    run(model=args.model, clients=args.clients, steps=args.steps,
        batch=args.batch, image=args.image, rounds=args.rounds)


if __name__ == "__main__":
    main()
