"""Paper Eq. (6) / Algorithm 2: communication rounds gamma vs energy budget,
and the delayed-return strategy's advantage over return-every-round.

The mission is declared as an ``repro.api.MissionSpec`` (the same object an
``ExperimentSpec`` embeds to budget a training campaign); the sweep edits
only its UAV battery field. Also reports the per-step link deadline the
hover window implies (``mission_max_link_s``) — the bound a campaign's
adaptive cut selection runs under.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import MissionSpec, mission_max_link_s
from repro.core.deployment import deploy_edge_devices, uniform_grid_sensors
from repro.core.trajectory import plan_tour
from repro.core.uav_energy import UAVParams

LOCAL_STEPS = 2   # steps per stop when deriving the link deadline


def run(print_csv: bool = True) -> list[dict]:
    rows = []
    pts = uniform_grid_sensors(100, 25)
    dep = deploy_edge_devices(pts, 200.0)
    base = np.zeros(2)
    mission = MissionSpec(farm_acres=100.0)
    for frac in (0.25, 0.5, 1.0, 2.0):
        m = dataclasses.replace(mission, uav=UAVParams(beta=1.9e6 * frac))
        plan = plan_tour(dep.edge_coords, base, params=m.uav,
                         hover_s_per_stop=m.hover_s_per_stop,
                         comm_s_per_stop=m.comm_s_per_stop)
        # return-to-base-every-round baseline
        per_round_with_return = plan.e_first + plan.e_return
        naive = int(m.uav.beta // per_round_with_return) \
            if per_round_with_return > 0 else 0
        rows.append({
            "bench": "rounds(eq6)",
            "case": f"beta={frac:.2f}x",
            "gamma_delayed_return": plan.rounds,
            "gamma_naive_return": naive,
            "kj_per_round": round(plan.e_per_round / 1e3, 2),
            "gain_rounds": plan.rounds - naive,
            "max_link_s": round(mission_max_link_s(
                m.hover_s_per_stop, m.comm_s_per_stop, LOCAL_STEPS), 2),
        })
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},0,"
                  f"gamma={r['gamma_delayed_return']};"
                  f"naive={r['gamma_naive_return']};"
                  f"kJ/round={r['kj_per_round']};"
                  f"max_link_s={r['max_link_s']}")
    return rows


if __name__ == "__main__":
    run()
