"""Benchmark runner — one entry per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--full]

Prints ``name,case,us_per_call,derived`` CSV lines per bench.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.runtime_flags import enable_fast_cpu_runtime

enable_fast_cpu_runtime()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the CNN training bench (fig3)")
    ap.add_argument("--full", action="store_true",
                    help="full fig3 sweep (3 backbones x 5 settings)")
    args = ap.parse_args()

    t0 = time.time()
    print("# bench_deployment (paper Fig. 2)")
    from . import bench_deployment
    bench_deployment.run()

    print("# bench_uav_energy (paper Table II)")
    from . import bench_uav_energy
    bench_uav_energy.run()

    print("# bench_rounds (paper Eq. 6 / Alg. 2)")
    from . import bench_rounds
    bench_rounds.run()

    print("# bench_resource (paper Table III)")
    from . import bench_resource
    bench_resource.run()

    print("# bench_engine_perf (host-loop vs scanned vs fleet engines; "
          "appends results/engine_perf.json)")
    from . import bench_engine_perf
    bench_engine_perf.run()

    if not args.fast:
        print("# bench_sl_accuracy (paper Fig. 3) — trains CNNs, takes minutes")
        from . import bench_sl_accuracy
        if args.full:
            bench_sl_accuracy.run(
                models=("resnet18", "googlenet", "mobilenetv2"),
                settings=("FL", "SL_75_25", "SL_40_60", "SL_25_75",
                          "SL_15_85"))
        else:
            bench_sl_accuracy.run()

    print("# roofline (dry-run derived; deliverable g)")
    from . import roofline
    roofline.run()

    print(f"# all benches done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
