"""Generate EXPERIMENTS.md from recorded artifacts.

    PYTHONPATH=src python -m benchmarks.report

Sections: paper reproduction tables (Fig.2 / Table II / Eq.6 / Table III /
Fig.3), §Dry-run, §Roofline — all derived from results/; §Perf is included
verbatim from results/PERF_LOG.md (the hillclimb log).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _fmt_bytes(b):
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def paper_sections() -> str:
    from . import bench_deployment, bench_uav_energy, bench_rounds, \
        bench_resource
    out = ["## §Paper-Fig2 — deployment strategies\n",
           "| case | edge devices | tour (m) | kJ/round | rounds γ | covered |",
           "|---|---|---|---|---|---|"]
    for r in bench_deployment.run(print_csv=False):
        out.append(f"| {r['case']} | {r['edge_devices']} | {r['tour_m']} "
                   f"| {r['kj_per_round']} | {r['rounds']} | {r['covered']} |")

    out += ["", "## §Paper-TableII — UAV energy per trip\n",
            "| case | ours kJ/trip | paper kJ/trip | saving vs baseline |",
            "|---|---|---|---|"]
    for r in bench_uav_energy.run(print_csv=False):
        out.append(f"| {r['case']} | {r['kj_per_trip']} | {r['paper_kj']} "
                   f"| {r['saving_vs_ours_pct']}% |")
    out.append("\nThe paper's qualitative claim — eEnergy-Split needs the "
               "fewest devices and the least per-trip energy among "
               "*coverage-satisfying* deployments — reproduces (K-means "
               "next; GASBAC sometimes cheaper but violates the Eq. 4 "
               "coverage constraint, `covered=False` above: its balanced "
               "clusters leave sensors out of CR). Absolute kJ differs "
               "from the paper's Table II because tour geometry and "
               "hover/comm dwell times are not published; the ~27%/~35% "
               "relative savings at 100 acres match the claim's "
               "direction, not its magnitude.")

    out += ["", "## §Paper-Rounds — Eq. (6) γ vs battery budget\n",
            "| budget | γ (delayed return, Alg. 2) | γ (return each round) |",
            "|---|---|---|"]
    for r in bench_rounds.run(print_csv=False):
        out.append(f"| {r['case']} | {r['gamma_delayed_return']} "
                   f"| {r['gamma_naive_return']} |")

    out += ["", "## §Paper-TableIII — per-tier time / energy / CO2\n",
            "| case | client s | server s | link s | client kJ | server kJ "
            "| client CO2 g |",
            "|---|---|---|---|---|---|---|"]
    for r in bench_resource.run(print_csv=False):
        out.append(f"| {r['case']} | {r['client_s']} | {r['server_s']} "
                   f"| {r['link_s']} | {r['client_kj']} | {r['server_kj']} "
                   f"| {r['client_co2_g']} |")
    out.append("\nReproduces §IV-D's finding: SL cuts client TIME for every "
               "backbone; the ENERGY advantage is model-dependent (the link "
               "+ shallow-layer overhead can erode it for deep nets, while "
               "MobileNetV2 wins on both).")

    if os.path.exists("results/sl_accuracy.json"):
        rows = json.load(open("results/sl_accuracy.json"))
        out += ["", "## §Paper-Fig3 — FL vs SL classification (synthetic KAP)\n",
                "| case | acc | f1 | mcc | client kJ | paper acc (%) |",
                "|---|---|---|---|---|---|"]
        for r in rows:
            out.append(f"| {r['case']} | {r['accuracy']} | {r['f1']} "
                       f"| {r['mcc']} | {r['client_kj']} "
                       f"| {r.get('paper_acc_pct', '—')} |")
        out.append("\nSynthetic non-IID stand-in (offline container — no "
                   "KAP download): absolute accuracies are not comparable "
                   "to the paper's; the comparison of interest is SL vs FL "
                   "under the same budget.")
    return "\n".join(out)


def training_section() -> str:
    path = "results/train_llm_log.txt"
    if not os.path.exists(path):
        return ""
    lines = open(path).read().strip().splitlines()
    out = ["## §End-to-end training — smollm-135m (full 135M config, "
           "split cut SL_15,85)\n",
           "`PYTHONPATH=src python examples/train_llm_split.py --steps 250 "
           "--batch 4 --seq 128` — AdamW + grad-clip, synthetic copy-"
           "structure tokens, cut at layer 5/30 (client tier):\n",
           "```"]
    out += [l for l in lines if "step " in l][:6]
    out += ["  ..."] + [lines[-2], lines[-1], "```",
            "Loss 11.11 -> ~1.9 (the copy-task entropy floor) in 250 steps; "
            "checkpoint saved via repro.checkpoint."]
    return "\n".join(out)


def dryrun_section() -> str:
    recs = [json.load(open(p)) for p in
            sorted(glob.glob("results/dryrun/*.json"))]
    base = [r for r in recs if r.get("tag", "baseline") == "baseline"]
    n_ok = sum(r["status"] == "ok" for r in base)
    n_skip = sum(r["status"] == "skipped" for r in base)
    n_err = sum(r["status"] == "error" for r in base)
    out = [f"## §Dry-run — {n_ok} ok / {n_skip} skipped (documented) / "
           f"{n_err} errors\n",
           "Every (architecture x input shape) lowered **and compiled** on "
           "the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh "
           "(512 host-platform devices). Per-device peak memory from "
           "`memory_analysis()`:\n",
           "| arch | shape | mesh | peak/dev | args/dev | compile s | "
           "collectives (count) |",
           "|---|---|---|---|---|---|---|"]
    for r in base:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped: {r['reason'][:60]}… | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR {r['error'][:60]} | | | |")
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        ncoll = sum(v["count"] for k, v in coll.items()
                    if isinstance(v, dict))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_bytes(mem.get('peak_memory_in_bytes'))} "
            f"| {_fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {r['compile_s']} | {ncoll} |")
    return "\n".join(out)


def roofline_section() -> str:
    from . import roofline
    rows = roofline.load_all()
    rows_sp = [r for r in rows if r.get("mesh") == "pod16x16"
               and r.get("tag", "baseline") == "baseline"]
    out = ["## §Roofline — single-pod (16x16, 256 chips), per-device terms\n",
           "Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per "
           "chip. Counts are scan-body-corrected (see "
           "`launch/steps.py:build_body_probes`). `useful` = "
           "MODEL_FLOPS / (HLO_FLOPs x chips), MODEL_FLOPS = 6·N_active·D "
           "(train) or 2·N_active·D (inference).\n",
           "| arch | shape | t_compute | t_memory | t_collective | dominant "
           "| useful | lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows_sp:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"| — | {r['skipped'][:50]}… |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e}s "
            f"| {r['t_memory_s']:.2e}s | {r['t_collective_s']:.2e}s "
            f"| **{r['dominant']}** | {r['useful_compute_ratio']:.2f} "
            f"| {r['lever'][:58]} |")

    # multi-pod deltas
    rows_mp = [r for r in rows if r.get("mesh") == "pod2x16x16"
               and r.get("tag", "baseline") == "baseline" and "skipped" not in r]
    out += ["", "### Multi-pod (2x16x16) — collective-term deltas\n",
            "| arch | shape | t_coll single-pod | t_coll multi-pod |",
            "|---|---|---|---|"]
    sp_map = {(r["arch"], r["shape"]): r for r in rows_sp if "skipped" not in r}
    for r in rows_mp:
        s = sp_map.get((r["arch"], r["shape"]))
        if s:
            out.append(f"| {r['arch']} | {r['shape']} "
                       f"| {s['t_collective_s']:.2e}s "
                       f"| {r['t_collective_s']:.2e}s |")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

Artifacts: `results/dryrun/*.json` (per-pair dry-run records),
`results/sl_accuracy.json` (Fig. 3 runs), `results/PERF_LOG.md`
(hillclimb iterations). Regenerate this file with
`PYTHONPATH=src python -m benchmarks.report`.
"""


def main():
    parts = [HEADER, paper_sections(), "", training_section(), "",
             dryrun_section(), "", roofline_section(), ""]
    if os.path.exists("results/PERF_LOG.md"):
        parts.append(open("results/PERF_LOG.md").read())
    else:
        parts.append("## §Perf\n\n(pending — see results/PERF_LOG.md)")
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
