"""Generate EXPERIMENTS.md from recorded artifacts — and gate perf trends.

    PYTHONPATH=src python -m benchmarks.report            # write EXPERIMENTS.md
    PYTHONPATH=src python benchmarks/report.py --check    # perf trend gate

Sections: paper reproduction tables (Fig.2 / Table II / Eq.6 / Table III /
Fig.3), §Dry-run, §Roofline — all derived from results/; §Perf is included
verbatim from results/PERF_LOG.md (the hillclimb log).

``--check`` reads ``results/engine_perf.json`` (the per-commit steps/sec
log appended by ``benchmarks/bench_engine_perf.py``), compares the last
two logged commits on every (model, case, variant) they share, and exits
nonzero when any variant regressed by more than ``--threshold`` (default
10%) — the CI perf gate. ``--relative`` divides each variant's steps/s by
the same commit's ``sl_host_loop`` baseline before comparing: the host
loop is the never-optimized reference, so the ratio cancels machine speed
and isolates engine regressions — use it when the two commits' rows come
from different machines (the committed log vs a CI runner). Variants the
previous commit logged that the latest did not are WARNED about, not
compared (a shrunk bench invocation is not a regression).

``--compact N`` prunes the same log in place to each (model, case,
variant) key's last N commits — CI compacts before uploading the artifact
so the log stops growing without bound.

``--runs [ROOT]`` lists ``repro.obs`` telemetry run dirs (default
``results/runs``) cross-linked to the gate: runs whose manifest commit
matches either side of the last-two-commits comparison are tagged
``[gate:prev]`` / ``[gate:cur]`` — render one with
``tools/obs_report.py <run_dir>``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PERF_LOG = "results/engine_perf.json"


def _fmt_bytes(b):
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def paper_sections() -> str:
    from . import bench_deployment, bench_uav_energy, bench_rounds, \
        bench_resource
    out = ["## §Paper-Fig2 — deployment strategies\n",
           "| case | edge devices | tour (m) | kJ/round | rounds γ | covered |",
           "|---|---|---|---|---|---|"]
    for r in bench_deployment.run(print_csv=False):
        out.append(f"| {r['case']} | {r['edge_devices']} | {r['tour_m']} "
                   f"| {r['kj_per_round']} | {r['rounds']} | {r['covered']} |")

    out += ["", "## §Paper-TableII — UAV energy per trip\n",
            "| case | ours kJ/trip | paper kJ/trip | saving vs baseline |",
            "|---|---|---|---|"]
    for r in bench_uav_energy.run(print_csv=False):
        out.append(f"| {r['case']} | {r['kj_per_trip']} | {r['paper_kj']} "
                   f"| {r['saving_vs_ours_pct']}% |")
    out.append("\nThe paper's qualitative claim — eEnergy-Split needs the "
               "fewest devices and the least per-trip energy among "
               "*coverage-satisfying* deployments — reproduces (K-means "
               "next; GASBAC sometimes cheaper but violates the Eq. 4 "
               "coverage constraint, `covered=False` above: its balanced "
               "clusters leave sensors out of CR). Absolute kJ differs "
               "from the paper's Table II because tour geometry and "
               "hover/comm dwell times are not published; the ~27%/~35% "
               "relative savings at 100 acres match the claim's "
               "direction, not its magnitude.")

    out += ["", "## §Paper-Rounds — Eq. (6) γ vs battery budget\n",
            "| budget | γ (delayed return, Alg. 2) | γ (return each round) |",
            "|---|---|---|"]
    for r in bench_rounds.run(print_csv=False):
        out.append(f"| {r['case']} | {r['gamma_delayed_return']} "
                   f"| {r['gamma_naive_return']} |")

    out += ["", "## §Paper-TableIII — per-tier time / energy / CO2\n",
            "| case | client s | server s | link s | client kJ | server kJ "
            "| client CO2 g |",
            "|---|---|---|---|---|---|---|"]
    for r in bench_resource.run(print_csv=False):
        out.append(f"| {r['case']} | {r['client_s']} | {r['server_s']} "
                   f"| {r['link_s']} | {r['client_kj']} | {r['server_kj']} "
                   f"| {r['client_co2_g']} |")
    out.append("\nReproduces §IV-D's finding: SL cuts client TIME for every "
               "backbone; the ENERGY advantage is model-dependent (the link "
               "+ shallow-layer overhead can erode it for deep nets, while "
               "MobileNetV2 wins on both).")

    if os.path.exists("results/sl_accuracy.json"):
        rows = json.load(open("results/sl_accuracy.json"))
        out += ["", "## §Paper-Fig3 — FL vs SL classification (synthetic KAP)\n",
                "| case | acc | f1 | mcc | client kJ | paper acc (%) |",
                "|---|---|---|---|---|---|"]
        for r in rows:
            out.append(f"| {r['case']} | {r['accuracy']} | {r['f1']} "
                       f"| {r['mcc']} | {r['client_kj']} "
                       f"| {r.get('paper_acc_pct', '—')} |")
        out.append("\nSynthetic non-IID stand-in (offline container — no "
                   "KAP download): absolute accuracies are not comparable "
                   "to the paper's; the comparison of interest is SL vs FL "
                   "under the same budget.")
    return "\n".join(out)


def training_section() -> str:
    path = "results/train_llm_log.txt"
    if not os.path.exists(path):
        return ""
    lines = open(path).read().strip().splitlines()
    out = ["## §End-to-end training — smollm-135m (full 135M config, "
           "split cut SL_15,85)\n",
           "`PYTHONPATH=src python examples/train_llm_split.py --steps 250 "
           "--batch 4 --seq 128` — AdamW + grad-clip, synthetic copy-"
           "structure tokens, cut at layer 5/30 (client tier):\n",
           "```"]
    out += [l for l in lines if "step " in l][:6]
    out += ["  ..."] + [lines[-2], lines[-1], "```",
            "Loss 11.11 -> ~1.9 (the copy-task entropy floor) in 250 steps; "
            "checkpoint saved via repro.checkpoint."]
    return "\n".join(out)


def dryrun_section() -> str:
    recs = [json.load(open(p)) for p in
            sorted(glob.glob("results/dryrun/*.json"))]
    base = [r for r in recs if r.get("tag", "baseline") == "baseline"]
    n_ok = sum(r["status"] == "ok" for r in base)
    n_skip = sum(r["status"] == "skipped" for r in base)
    n_err = sum(r["status"] == "error" for r in base)
    out = [f"## §Dry-run — {n_ok} ok / {n_skip} skipped (documented) / "
           f"{n_err} errors\n",
           "Every (architecture x input shape) lowered **and compiled** on "
           "the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh "
           "(512 host-platform devices). Per-device peak memory from "
           "`memory_analysis()`:\n",
           "| arch | shape | mesh | peak/dev | args/dev | compile s | "
           "collectives (count) |",
           "|---|---|---|---|---|---|---|"]
    for r in base:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped: {r['reason'][:60]}… | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR {r['error'][:60]} | | | |")
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        ncoll = sum(v["count"] for k, v in coll.items()
                    if isinstance(v, dict))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_bytes(mem.get('peak_memory_in_bytes'))} "
            f"| {_fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {r['compile_s']} | {ncoll} |")
    return "\n".join(out)


def roofline_section() -> str:
    from . import roofline
    rows = roofline.load_all()
    rows_sp = [r for r in rows if r.get("mesh") == "pod16x16"
               and r.get("tag", "baseline") == "baseline"]
    out = ["## §Roofline — single-pod (16x16, 256 chips), per-device terms\n",
           "Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per "
           "chip. Counts are scan-body-corrected (see "
           "`launch/steps.py:build_body_probes`). `useful` = "
           "MODEL_FLOPS / (HLO_FLOPs x chips), MODEL_FLOPS = 6·N_active·D "
           "(train) or 2·N_active·D (inference).\n",
           "| arch | shape | t_compute | t_memory | t_collective | dominant "
           "| useful | lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows_sp:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                       f"| — | {r['skipped'][:50]}… |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e}s "
            f"| {r['t_memory_s']:.2e}s | {r['t_collective_s']:.2e}s "
            f"| **{r['dominant']}** | {r['useful_compute_ratio']:.2f} "
            f"| {r['lever'][:58]} |")

    # multi-pod deltas
    rows_mp = [r for r in rows if r.get("mesh") == "pod2x16x16"
               and r.get("tag", "baseline") == "baseline" and "skipped" not in r]
    out += ["", "### Multi-pod (2x16x16) — collective-term deltas\n",
            "| arch | shape | t_coll single-pod | t_coll multi-pod |",
            "|---|---|---|---|"]
    sp_map = {(r["arch"], r["shape"]): r for r in rows_sp if "skipped" not in r}
    for r in rows_mp:
        s = sp_map.get((r["arch"], r["shape"]))
        if s:
            out.append(f"| {r['arch']} | {r['shape']} "
                       f"| {s['t_collective_s']:.2e}s "
                       f"| {r['t_collective_s']:.2e}s |")
    return "\n".join(out)


BASELINE_VARIANT = "sl_host_loop"


def _last_two_keyed(rows: list[dict]):
    """``(prev_commit, cur_commit, prev_keyed, cur_keyed)`` of the engine-
    perf log, or ``None`` with fewer than two logged commits. Keys are
    (model, case, variant); the latest row wins when a commit logged a key
    twice."""
    rows = [r for r in rows if r.get("bench") == "engine_perf"
            and "steps_per_s" in r]
    commits: list[str] = []
    for r in rows:
        if r["commit"] not in commits:
            commits.append(r["commit"])
    if len(commits) < 2:
        return None
    prev_c, cur_c = commits[-2], commits[-1]

    def keyed(commit):
        out = {}
        for r in rows:
            if r["commit"] == commit:
                out[(r["model"], r["case"], r["variant"])] = r["steps_per_s"]
        return out

    return prev_c, cur_c, keyed(prev_c), keyed(cur_c)


def missing_variants(rows: list[dict]) -> list[str]:
    """Keys the previous commit logged that the latest commit did NOT —
    usually a shrunk bench invocation (``--mc-seeds 0``, fewer
    ``--population`` cases), not a perf regression. The gate WARNS about
    these instead of failing (and instead of crashing on the lookup)."""
    lt = _last_two_keyed(rows)
    if lt is None:
        return []
    _, _, prev, cur = lt
    return ["/".join(k) for k in sorted(set(prev) - set(cur))]


def perf_trend(rows: list[dict], *, threshold: float = 0.10,
               relative: bool = False) -> tuple[list[dict], list[str]]:
    """Compare the last two logged commits of the engine-perf log.

    ``rows`` is the append-only ``engine_perf.json`` list; commit order is
    first-appearance order (the log is chronological). Returns
    ``(comparisons, regressions)``: one comparison dict per
    (model, case, variant) key both commits share (the latest row wins when
    a commit logged a key twice), and a flat list of human-readable
    regression strings for every key whose metric dropped more than
    ``threshold``.

    ``relative`` normalizes each variant by the same commit's
    ``sl_host_loop`` row for that (model, case) — the seed-style reference
    loop nobody optimizes — so comparisons across machines measure engine
    speedup, not machine speed. Keys without a baseline on both sides
    (including the baseline itself) fall back to absolute steps/s.
    """
    lt = _last_two_keyed(rows)
    if lt is None:
        return [], []
    prev_c, cur_c, prev, cur = lt
    comparisons, regressions = [], []
    for key in sorted(set(prev) & set(cur)):
        p, c = prev[key], cur[key]
        unit = "steps/s"
        if relative and key[2] != BASELINE_VARIANT:
            base_key = (key[0], key[1], BASELINE_VARIANT)
            pb, cb = prev.get(base_key, 0), cur.get(base_key, 0)
            if pb > 0 and cb > 0:
                p, c = round(p / pb, 3), round(c / cb, 3)
                unit = "x host_loop"
        delta = (c - p) / p if p > 0 else 0.0
        comp = {"model": key[0], "case": key[1], "variant": key[2],
                "prev_commit": prev_c, "cur_commit": cur_c,
                "prev_steps_per_s": p, "cur_steps_per_s": c, "unit": unit,
                "delta_pct": round(100.0 * delta, 1)}
        comparisons.append(comp)
        if relative and key[2] == BASELINE_VARIANT:
            continue   # the baseline row only measures machine speed here
        if delta < -threshold:
            regressions.append(
                f"{key[0]}/{key[1]}/{key[2]}: {p} -> {c} {unit} "
                f"({comp['delta_pct']}% vs {prev_c})")
    return comparisons, regressions


def check_perf(path: str = PERF_LOG, *, threshold: float = 0.10,
               relative: bool = False) -> int:
    """CLI trend gate: 0 = ok (or nothing comparable), 1 = regression."""
    if not os.path.exists(path):
        print(f"perf-check: no {path}; nothing to compare")
        return 0
    try:
        rows = json.load(open(path))
    except ValueError:
        print(f"perf-check: {path} is not valid JSON")
        return 1
    comparisons, regressions = perf_trend(rows, threshold=threshold,
                                          relative=relative)
    if not comparisons:
        print("perf-check: <2 commits share a (model, case, variant) key; "
              "nothing to compare")
        return 0
    cur = comparisons[0]["cur_commit"]
    prev = comparisons[0]["prev_commit"]
    print(f"perf-check: {cur} vs {prev} "
          f"({len(comparisons)} comparable variants, "
          f"threshold -{threshold:.0%}"
          f"{', relative to ' + BASELINE_VARIANT if relative else ''})")
    for c in comparisons:
        print(f"  {c['model']}/{c['case']}/{c['variant']}: "
              f"{c['prev_steps_per_s']} -> {c['cur_steps_per_s']} "
              f"{c['unit']} ({c['delta_pct']:+}%)")
    for m in missing_variants(rows):
        print(f"  warning: {m} logged for {prev} but missing from {cur} "
              f"(shrunk bench invocation?) — not compared")
    if regressions:
        print(f"perf-check: {len(regressions)} REGRESSION(S) "
              f"worse than {threshold:.0%}:")
        for r in regressions:
            print(f"  !! {r}")
        return 1
    print("perf-check: ok")
    return 0


def compact_perf_log(rows: list[dict], keep: int) -> list[dict]:
    """Prune the append-only engine-perf log to each (model, case, variant)
    key's last ``keep`` logged commits.

    The log grows by one row set per CI/bench invocation forever; the
    trend gate only ever reads the last two commits per key, so older rows
    are artifact weight. Rows that are not engine-perf measurements (no
    ``steps_per_s``) pass through untouched; commit order per key is
    first-appearance order, same as ``perf_trend``."""
    if keep < 1:
        raise ValueError("--compact needs keep >= 1")
    commits_of: dict[tuple, list] = {}
    for r in rows:
        if r.get("bench") != "engine_perf" or "steps_per_s" not in r:
            continue
        key = (r.get("model"), r.get("case"), r.get("variant"))
        cl = commits_of.setdefault(key, [])
        if r.get("commit") not in cl:
            cl.append(r.get("commit"))
    out = []
    for r in rows:
        if r.get("bench") != "engine_perf" or "steps_per_s" not in r:
            out.append(r)
            continue
        key = (r.get("model"), r.get("case"), r.get("variant"))
        if r.get("commit") in commits_of[key][-keep:]:
            out.append(r)
    return out


def compact_cli(keep: int, path: str = PERF_LOG) -> int:
    """CLI for ``--compact``: rewrite the log pruned in place (CI runs this
    before uploading the artifact)."""
    if not os.path.exists(path):
        print(f"compact: no {path}; nothing to prune")
        return 0
    try:
        rows = json.load(open(path))
    except ValueError:
        print(f"compact: {path} is not valid JSON")
        return 1
    pruned = compact_perf_log(rows, keep)
    json.dump(pruned, open(path, "w"), indent=1)
    print(f"compact: {path} {len(rows)} -> {len(pruned)} rows "
          f"(last {keep} commits per (model, case, variant))")
    return 0


def runs_overview(root: str = "results/runs",
                  perf_log: str = PERF_LOG) -> list[dict]:
    """One row per telemetry run dir (``repro.obs``), cross-linked to the
    perf-trend log: a run whose manifest ``git_commit`` matches one of the
    last two logged commits is the telemetry stream behind that side of
    the ``--check`` comparison (``gate_side`` = "prev"/"cur")."""
    perf_commits: list[str] = []
    if os.path.exists(perf_log):
        try:
            for r in json.load(open(perf_log)):
                c = r.get("commit")
                if c and c not in perf_commits:
                    perf_commits.append(c)
        except ValueError:
            pass
    gate = perf_commits[-2:]
    rows = []
    for d in sorted(glob.glob(os.path.join(root, "*"))):
        man_path = os.path.join(d, "manifest.json")
        if not os.path.isdir(d) or not os.path.exists(man_path):
            continue
        try:
            man = json.load(open(man_path))
        except ValueError:
            man = {}
        ev_path = os.path.join(d, "events.jsonl")
        n_events = (sum(1 for _ in open(ev_path))
                    if os.path.exists(ev_path) else 0)
        commit = man.get("git_commit", "unknown")
        rows.append({
            "run_id": man.get("run_id", os.path.basename(d)),
            "run_dir": d,
            "created": man.get("created_utc", "?"),
            "commit": commit,
            "plans": len(man.get("plans", [])),
            "sweeps": len(man.get("sweeps", [])),
            "events": n_events,
            "in_perf_log": commit in perf_commits,
            "gate_side": ("cur" if gate and commit == gate[-1]
                          else "prev" if len(gate) == 2 and commit == gate[0]
                          else None),
        })
    return rows


def show_runs(root: str = "results/runs") -> int:
    """CLI for ``--runs``: list telemetry run dirs next to the trend gate."""
    rows = runs_overview(root)
    if not rows:
        print(f"runs: no telemetry run dirs under {root} "
              f"(produce one with bench_engine_perf.py --obs)")
        return 0
    print(f"runs: {len(rows)} run dir(s) under {root} "
          f"(gate sides from {PERF_LOG}; render one with "
          f"tools/obs_report.py <run_dir>)")
    for r in rows:
        side = f" [gate:{r['gate_side']}]" if r["gate_side"] else ""
        note = "" if r["in_perf_log"] else "  (commit not in perf log)"
        print(f"  {r['run_id']}  {r['created']}  commit={r['commit']}{side}"
              f"  plans={r['plans']} sweeps={r['sweeps']}"
              f" events={r['events']}  {r['run_dir']}{note}")
    return 0


HEADER = """# EXPERIMENTS

Artifacts: `results/dryrun/*.json` (per-pair dry-run records),
`results/sl_accuracy.json` (Fig. 3 runs), `results/PERF_LOG.md`
(hillclimb iterations). Regenerate this file with
`PYTHONPATH=src python -m benchmarks.report`.
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="perf trend gate over results/engine_perf.json "
                         "(nonzero exit on >threshold regressions)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional steps/s drop that fails --check")
    ap.add_argument("--relative", action="store_true",
                    help="normalize by each commit's sl_host_loop row "
                         "(cross-machine comparisons, e.g. CI vs the "
                         "committed log)")
    ap.add_argument("--runs", nargs="?", const="results/runs", default=None,
                    metavar="ROOT",
                    help="list repro.obs telemetry run dirs under ROOT "
                         "(default results/runs) cross-linked to the perf "
                         "trend gate's last two commits")
    ap.add_argument("--compact", type=int, default=None, metavar="N",
                    help="prune results/engine_perf.json in place to each "
                         "(model, case, variant) key's last N commits "
                         "(CI runs this before uploading the artifact)")
    args = ap.parse_args()
    if args.compact is not None:
        sys.exit(compact_cli(args.compact))
    if args.runs is not None:
        sys.exit(show_runs(args.runs))
    if args.check:
        sys.exit(check_perf(threshold=args.threshold,
                            relative=args.relative))
    parts = [HEADER, paper_sections(), "", training_section(), "",
             dryrun_section(), "", roofline_section(), ""]
    if os.path.exists("results/PERF_LOG.md"):
        parts.append(open("results/PERF_LOG.md").read())
    else:
        parts.append("## §Perf\n\n(pending — see results/PERF_LOG.md)")
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
