"""Paper Table II: UAV energy (kJ/trip) across farm configurations.

Three configurations x three methods. The paper's absolute numbers are not
reproducible from Table I alone (movement power x our optimal 1018 m tour
already exceeds 35 kJ, so the paper's tour/dwell assumptions must differ);
dwell times are held FIXED across methods and configurations so deployment
is the only variable. The claim under test is the RELATIVE saving and the
ordering among coverage-satisfying methods.
"""
from __future__ import annotations

import numpy as np

from repro.core.deployment import (deploy_edge_devices, deploy_gasbac,
                                   deploy_kmeans, uniform_grid_sensors)
from repro.core.trajectory import greedy_tour_plan, plan_tour

CR = 200.0
# (acres, sensors) — paper Table II
CONFIGS = [(100, 25), (140, 36), (200, 49)]
PAPER_KJ = {  # paper Table II values for reference columns
    (100, 25): {"eEnergy-Split": 35.07, "K-means": 80.89, "GASBAC": 92.80},
    (140, 36): {"eEnergy-Split": 57.68, "K-means": 114.96, "GASBAC": 117.33},
    (200, 49): {"eEnergy-Split": 103.10, "K-means": 154.19, "GASBAC": 164.37},
}
HOVER_S = 8.0      # calibrated dwell (see module docstring)
COMM_S = 4.0


def run(print_csv: bool = True) -> list[dict]:
    rows = []
    base = np.zeros(2)
    for acres, n in CONFIGS:
        pts = uniform_grid_sensors(acres, n)
        deps = {
            "eEnergy-Split": deploy_edge_devices(pts, CR),
            "K-means": deploy_kmeans(pts, CR),
            "GASBAC": deploy_gasbac(pts, CR),
        }
        plans = {}
        for mname, dep in deps.items():
            planner = plan_tour if mname == "eEnergy-Split" else greedy_tour_plan
            plans[mname] = planner(dep.edge_coords, base,
                                   hover_s_per_stop=HOVER_S,
                                   comm_s_per_stop=COMM_S)
        ours = plans["eEnergy-Split"].e_per_round
        for mname, plan in plans.items():
            rows.append({
                "bench": "uav_energy(tab2)",
                "case": f"{acres}ac_{n}s/{mname}",
                "kj_per_trip": round(plan.e_per_round / 1e3, 2),
                "paper_kj": PAPER_KJ[(acres, n)][mname],
                "saving_vs_ours_pct": round(100 * (1 - ours / plan.e_per_round), 1)
                if mname != "eEnergy-Split" else 0.0,
                "rounds": plan.rounds,
            })
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},0,"
                  f"kJ={r['kj_per_trip']};paper={r['paper_kj']};"
                  f"saving_vs_baseline={r['saving_vs_ours_pct']}%;"
                  f"rounds={r['rounds']}")
    return rows


if __name__ == "__main__":
    run()
