"""§Perf hillclimb driver: run tagged dry-run variants for the three chosen
pairs and append hypothesis→change→before→after records to
results/PERF_LOG.md.

    PYTHONPATH=src python -m benchmarks.perf_iterate

Chosen pairs (from the baseline roofline table):
  A yi-9b x train_4k        — most representative of the paper's technique:
                              the DP-only client tier costs 16x per-device
                              FLOPs on its 8 layers (body probes).
  B jamba-1.5-large-398b x train_4k — largest collective term of the table
                              (MoE gather/scatter crosses the data shards).
  C qwen1.5-32b x decode_32k — worst fit: 43.9 GB/dev peak (KV cache) on a
                              16 GB chip; memory-dominated.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json      # noqa: E402
import sys       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_one          # noqa: E402
from repro.launch.steps import PerfOptions       # noqa: E402

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9

# (arch, shape, tag, PerfOptions, hypothesis)
VARIANTS = [
    # A — yi-9b train
    ("yi-9b", "train_4k", "sp_client",
     PerfOptions(seq_parallel_client=True),
     "client tier is DP-only (paper constraint) -> its 8 layers burn 16x "
     "per-device FLOPs (6.3e13 vs 3.9e12/layer, body probes). Sharding the "
     "SEQUENCE over the idle 'model' axis during the client phase keeps "
     "weights unsharded (still edge-faithful) but divides client compute "
     "by 16: predict corrected FLOPs 7.3e14 -> ~3.2e14 (-56%) and memory "
     "term down similarly; small new all-gather at the attention boundary."),
    ("yi-9b", "train_4k", "sp_both",
     PerfOptions(seq_parallel_client=True, seq_parallel_server=True),
     "extend sequence sharding to the server tier's norm/elementwise "
     "regions (Megatron-SP): predict bytes term down ~10-30% more; "
     "collective term roughly flat (all-gather moves, doesn't grow)."),
    # B — jamba train
    ("jamba-1.5-large-398b", "train_4k", "moe_grouped",
     PerfOptions(moe_groups=16),
     "baseline MoE dispatch gathers tokens globally -> cross-shard "
     "gather/scatter dominates collectives. Grouping dispatch by the 16 "
     "data shards keeps gather/scatter local; only the expert tables move "
     "(all-to-all). Predict collective bytes down >2x on MoE layers."),
    ("jamba-1.5-large-398b", "train_4k", "moe_grouped_sp",
     PerfOptions(moe_groups=16, seq_parallel_client=True,
                 seq_parallel_server=True),
     "stack sequence-parallelism on top: mamba scans are token-local, so "
     "seq sharding should cut their per-device bytes too."),
    # C — qwen decode
    ("qwen1.5-32b", "decode_32k", "kv_int8",
     PerfOptions(kv_dtype="int8"),
     "decode reads the whole KV cache every token: 5.5TB/256 = 21.5GB/dev "
     "bf16. int8 cache halves cache bytes and the 43.9GB peak; predict "
     "memory term ~2x down, quantization noise <2% (tested)."),
    ("qwen1.5-32b", "decode_32k", "kv_int8_donate",
     PerfOptions(kv_dtype="int8", donate=True),
     "the cache update also materializes input+output copies without "
     "aliasing. Donating the state buffer should cut peak memory by "
     "roughly the cache size again -> fits 16GB v5e."),
]


def terms(rec):
    f = rec.get("flops_corrected", rec.get("flops", 0))
    b = rec.get("bytes_corrected", rec.get("bytes_accessed", 0))
    c = rec.get("coll_bytes_corrected",
                rec.get("collectives", {}).get("total_bytes", 0))
    peak = rec.get("memory", {}).get("peak_memory_in_bytes")
    return {"t_compute": f / PEAK_FLOPS, "t_memory": b / HBM_BW,
            "t_collective": c / ICI_BW, "peak_gb": (peak or 0) / 1e9}


def load_baseline(arch, shape):
    path = f"results/dryrun/{arch}__{shape}__pod16x16.json"
    return json.load(open(path))


def fmt(t):
    return (f"compute {t['t_compute']:.3e}s / memory {t['t_memory']:.3e}s / "
            f"collective {t['t_collective']:.3e}s / peak {t['peak_gb']:.1f}GB")


def main():
    os.makedirs("results", exist_ok=True)
    log_path = "results/PERF_LOG.md"
    new_file = not os.path.exists(log_path)
    log = open(log_path, "a")
    if new_file:
        log.write(
            "## §Perf — hillclimb log (3 chosen pairs)\n\n"
            "Chosen from the baseline table: **yi-9b x train_4k** (most "
            "representative of the paper's technique — the DP-only client "
            "tier), **jamba-1.5-large-398b x train_4k** (most collective-"
            "bound), **qwen1.5-32b x decode_32k** (worst memory fit: "
            "43.9GB/dev on a 16GB chip). Paper-faithful BASELINE rows and "
            "beyond-paper OPTIMIZED rows are recorded separately; terms "
            "are per-device roofline seconds on TPU v5e constants.\n\n"
            "Note: the memory term inherits the CPU backend's fusion "
            "granularity, so its absolute value is an upper bound; deltas "
            "between variants (same backend) are the signal.\n\n")
    for arch, shape, tag, opts, hyp in VARIANTS:
        base = load_baseline(arch, shape)
        tb = terms(base)
        print(f"[perf] {arch} x {shape} :: {tag} ...", flush=True)
        rec = run_one(arch, shape, multi_pod=False, tag=tag, opts=opts)
        if rec["status"] != "ok":
            log.write(f"### {arch} x {shape} — `{tag}`: **ERROR** "
                      f"{rec.get('error', '')[:300]}\n\n")
            log.flush()
            continue
        tv = terms(rec)
        dom = max(("t_compute", "t_memory", "t_collective"),
                  key=lambda k: tb[k])
        delta = (tb[dom] - tv[dom]) / tb[dom] * 100 if tb[dom] else 0.0
        verdict = "CONFIRMED" if delta > 5 else (
            "PARTIAL" if delta > 0 else "REFUTED")
        log.write(
            f"### {arch} x {shape} — `{tag}`\n\n"
            f"**Hypothesis.** {hyp}\n\n"
            f"- before (paper-faithful baseline): {fmt(tb)}\n"
            f"- after (`{tag}`): {fmt(tv)}\n"
            f"- dominant term ({dom.replace('t_', '')}): "
            f"{tb[dom]:.3e}s -> {tv[dom]:.3e}s (**{delta:+.1f}%**) — "
            f"**{verdict}**\n\n")
        log.flush()
    log.close()
    print("[perf] log appended to", log_path)


if __name__ == "__main__":
    main()
