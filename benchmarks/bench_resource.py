"""Paper Table III: per-tier time / energy / CO2 for FL and the SL splits.

Analytic reproduction of the paper's own §IV-D methodology: client/server
FLOPs are counted from the XLA-compiled step (per split fraction), turned
into A5000 roofline times, the client side scaled to Jetson AGX Orin via
Eq. (9), then converted to energy (board power) and CO2.

FLOP accounting is *symmetric* across FL and SL (repro.core.paper_train's
counters): FL counts the full fwd+bwd step, SL counts the client prefix's
fwd + VJP and the server suffix's fwd+bwd (incl. the returned cut
gradient) — no asymmetric "3x forward" approximations on either side.

Reproduces the paper's headline *qualitative* finding: SL slashes client
TIME for every backbone, but the ENERGY saving is model-dependent —
lightweight MobileNetV2 wins on both, while for deeper nets the shallow
high-resolution client layers + link overhead erode the gain.
"""
from __future__ import annotations

import jax

from repro.core.energy import (CO2_G_PER_J, JETSON_AGX_ORIN, RTX_A5000,
                               scale_time)
from repro.core.link import LinkConfig
from repro.core.paper_train import count_fl_step_flops, count_sl_step_flops
from repro.core.split import init_stages, partition_stages
from repro.models.cnn import CNN_BUILDERS

SPLITS = {"FL": None, "SL_75_25": 0.75, "SL_40_60": 0.40,
          "SL_25_75": 0.25, "SL_15_85": 0.15}
BATCH = 16
IMG = 64
STEPS_PER_EPOCH = 60     # paper reports per-training-run totals; we report
                         # per-epoch-equivalent (60 minibatches)


def run(models=("resnet18", "googlenet", "mobilenetv2"),
        print_csv: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (BATCH, IMG, IMG, 3))
    y = jax.random.randint(key, (BATCH,), 0, 12)
    link = LinkConfig(rate_bps=100e6)

    for model in models:
        stages = CNN_BUILDERS[model](12)
        params = init_stages(key, stages)

        full_bwd = count_fl_step_flops(stages, params, x, y)

        for setting, frac in SPLITS.items():
            if frac is None:
                client_fl, server_fl, link_bytes = full_bwd, 0.0, 0.0
            else:
                cs, cp, ss, sp, k = partition_stages(stages, params, frac)
                client_fl, server_fl, smashed = count_sl_step_flops(
                    cs, cp, ss, sp, x, y)
                link_bytes = link.roundtrip_bytes(
                    smashed.size * smashed.dtype.itemsize,
                    smashed.dtype.itemsize)

            t_src_c = client_fl * STEPS_PER_EPOCH / (RTX_A5000.fp32_tflops * 1e12)
            t_client = scale_time(t_src_c, RTX_A5000, JETSON_AGX_ORIN)
            t_link = link.transfer_time_s(link_bytes * STEPS_PER_EPOCH, 1)
            t_server = server_fl * STEPS_PER_EPOCH / (RTX_A5000.fp32_tflops * 1e12)

            e_client = (t_client * JETSON_AGX_ORIN.power_w
                        + t_link * link.radio_power_w)
            e_server = t_server * RTX_A5000.power_w
            rows.append({
                "bench": "resource(tab3)",
                "case": f"{model}/{setting}",
                "client_s": round(t_client, 2),
                "server_s": round(t_server, 4),
                "link_s": round(t_link, 3),
                "client_kj": round(e_client / 1e3, 4),
                "server_kj": round(e_server / 1e3, 5),
                "client_co2_g": round(e_client * CO2_G_PER_J, 4),
                "server_co2_g": round(e_server * CO2_G_PER_J, 6),
                "client_tflops": round(client_fl * STEPS_PER_EPOCH / 1e12, 2),
            })
    if print_csv:
        for r in rows:
            print(f"{r['bench']},{r['case']},0,"
                  f"client_s={r['client_s']};server_s={r['server_s']};"
                  f"link_s={r['link_s']};client_kJ={r['client_kj']};"
                  f"server_kJ={r['server_kj']};client_CO2g={r['client_co2_g']}")
    return rows


if __name__ == "__main__":
    run()
